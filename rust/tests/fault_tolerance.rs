//! The elastic-membership + fault-injection plane, end to end:
//!
//! - fault plans replay byte-stably (parse → resolve → canonical spec);
//! - k-of-n partial folds are deterministic — "first k by branch
//!   index", identical modeled outputs at any worker-thread count;
//! - the epoch barrier no longer hangs on a dead peer: timed waits
//!   reap the stale rank and back-fill proxy arrivals;
//! - the historical fail-fast abort survives as the `abort` policy,
//!   now with a deadline instead of an infinite park;
//! - full clusters (real PJRT, artifact-gated) complete every epoch
//!   when a peer is killed mid-run under `takeover` / `drop`, and the
//!   takeover run reproduces the fault-free validation curve.

mod common;

use std::sync::Arc;
use std::time::Duration;

use p2pless::broker::Broker;
use p2pless::config::{Backend, FailurePolicy, SyncMode, TrainConfig};
use p2pless::coordinator::{Cluster, EpochBarrier, Membership};
use p2pless::error::Error;
use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy,
};
use p2pless::harness::faults::FaultPlanSpec;
use p2pless::util::Bytes;

// ---------------------------------------------------------------------------
// fault-plan determinism (no artifacts needed)
// ---------------------------------------------------------------------------

/// Parsing the same spec twice and resolving against the same cluster
/// shape must produce identical schedules — and the canonical rendering
/// round-trips through the parser.
#[test]
fn fault_plan_replay_is_byte_stable() {
    let spec = "kill:peer1@2;delay:peer0.branch3@1:5ms;dup:peer2.branch0@1";
    let a = FaultPlanSpec::parse(spec).unwrap().resolve(4, 3).unwrap();
    let b = FaultPlanSpec::parse(spec).unwrap().resolve(4, 3).unwrap();
    assert_eq!(a.to_spec(), b.to_spec());
    assert_eq!(a.events(), b.events());
    // canonical form parses back to the same schedule
    let c = FaultPlanSpec::parse(&a.to_spec()).unwrap().resolve(4, 3).unwrap();
    assert_eq!(c.to_spec(), a.to_spec());
}

/// The seeded rate form expands deterministically: same seed, same
/// victims and epochs; rank 0 is always spared and at least one peer
/// survives.
#[test]
fn rate_plan_resolves_deterministically() {
    let resolve = || {
        FaultPlanSpec::parse("rate:kill=0.5,seed=7")
            .unwrap()
            .resolve(8, 4)
            .unwrap()
    };
    let a = resolve();
    let b = resolve();
    assert_eq!(a.to_spec(), b.to_spec());
    assert_eq!(a.events().len(), 4, "floor(0.5 × 8) kills");
    for e in a.events() {
        assert_ne!(e.peer, 0, "rank 0 is spared by the seeded sweep");
        assert!(e.epoch >= 1 && e.epoch <= 4);
    }
    // a different seed reshuffles the schedule
    let other = FaultPlanSpec::parse("rate:kill=0.5,seed=8")
        .unwrap()
        .resolve(8, 4)
        .unwrap();
    assert_ne!(other.to_spec(), a.to_spec());
}

#[test]
fn fault_plan_rejects_malformed_specs() {
    for bad in [
        "explode:peer1@2",          // unknown verb
        "kill:peer0.branch1@2",     // kills target peers, not branches
        "dup:peer1@2",              // dups need a specific branch
        "delay:peer1@2",            // delays need a duration
        "rate:seed=3",              // rate needs kill=<frac>
        "rate:kill=1.5,seed=3",     // rate outside [0,1]
        "kill:peer1",               // missing @epoch
    ] {
        assert!(FaultPlanSpec::parse(bad).is_err(), "accepted {bad:?}");
    }
    // resolve validates against the cluster shape
    let spec = FaultPlanSpec::parse("kill:peer5@1").unwrap();
    assert!(spec.resolve(4, 3).is_err(), "peer 5 of a 4-peer cluster");
    let spec = FaultPlanSpec::parse("kill:peer1@9").unwrap();
    assert!(spec.resolve(4, 3).is_err(), "epoch 9 of a 3-epoch run");
}

// ---------------------------------------------------------------------------
// k-of-n fold quorum (no artifacts needed)
// ---------------------------------------------------------------------------

fn echo() -> Handler {
    Arc::new(|b: &Bytes| Ok(b.clone()))
}

fn platform(handler: Handler) -> Arc<FaasPlatform> {
    let p = Arc::new(FaasPlatform::new(Duration::from_millis(1500)));
    p.register(FunctionSpec::new("grad", 1024, handler)).unwrap();
    p
}

/// The quorum is "first k by branch index", not "first k to land": the
/// yielded branch set and every modeled number must be identical at any
/// worker-thread count.
#[test]
fn quorum_fold_is_deterministic_across_thread_counts() {
    let n = 12usize;
    let k = 5usize;
    let run = |threads: usize| {
        let p = platform(echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(threads)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            p,
            0,
            "grad",
            n,
            4,
            RetryPolicy::default(),
        )
        .unwrap()
        .with_quorum(k);
        for i in 0..n {
            pipe.submit(Bytes::from(vec![i as u8]), Some(Duration::from_millis(100)));
        }
        let mut yielded = Vec::new();
        while let Some((idx, out)) = pipe.next_output() {
            assert_eq!(out[0] as usize, idx, "branch payload must round-trip");
            yielded.push(idx);
        }
        let r = pipe.finish().unwrap();
        (yielded, r.wall, r.billed, r.cost_usd.to_bits(), r.invocations, r.stragglers)
    };
    let reference = run(1);
    assert_eq!(reference.0, (0..k).collect::<Vec<_>>(), "first k by index");
    assert_eq!(reference.5, n - k, "the rest are stragglers");
    assert_eq!(reference.4, n, "stragglers still execute and bill");
    for threads in [2usize, 8] {
        assert_eq!(run(threads), reference, "quorum fold moved at {threads} threads");
    }
}

/// `--fold-quorum 0` (the default) and any quorum >= n are the
/// unquorumed path — byte-identical reports, no stragglers.
#[test]
fn quorum_zero_and_full_match_unquorumed() {
    let n = 6usize;
    let run = |quorum: usize| {
        let p = platform(echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            p,
            0,
            "grad",
            n,
            4,
            RetryPolicy::default(),
        )
        .unwrap()
        .with_quorum(quorum);
        for i in 0..n {
            pipe.submit(Bytes::from(vec![i as u8]), Some(Duration::from_millis(50)));
        }
        let mut count = 0usize;
        while pipe.next_output().is_some() {
            count += 1;
        }
        let r = pipe.finish().unwrap();
        (count, r.wall, r.billed, r.cost_usd.to_bits(), r.stragglers)
    };
    let unquorumed = run(0);
    assert_eq!(unquorumed.0, n);
    assert_eq!(unquorumed.4, 0);
    assert_eq!(run(n), unquorumed, "quorum == n must change nothing");
    assert_eq!(run(n + 3), unquorumed, "quorum > n must change nothing");
}

// ---------------------------------------------------------------------------
// epoch-barrier liveness (no artifacts needed)
// ---------------------------------------------------------------------------

/// The satellite regression: pre-membership, a survivor parked on the
/// cumulative barrier forever once a peer stopped arriving. With the
/// armed table the timed wait reaps the stale rank and back-fills its
/// proxy arrivals, epoch after epoch.
#[test]
fn barrier_timed_wait_reaps_dead_peer_and_backfills() {
    let broker = Arc::new(Broker::default());
    let m = Membership::new(
        broker.clone(),
        2,
        FailurePolicy::Drop,
        Duration::from_millis(5),
        Duration::from_millis(30),
        true,
    )
    .unwrap();
    let barrier = EpochBarrier::new(&broker, 2).unwrap();
    // peer 1 never beats and never arrives; rank 0 carries 3 epochs
    for epoch in 1..=3u64 {
        m.beat(0);
        barrier.arrive(0, epoch).unwrap();
        m.note_barrier_arrival(0, epoch);
        m.fill_barrier(&barrier, epoch).unwrap();
        let mut rounds = 0;
        while !barrier.wait_timeout(epoch, m.wait_slice()).unwrap() {
            m.reap().unwrap();
            m.fill_barrier(&barrier, epoch).unwrap();
            rounds += 1;
            assert!(rounds < 100, "barrier {epoch} never filled");
        }
    }
    assert_eq!(m.deaths(), 1, "peer 1 reaped exactly once");
    assert!(!m.is_alive(1));
    assert_eq!(m.barrier_proxies(), 3, "one proxy arrival per epoch");
}

/// Under the `abort` policy the same timed wait preserves the fail-fast
/// contract of `cluster_abort.rs`: the reap aborts the broker, and a
/// peer parked on the barrier wakes with `Error::Aborted` instead of
/// hanging on the dead peer's deadline.
#[test]
fn stale_peer_under_abort_policy_releases_parked_survivor() {
    let broker = Arc::new(Broker::default());
    let m = Membership::new(
        broker.clone(),
        2,
        FailurePolicy::Abort,
        Duration::from_millis(5),
        Duration::from_millis(30),
        true,
    )
    .unwrap();
    let barrier = Arc::new(EpochBarrier::new(&broker, 2).unwrap());
    let b = barrier.clone();
    let parked = std::thread::spawn(move || b.arrive_and_wait(1, 1));
    // let rank 1 park, then let rank 0's heartbeat go stale
    std::thread::sleep(Duration::from_millis(40));
    m.beat(1);
    let err = m.reap().unwrap_err();
    assert!(matches!(err, Error::Aborted(_)), "reap must abort, got {err}");
    assert!(broker.is_aborted());
    let err = parked.join().unwrap().unwrap_err();
    assert!(matches!(err, Error::Aborted(_)), "parked peer still hung: {err}");
}

// ---------------------------------------------------------------------------
// full clusters under injected faults (real PJRT, artifact-gated)
// ---------------------------------------------------------------------------

fn fault_cfg() -> TrainConfig {
    TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 3,
        batch_size: 16,
        epochs: 3,
        lr: 0.05,
        train_samples: 3 * 16 * 2,
        val_samples: 64,
        backend: Backend::Serverless,
        sync: SyncMode::Synchronous,
        artifacts_dir: common::artifacts_dir(),
        // short deadlines so a hang regression fails fast instead of
        // stalling the suite (death detection itself is prompt — the
        // dying thread declares itself)
        heartbeat_interval_ms: 20,
        peer_timeout_ms: 5_000,
        ..Default::default()
    }
}

/// The tentpole acceptance: kill one peer mid-run; under `takeover` the
/// survivors complete every epoch AND the successor recomputes the dead
/// peer's partition through its registered lambda, so the leader's
/// validation curve is the fault-free one.
#[test]
fn takeover_completes_all_epochs_with_reference_curve() {
    require_artifacts!();
    let reference = Cluster::with_engine(fault_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer1@2".into(),
        ..fault_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    // survivors carried the full epoch count; the dead peer's report is
    // a recorded death, not a run failure
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.peers.len(), 2, "peer 1's thread died at epoch 2");
    assert_eq!(rep.counter("membership.deaths"), Some(1));
    assert_eq!(rep.counter("fault.kills_fired"), Some(1));
    // epochs 2 and 3 recomputed on the dead peer's behalf
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(2));
    // the takeover re-dispatches the dead peer's epoch-persistent batch
    // refs through its registered function: same quantizer seeds, same
    // fold width — the validation curve must match the fault-free run
    assert_eq!(rep.val_curve.len(), reference.val_curve.len());
    for ((e1, l1, _), (e2, l2, _)) in reference.val_curve.iter().zip(&rep.val_curve) {
        assert_eq!(e1, e2);
        assert!(
            (l1 - l2).abs() < 1e-6,
            "takeover diverged at epoch {e1}: {l1} vs {l2}"
        );
    }
    // takeover fan-outs sweep their own scratch; the trainer sweeps the
    // dead peer's orphans — the store still ends empty
    assert_eq!(rep.store_objects, 0);
}

/// PR-9 satellite: the same kill with the sharded params plane on. The
/// dead peer's shard objects and manifest are orphan-swept (store ends
/// empty), and the takeover re-dispatch resolves the SAME manifest the
/// dead peer published — the survivors' final params fingerprints and
/// the validation curve match the fault-free sharded run exactly.
#[test]
fn takeover_resolves_the_same_shard_manifest() {
    require_artifacts!();
    let sharded = TrainConfig { params_sharding: "4".into(), ..fault_cfg() };
    let reference = Cluster::with_engine(sharded.clone(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer1@2".into(),
        ..sharded
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.deaths"), Some(1));
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(2));
    let total = rep.counter("shard.total").unwrap();
    assert!(total > 0, "sharded faulted run reported no shard uploads");
    assert_eq!(
        rep.counter("shard.changed").unwrap() + rep.counter("shard.reused").unwrap(),
        total
    );
    assert_eq!(rep.val_curve.len(), reference.val_curve.len());
    for ((e1, l1, _), (e2, l2, _)) in reference.val_curve.iter().zip(&rep.val_curve) {
        assert_eq!(e1, e2);
        assert!(
            (l1 - l2).abs() < 1e-6,
            "sharded takeover diverged at epoch {e1}: {l1} vs {l2}"
        );
    }
    // the dead peer's shard scratch (manifest + shard objects) was
    // orphan-swept with its generations; nothing survives the run
    assert_eq!(rep.store_objects, 0, "sharded takeover leaked store objects");
    // bit-stable replay: the takeover resolves the same manifest to the
    // same shard objects every time — survivors' final params bits are
    // identical across reruns of the same fault plan
    let replay = Cluster::with_engine(
        TrainConfig {
            on_peer_failure: FailurePolicy::Takeover,
            fault_plan: "kill:peer1@2".into(),
            params_sharding: "4".into(),
            ..fault_cfg()
        },
        common::engine(),
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(replay.peers.len(), rep.peers.len());
    for (a, b) in rep.peers.iter().zip(&replay.peers) {
        assert_eq!(a.rank, b.rank);
        assert_eq!(
            a.params_fnv, b.params_fnv,
            "rank {} params bits not replay-stable under sharded takeover",
            a.rank
        );
    }
}

/// Same kill under `drop`: the run completes with the fold shrunk to
/// the survivors (no takeover, gradients recorded as dropped).
#[test]
fn drop_policy_completes_with_shrunk_fold() {
    require_artifacts!();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Drop,
        fault_plan: "kill:peer1@2".into(),
        ..fault_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.deaths"), Some(1));
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(0));
    // 2 survivors × 2 epochs skip the dead peer's slot
    assert_eq!(rep.counter("membership.dropped_grads"), Some(4));
    assert_eq!(rep.store_objects, 0);
}

/// The `abort` policy (the default) preserves the seed's fail-fast
/// semantics under an injected kill: the run errors out instead of
/// routing around the death.
#[test]
fn abort_policy_fails_fast_on_injected_kill() {
    require_artifacts!();
    let cfg = TrainConfig {
        fault_plan: "kill:peer1@2".into(),
        ..fault_cfg()
    };
    let err = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap_err();
    assert!(
        err.to_string().contains("peer 1"),
        "abort must surface the killed peer: {err}"
    );
}

/// The instance backend takes over too: the successor re-batches the
/// dead peer's raw partition with the dead peer's seed, reproducing the
/// gradients it would have computed.
#[test]
fn instance_backend_takeover_matches_reference_curve() {
    require_artifacts!();
    let base = TrainConfig { backend: Backend::Instance, ..fault_cfg() };
    let reference = Cluster::with_engine(base.clone(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer2@2".into(),
        ..base
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(2));
    for ((e1, l1, _), (e2, l2, _)) in reference.val_curve.iter().zip(&rep.val_curve) {
        assert_eq!(e1, e2);
        assert!(
            (l1 - l2).abs() < 1e-6,
            "instance takeover diverged at epoch {e1}: {l1} vs {l2}"
        );
    }
}

// ---------------------------------------------------------------------------
// elastic joins × failures (real PJRT, artifact-gated)
// ---------------------------------------------------------------------------

/// Per-rank final-params fingerprints, keyed so reports with different
/// peer orderings (joiner threads land last) compare cleanly.
fn fnv_by_rank(rep: &p2pless::coordinator::TrainReport) -> Vec<(usize, u64)> {
    let mut v: Vec<(usize, u64)> =
        rep.peers.iter().map(|p| (p.rank, p.params_fnv)).collect();
    v.sort_unstable();
    v
}

/// The tentpole composition: kill peer 1 at epoch 2 (takeover absorbs
/// its partition), then re-admit it at the epoch-3 boundary (revival).
/// The joiner warm-starts from the leader's params, takes its old
/// partition back, and the cluster lands on the fault-free result —
/// validation curve AND every rank's final params bits.
#[test]
fn revival_join_after_takeover_lands_on_fault_free_bits() {
    require_artifacts!();
    let reference = Cluster::with_engine(fault_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer1@2;join:peer1@3".into(),
        ..fault_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.deaths"), Some(1));
    assert_eq!(rep.counter("fault.kills_fired"), Some(1));
    assert_eq!(rep.counter("membership.joins"), Some(1));
    assert_eq!(rep.counter("fault.joins_fired"), Some(1));
    // only epoch 2 was carried by the survivors; epoch 3 is the
    // joiner's own work again
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(1));
    // all three ranks report — the joiner's thread files for rank 1
    assert_eq!(rep.peers.len(), 3, "revived rank must file a report");
    common::assert_val_curves_bit_identical(&reference, &rep, "revival join");
    assert_eq!(
        fnv_by_rank(&reference),
        fnv_by_rank(&rep),
        "revival join must land on the fault-free params bits"
    );
    // warm-start object deleted by the joiner, scratch swept as usual
    assert_eq!(rep.store_objects, 0, "revival join leaked store objects");
}

/// Growth join: a brand-new rank 3 grows a 3-peer cluster at the
/// epoch-2 boundary. The largest live partition is split with the
/// newcomer, the barrier widens piecewise, and the run is replay-stable
/// (same plan → same bits), with every rank in lockstep at the end.
#[test]
fn growth_join_splits_partition_and_replays_bit_stably() {
    require_artifacts!();
    let run = || {
        let cfg = TrainConfig {
            on_peer_failure: FailurePolicy::Takeover,
            fault_plan: "join:peer3@2".into(),
            ..fault_cfg()
        };
        Cluster::with_engine(cfg, common::engine()).unwrap().run().unwrap()
    };
    let rep = run();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.joins"), Some(1));
    assert_eq!(rep.counter("fault.joins_fired"), Some(1));
    assert_eq!(rep.counter("membership.deaths"), Some(0));
    assert_eq!(rep.peers.len(), 4, "grown cluster must report all four ranks");
    // synchronous averaging keeps every rank's params identical
    let fnvs = fnv_by_rank(&rep);
    for (rank, fnv) in &fnvs {
        assert_eq!(
            *fnv, fnvs[0].1,
            "rank {rank} out of lockstep after the growth join"
        );
    }
    assert_eq!(rep.store_objects, 0, "growth join leaked store objects");
    let replay = run();
    common::assert_val_curves_bit_identical(&rep, &replay, "growth join replay");
    assert_eq!(fnv_by_rank(&replay), fnvs, "growth join not replay-stable");
}

/// Join under a k-of-n fold quorum: admission, warm start and the
/// shrunk fold compose — the run completes every epoch and the joiner
/// participates in the quorumed fold like any other rank.
#[test]
fn revival_join_composes_with_fold_quorum() {
    require_artifacts!();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer1@2;join:peer1@3".into(),
        fold_quorum: 1,
        ..fault_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.joins"), Some(1));
    assert_eq!(rep.counter("fold.quorum"), Some(1));
    assert!(rep.counter("fold.stragglers").unwrap() > 0);
    assert!(rep.mean_train_loss_last_epoch().unwrap().is_finite());
    assert_eq!(rep.store_objects, 0);
}

/// The instance backend joins too: the revived peer re-batches its raw
/// partition with its own seed (no store-backed refs involved), so the
/// composition lands on the instance reference curve.
#[test]
fn instance_backend_revival_join_matches_reference() {
    require_artifacts!();
    let base = TrainConfig { backend: Backend::Instance, ..fault_cfg() };
    let reference = Cluster::with_engine(base.clone(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig {
        on_peer_failure: FailurePolicy::Takeover,
        fault_plan: "kill:peer2@2;join:peer2@3".into(),
        ..base
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    assert_eq!(rep.counter("membership.joins"), Some(1));
    assert_eq!(rep.counter("membership.takeover_epochs"), Some(1));
    assert_eq!(rep.peers.len(), 3);
    common::assert_val_curves_bit_identical(&reference, &rep, "instance revival join");
    assert_eq!(fnv_by_rank(&reference), fnv_by_rank(&rep));
}

// ---------------------------------------------------------------------------
// chaos invariance: injected I/O faults are transparent (artifact-gated)
// ---------------------------------------------------------------------------

/// Every chaos kind at once — transient store put/get errors, a
/// corrupted read, store/broker delays, a publish drop, a kill AND a
/// revival join — under `takeover`. At each exec-slot count the faulted
/// run must land on the fault-free run's exact bits: the retry loop
/// absorbs transients, hash verification catches the corruption and
/// re-fetches, delays only move measured wall, and the join path is
/// warm-started from in-lockstep params.
#[test]
fn full_chaos_run_is_bit_identical_to_fault_free() {
    require_artifacts!();
    const PLAN: &str = "kill:peer1@2;join:peer1@3;\
                        storeput:peer0@1;storeget:peer2@2;storecorrupt:peer0@3;\
                        storedelay:peer1@1:0ms;\
                        brokerdrop:peer2@1;brokerdelay:peer0@2:0ms";
    for slots in [1usize, 2, 8] {
        let engine = Arc::new(p2pless::runtime::Engine::with_slots(slots).unwrap());
        let base = TrainConfig { exec_slots: slots, ..fault_cfg() };
        let reference = Cluster::with_engine(base.clone(), engine.clone())
            .unwrap()
            .run()
            .unwrap();
        let cfg = TrainConfig {
            on_peer_failure: FailurePolicy::Takeover,
            fault_plan: PLAN.into(),
            ..base
        };
        let rep = Cluster::with_engine(cfg, engine).unwrap().run().unwrap();
        assert_eq!(rep.epochs_run(), 3, "slots {slots}");
        // every scheduled injection found its op and fired exactly once
        assert_eq!(rep.counter("fault.kills_fired"), Some(1), "slots {slots}");
        assert_eq!(rep.counter("fault.joins_fired"), Some(1), "slots {slots}");
        assert_eq!(rep.counter("fault.store_faults_fired"), Some(4), "slots {slots}");
        assert_eq!(rep.counter("fault.broker_faults_fired"), Some(2), "slots {slots}");
        // ...and was absorbed by the matching recovery plane
        assert!(rep.counter("store.retries").unwrap() >= 2, "slots {slots}");
        assert_eq!(rep.counter("store.corrupt_refetches"), Some(1), "slots {slots}");
        assert!(rep.counter("broker.retries").unwrap() >= 1, "slots {slots}");
        // transparency: the training math never saw any of it
        common::assert_val_curves_bit_identical(
            &reference,
            &rep,
            &format!("chaos at {slots} slots"),
        );
        assert_eq!(
            fnv_by_rank(&reference),
            fnv_by_rank(&rep),
            "chaos perturbed final params bits at {slots} slots"
        );
        assert_eq!(rep.store_objects, 0, "chaos run leaked store objects");
    }
}

/// Disarmed regression: without a fault plan the chaos plane must not
/// exist observably. The retry knobs may be set to anything — the
/// pinned data-plane counters, the curve and the final params bits are
/// byte-identical to the default-knob run, and every chaos counter
/// reads zero.
#[test]
fn disarmed_chaos_knobs_change_nothing() {
    require_artifacts!();
    let baseline = Cluster::with_engine(fault_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let tuned = TrainConfig {
        store_retries: 7,
        store_backoff_ms: 5,
        ..fault_cfg()
    };
    let rep = Cluster::with_engine(tuned, common::engine())
        .unwrap()
        .run()
        .unwrap();
    common::assert_val_curves_bit_identical(&baseline, &rep, "disarmed knobs");
    common::assert_pinned_counters_eq(&baseline, &rep, "disarmed knobs");
    assert_eq!(fnv_by_rank(&baseline), fnv_by_rank(&rep));
    for counter in [
        "store.retries",
        "store.corrupt_refetches",
        "broker.retries",
        "membership.joins",
    ] {
        assert_eq!(rep.counter(counter), Some(0), "{counter} fired while disarmed");
        assert_eq!(baseline.counter(counter), Some(0), "{counter} fired in baseline");
    }
    // the PR-1 lambda-retry accounting is untouched by the store knobs
    assert_eq!(
        rep.counter("faas.retries"),
        baseline.counter("faas.retries"),
        "store knobs leaked into the faas retry plane"
    );
}

/// k-of-n through the whole cluster: a serverless run with a fold
/// quorum completes, counts its stragglers, and still learns (the loss
/// denominators shrink to the folded branch count).
#[test]
fn cluster_fold_quorum_counts_stragglers() {
    require_artifacts!();
    let cfg = TrainConfig { fold_quorum: 1, ..fault_cfg() };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 3);
    // 2 batches per peer-epoch, quorum 1: one straggler each
    assert_eq!(rep.counter("fold.stragglers"), Some(3 * 3));
    assert_eq!(rep.counter("fold.quorum"), Some(1));
    assert!(rep.mean_train_loss_last_epoch().unwrap().is_finite());
}
