//! Integration: full multi-peer training clusters (real PJRT) across
//! the paper's axes — backends, sync modes, compression, fault
//! injection — checking replica consistency and learning progress.

mod common;

use std::sync::Arc;

use p2pless::broker::FaultPlan;
use p2pless::config::{Backend, Compression, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::metrics::Stage;
use p2pless::runtime::Engine;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 2,
        lr: 0.05,
        train_samples: 2 * 16 * 3,
        val_samples: 64,
        backend: Backend::Instance,
        sync: SyncMode::Synchronous,
        artifacts_dir: common::artifacts_dir(),
        ..Default::default()
    }
}

#[test]
fn synchronous_cluster_trains_and_reports() {
    require_artifacts!();
    let rep = Cluster::with_engine(base_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.peers.len(), 2);
    assert_eq!(rep.epochs_run(), 2);
    assert_eq!(rep.val_curve.len(), 2, "leader verdict per epoch");
    for p in &rep.peers {
        assert_eq!(p.train_loss.len(), 2);
        assert!(p.train_loss.iter().all(|l| l.is_finite()));
        assert!(p.sent_bytes.iter().all(|&b| b > 0));
    }
    // every Table-I stage was measured
    for (stage, s) in &rep.stages {
        if *stage != Stage::ConvergenceDetection {
            assert!(s.count > 0, "stage {stage} unmeasured");
        }
    }
    assert!(rep.broker_msgs > 0);
}

#[test]
fn async_cluster_completes_without_barrier() {
    require_artifacts!();
    let cfg = TrainConfig { sync: SyncMode::Asynchronous, ..base_cfg() };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 2);
    assert!(rep.mean_train_loss_last_epoch().unwrap().is_finite());
}

#[test]
fn serverless_backend_matches_instance_loss() {
    require_artifacts!();
    // identical config except the backend. The serverless path batches
    // the partition once before training (paper §III-B) while the
    // instance path reshuffles per epoch — but with no dropped samples
    // and equal-size batches the epoch-mean gradient is the same sample
    // mean either way, so the leader's validation loss after each epoch
    // must still match closely (f32 association noise only).
    let inst = Cluster::with_engine(base_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let cfg = TrainConfig { backend: Backend::Serverless, ..base_cfg() };
    let srv = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert!(srv.lambda_invocations > 0, "lambdas must actually run");
    assert!(srv.lambda_cost_usd > 0.0);
    for ((_, li, _), (_, ls, _)) in inst.val_curve.iter().zip(&srv.val_curve) {
        assert!(
            (li - ls).abs() < 1e-3,
            "instance {li} vs serverless {ls}"
        );
    }
}

#[test]
fn serverless_store_stays_bounded_across_epochs() {
    require_artifacts!();
    // batch objects are uploaded once (epoch-persistent generation) and
    // removed at teardown; each epoch's scratch (params + parked
    // gradients) is reclaimed by its generation sweep — so the store
    // ends empty no matter how many epochs ran (put/decode counter
    // accounting lives in rust/tests/data_plane.rs)
    let cfg = TrainConfig { backend: Backend::Serverless, epochs: 3, ..base_cfg() };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert!(rep.lambda_invocations > 0);
    assert!(rep.lambda_measured_wall > std::time::Duration::ZERO);
    assert_eq!(
        rep.store_objects, 0,
        "per-epoch sweep must leave the object store empty"
    );
}

#[test]
fn exec_slots_do_not_change_results() {
    require_artifacts!();
    // the engine semaphore bounds *physical* PJRT concurrency only:
    // the same gradients flow either way, so the leader's verdict
    // curve must match between serialized and parallel engines
    let run = |slots: usize| {
        let cfg = TrainConfig {
            backend: Backend::Serverless,
            exec_slots: slots,
            ..base_cfg()
        };
        let engine = Arc::new(Engine::with_slots(slots).unwrap());
        Cluster::with_engine(cfg, engine).unwrap().run().unwrap()
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial.val_curve.len(), parallel.val_curve.len());
    for ((e1, l1, a1), (e2, l2, a2)) in serial.val_curve.iter().zip(&parallel.val_curve) {
        assert_eq!(e1, e2);
        assert!((l1 - l2).abs() < 1e-5, "slots=1 {l1} vs slots=8 {l2}");
        assert!((a1 - a2).abs() < 1e-5);
    }
}

#[test]
fn qsgd_compression_still_learns() {
    require_artifacts!();
    let cfg = TrainConfig {
        compression: Compression::Qsgd { s: 64 },
        epochs: 3,
        ..base_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    let first = rep.peers[0].train_loss.first().copied().unwrap();
    let last = rep.mean_train_loss_last_epoch().unwrap();
    assert!(
        last < first + 0.1,
        "training must not diverge under QSGD: {first} -> {last}"
    );
    // QSGD wire must be smaller than raw f32
    let raw = 4 * 9546; // squeezenet_mnist param count
    for p in &rep.peers {
        for &sent in &p.sent_bytes {
            assert!(sent < raw / 3, "sent {sent} vs raw {raw}");
        }
    }
}

#[test]
fn async_mode_survives_dropped_messages() {
    require_artifacts!();
    // every 3rd publish silently dropped: async peers fall back to
    // stale/absent gradients (the paper's "temporary disruptions")
    let cfg = TrainConfig { sync: SyncMode::Asynchronous, ..base_cfg() };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .with_faults(FaultPlan { drop_every: 3, delay_us: 0 })
        .run()
        .unwrap();
    assert_eq!(rep.epochs_run(), 2, "async training must complete despite drops");
}

#[test]
fn sync_replicas_stay_consistent() {
    require_artifacts!();
    // in synchronous mode every peer applies the same averaged gradient
    // to the same init, so their reported train-loss sequences are the
    // evaluations of identical replicas on different partitions; the
    // leader's verdicts must be identical across two identical runs.
    let r1 = Cluster::with_engine(base_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let r2 = Cluster::with_engine(base_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    for ((e1, l1, a1), (e2, l2, a2)) in r1.val_curve.iter().zip(&r2.val_curve) {
        assert_eq!(e1, e2);
        assert!((l1 - l2).abs() < 1e-5, "run determinism: {l1} vs {l2}");
        assert!((a1 - a2).abs() < 1e-5);
    }
}

#[test]
fn four_peer_cluster_runs() {
    require_artifacts!();
    let cfg = TrainConfig {
        peers: 4,
        train_samples: 4 * 16 * 2,
        epochs: 1,
        ..base_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.peers.len(), 4);
    // 4 gradient publishes + 1 leader verdict per epoch go through the
    // broker facade (barrier arrivals publish on their queue directly)
    assert!(rep.broker_msgs >= 5, "broker_msgs = {}", rep.broker_msgs);
}
