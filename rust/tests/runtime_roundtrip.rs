//! Integration: the AOT bridge end to end — python-lowered HLO artifacts
//! loaded, compiled and executed from rust via PJRT, checked for
//! numerical sanity and internal consistency.

mod common;

use p2pless::data::{DatasetKind, SyntheticDataset};
use p2pless::runtime::ModelRuntime;
use p2pless::util::Rng;

fn batch(kind: DatasetKind, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let d = SyntheticDataset::new(kind, seed).generate(n);
    (d.x, d.y)
}

#[test]
fn grad_runs_and_is_finite_for_all_models() {
    require_artifacts!();
    for key in ["mini_squeezenet_mnist", "mini_mobilenet_mnist", "mini_vgg_mnist"] {
        let rt = ModelRuntime::load(common::engine(), &common::artifacts_dir(), key).unwrap();
        let params = rt.init_params().unwrap();
        assert_eq!(params.len(), rt.param_count());
        let (x, y) = batch(DatasetKind::Mnist, 16, 1);
        let out = rt.grad(16, &params, &x, &y, true).unwrap();
        assert!(out.loss.is_finite(), "{key}: loss {}", out.loss);
        assert!(out.loss > 0.0 && out.loss < 20.0, "{key}: loss {}", out.loss);
        assert_eq!(out.grads.len(), rt.param_count());
        assert!(out.grads.iter().all(|g| g.is_finite()), "{key}: non-finite grads");
        let norm: f32 = out.grads.iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(norm > 1e-6, "{key}: zero gradient");
    }
}

#[test]
fn pallas_and_nopallas_artifacts_agree() {
    require_artifacts!();
    // the L1 kernel must not change the math (ablation artifact pair)
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_squeezenet_mnist",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let (x, y) = batch(DatasetKind::Mnist, 64, 2);
    let a = rt.grad(64, &params, &x, &y, true).unwrap();
    let b = rt.grad(64, &params, &x, &y, false).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-4, "{} vs {}", a.loss, b.loss);
    let max_diff = a
        .grads
        .iter()
        .zip(&b.grads)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "pallas vs jnp grads differ by {max_diff}");
}

#[test]
fn update_is_exact_sgd() {
    require_artifacts!();
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_squeezenet_mnist",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let mut rng = Rng::seed_from_u64(5);
    let grads: Vec<f32> = (0..params.len()).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let lr = 0.1f32;
    let updated = rt.update(&params, &grads, lr).unwrap();
    for i in 0..params.len() {
        let want = params[i] - lr * grads[i];
        assert!(
            (updated[i] - want).abs() <= 1e-6 * want.abs().max(1.0),
            "i={i}: {} vs {}",
            updated[i],
            want
        );
    }
}

#[test]
fn eval_counts_are_bounded() {
    require_artifacts!();
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_mobilenet_cifar",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let (x, y) = batch(DatasetKind::Cifar, 64, 3);
    let (loss, correct) = rt.eval(64, &params, &x, &y).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=64.0).contains(&correct), "correct={correct}");
}

#[test]
fn eval_dataset_tiles_batches() {
    require_artifacts!();
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_squeezenet_mnist",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let val = SyntheticDataset::new(DatasetKind::Mnist, 9).generate(200);
    // 200 samples -> largest eval batch 64 -> 3 batches, 192 samples
    let (loss, acc) = rt.eval_dataset(&params, &val).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn sgd_step_reduces_loss_on_fixed_batch() {
    require_artifacts!();
    // optimization sanity through the full AOT path
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_vgg_mnist",
    )
    .unwrap();
    let mut params = rt.init_params().unwrap();
    let (x, y) = batch(DatasetKind::Mnist, 16, 7);
    let first = rt.grad(16, &params, &x, &y, true).unwrap();
    let mut loss = first.loss;
    let mut grads = first.grads;
    for _ in 0..5 {
        params = rt.update(&params, &grads, 0.05).unwrap();
        let out = rt.grad(16, &params, &x, &y, true).unwrap();
        loss = out.loss;
        grads = out.grads;
    }
    assert!(
        loss < first.loss,
        "5 SGD steps should reduce loss: {} -> {}",
        first.loss,
        loss
    );
}

#[test]
fn concurrent_loads_compile_once() {
    require_artifacts!();
    // regression: two threads missing the executable cache for the same
    // artifact both compiled it — seconds of duplicated XLA work per
    // racer, and the loser's executable was silently dropped. The
    // per-key in-flight guard must collapse the race to one compile.
    use p2pless::runtime::Engine;
    use std::sync::{Arc, Barrier};

    // a fresh engine: the shared `common::engine()` may already have
    // cached this artifact from another test
    let engine = Arc::new(Engine::new().expect("PJRT CPU client"));
    let rt = ModelRuntime::load(
        engine.clone(),
        &common::artifacts_dir(),
        "mini_squeezenet_mnist",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let (x, y) = batch(DatasetKind::Mnist, 16, 4);

    const THREADS: usize = 8;
    let barrier = Arc::new(Barrier::new(THREADS));
    let rt = Arc::new(rt);
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let rt = rt.clone();
            let barrier = barrier.clone();
            let (params, x, y) = (params.clone(), x.clone(), y.clone());
            std::thread::spawn(move || {
                barrier.wait();
                // every thread races Engine::load for the same grad
                // artifact on a cold cache
                rt.grad(16, &params, &x, &y, true).unwrap().loss
            })
        })
        .collect();
    let losses: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // everyone got a working executable for the same (params, batch)
    for l in &losses {
        assert!((l - losses[0]).abs() < 1e-5, "{l} vs {}", losses[0]);
    }
    assert_eq!(
        engine.compile_count(),
        1,
        "concurrent loaders must share one compile"
    );
    assert_eq!(engine.cached_executables(), 1);
}

#[test]
fn wrong_shapes_are_rejected() {
    require_artifacts!();
    let rt = ModelRuntime::load(
        common::engine(),
        &common::artifacts_dir(),
        "mini_squeezenet_mnist",
    )
    .unwrap();
    let params = rt.init_params().unwrap();
    let (x, y) = batch(DatasetKind::Mnist, 16, 1);
    // wrong param count
    assert!(rt.grad(16, &params[1..], &x, &y, true).is_err());
    // batch with no artifact
    assert!(rt.grad(17, &params, &x, &y, true).is_err());
    // grads of the wrong length for update
    assert!(rt.update(&params, &params[1..], 0.1).is_err());
}
