//! Fused micro-batched execution, end to end:
//!
//! - batch-fold bit-identity (no PJRT needed): work items routed
//!   through the [`ExecBatcher`] produce bit-identical outputs — and
//!   bit-identical branch-order f64 folds — at `--exec-batch` 1/4/8 ×
//!   worker threads 1/2/8, because fusion never mixes members' data;
//! - mixed params versions: interleaved generations flow through the
//!   batcher without ever corrupting each other's outputs (the
//!   never-fuse-across-versions contract; exact group accounting is
//!   unit-tested in `runtime::batcher`);
//! - cluster acceptance (real PJRT, artifact-gated): training results
//!   are invariant across `--exec-batch` × `--exec-threads`, an
//!   8-branch single-peer run at `--exec-batch 8` performs exactly one
//!   fused engine dispatch per epoch, and fusion composes with
//!   cross-epoch dispatch (generations never fuse, stores stay clean);
//! - stacked execution (PR 7): the same fold bit-identity holds when
//!   groups complete as ONE stacked execution at stacking factors
//!   1/4/8 × threads 1/2/8, groups too big for any stacked artifact
//!   fall back without corruption, and — with v2 artifacts — a full
//!   fused group in the real cluster runs as exactly one stacked XLA
//!   execution (`engine.stacked_execs == engine.batched_execs`).

mod common;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use p2pless::config::{OffloadMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::faas::Semaphore;
use p2pless::runtime::{literal_f32, Engine, ExecBatcher, FuseKey, Manifest};

const ITEMS: usize = 16;
const DIM: usize = 8;

fn key(version: u64) -> FuseKey {
    FuseKey { exe: 0xFEED, batch: DIM, params: 4, version }
}

/// Deterministic per-item input, distinct across items so any routing
/// mix-up inside the batcher corrupts some item's output bits.
fn item_input(version: u64, i: usize) -> Vec<f32> {
    (0..DIM)
        .map(|k| (version.wrapping_mul(31) + i as u64 * 7 + k as u64) as f32 * 0.015625 - 1.0)
        .collect()
}

fn transform(x: &[f32]) -> Vec<f32> {
    x.iter().map(|v| 2.0 * v + 1.0).collect()
}

/// Push `ITEMS` work items of `version_of(i)` through one batcher on a
/// pool of `threads` plain worker threads; returns per-item output bits
/// in item order.
fn run_pool(
    exec_batch: usize,
    threads: usize,
    version_of: fn(usize) -> u64,
) -> Vec<Vec<u32>> {
    let batcher = Arc::new(ExecBatcher::new(exec_batch, Duration::from_millis(2)));
    let sem = Arc::new(Semaphore::new(2));
    let queue = Arc::new(Mutex::new((0..ITEMS).collect::<VecDeque<usize>>()));
    let results: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(vec![Vec::new(); ITEMS]));
    let workers: Vec<_> = (0..threads)
        .map(|_| {
            let batcher = batcher.clone();
            let sem = sem.clone();
            let queue = queue.clone();
            let results = results.clone();
            std::thread::spawn(move || loop {
                let Some(i) = queue.lock().unwrap().pop_front() else {
                    return;
                };
                let version = version_of(i);
                let data = item_input(version, i);
                let inputs = vec![literal_f32(&data, &[DIM as i64]).unwrap()];
                let (outs, _ins, _timing) = batcher
                    .run(key(version), inputs, &sem, |ins| {
                        let v = ins[0].to_vec::<f32>()?;
                        let out = transform(&v);
                        Ok(vec![literal_f32(&out, &[out.len() as i64])?])
                    })
                    .unwrap();
                let bits: Vec<u32> = outs[0]
                    .to_vec::<f32>()
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                results.lock().unwrap()[i] = bits;
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(batcher.fused_branches(), ITEMS as u64, "every item must execute");
    Arc::try_unwrap(results).unwrap().into_inner().unwrap()
}

/// Like [`run_pool`], but through [`ExecBatcher::run_stacked`]: a
/// synthetic stacked strategy mirrors the runtime's — it declines
/// singleton groups and groups bigger than the available factor
/// `stack_k`, and otherwise computes every lane in one call padded to
/// `stack_k`. Returns per-item bits plus the batcher's stacked
/// counters.
fn run_pool_stacked(
    exec_batch: usize,
    threads: usize,
    stack_k: usize,
) -> (Vec<Vec<u32>>, u64, u64) {
    let batcher = Arc::new(ExecBatcher::new(exec_batch, Duration::from_millis(2)));
    let sem = Arc::new(Semaphore::new(2));
    let queue = Arc::new(Mutex::new((0..ITEMS).collect::<VecDeque<usize>>()));
    let results: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(vec![Vec::new(); ITEMS]));
    let workers: Vec<_> = (0..threads)
        .map(|_| {
            let batcher = batcher.clone();
            let sem = sem.clone();
            let queue = queue.clone();
            let results = results.clone();
            std::thread::spawn(move || loop {
                let Some(i) = queue.lock().unwrap().pop_front() else {
                    return;
                };
                let data = item_input(42, i);
                let inputs = vec![literal_f32(&data, &[DIM as i64]).unwrap()];
                let (outs, _ins, _timing) = batcher
                    .run_stacked(
                        key(42),
                        inputs,
                        &sem,
                        |ins| {
                            let v = ins[0].to_vec::<f32>()?;
                            let out = transform(&v);
                            Ok(vec![literal_f32(&out, &[out.len() as i64])?])
                        },
                        |views| {
                            let g = views.len();
                            if g < 2 || g > stack_k {
                                return Ok(None);
                            }
                            let mut outs = Vec::with_capacity(g);
                            for v in views {
                                let x = v[0].to_vec::<f32>()?;
                                let out = transform(&x);
                                outs.push(vec![literal_f32(&out, &[out.len() as i64])?]);
                            }
                            Ok(Some((outs, Duration::from_micros(50), stack_k)))
                        },
                    )
                    .unwrap();
                let bits: Vec<u32> = outs[0]
                    .to_vec::<f32>()
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                results.lock().unwrap()[i] = bits;
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(batcher.fused_branches(), ITEMS as u64, "every item must execute");
    let (stacked, pad) = (batcher.stacked_execs(), batcher.pad_waste());
    (Arc::try_unwrap(results).unwrap().into_inner().unwrap(), stacked, pad)
}

/// Fold per-item outputs in item order into one f64 running sum per
/// coordinate — the shape of the epoch gradient fold — and return the
/// bit pattern.
fn fold_bits(outputs: &[Vec<u32>]) -> Vec<u64> {
    let mut acc = vec![0f64; DIM];
    for out in outputs {
        for (a, &bits) in acc.iter_mut().zip(out) {
            *a += f32::from_bits(bits) as f64;
        }
    }
    acc.iter().map(|v| v.to_bits()).collect()
}

/// The acceptance bar below the cluster: outputs and branch-order folds
/// are bit-identical at every `--exec-batch` × thread-count
/// combination, because a fused dispatch executes each member's own
/// inputs and nothing else.
#[test]
fn fused_folds_bit_identical_across_batch_and_threads() {
    let reference = run_pool(1, 1, |_| 42);
    let reference_fold = fold_bits(&reference);
    for exec_batch in [1usize, 4, 8] {
        for threads in [1usize, 2, 8] {
            let got = run_pool(exec_batch, threads, |_| 42);
            for (i, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g, w,
                    "item {i} output bits diverged at batch {exec_batch}, \
                     threads {threads}"
                );
            }
            assert_eq!(
                fold_bits(&got),
                reference_fold,
                "fold bits diverged at batch {exec_batch}, threads {threads}"
            );
        }
    }
}

/// Interleaved params versions flow through the batcher uncorrupted:
/// items of generation 1 and 2 alternate, and every item still gets its
/// own transform back (a cross-version fuse would hand some item
/// another generation's inputs — the unit tests in `runtime::batcher`
/// additionally pin the exact group accounting).
#[test]
fn mixed_params_versions_stay_isolated() {
    let got = run_pool(4, 8, |i| 1 + (i % 2) as u64);
    for (i, bits) in got.iter().enumerate() {
        let want: Vec<u32> = transform(&item_input(1 + (i % 2) as u64, i))
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(bits, &want, "item {i} was cross-contaminated");
    }
}

/// Stacked execution preserves the fold exactly: outputs and
/// branch-order folds at stacking factors 1/4/8 × threads 1/2/8 are
/// bit-identical to the sequential single-thread reference — whether a
/// group completed as one stacked execution, was padded, or fell back.
#[test]
fn stacked_folds_bit_identical_across_stack_and_threads() {
    let reference = run_pool(1, 1, |_| 42);
    let reference_fold = fold_bits(&reference);
    for stack_k in [1usize, 4, 8] {
        for threads in [1usize, 2, 8] {
            let (got, _stacked, _pad) = run_pool_stacked(stack_k, threads, stack_k);
            for (i, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g, w,
                    "item {i} output bits diverged at stack {stack_k}, \
                     threads {threads}"
                );
            }
            assert_eq!(
                fold_bits(&got),
                reference_fold,
                "fold bits diverged at stack {stack_k}, threads {threads}"
            );
        }
    }
}

/// Groups bigger than any available stacking factor decline the stack
/// and fall back to back-to-back turns — bits still never move. (At
/// `--exec-batch 8` with artifacts topping out at k=4, whether any
/// given group stacked depends on arrival timing; correctness must
/// not.)
#[test]
fn oversized_groups_fall_back_without_corruption() {
    let reference = run_pool(1, 1, |_| 42);
    let (got, _stacked, _pad) = run_pool_stacked(8, 8, 4);
    for (i, (g, w)) in got.iter().zip(&reference).enumerate() {
        assert_eq!(g, w, "item {i} corrupted on the fallback path");
    }
    assert_eq!(fold_bits(&got), fold_bits(&reference));
}

// -------------------------------------------------------------- cluster

/// The shared 2-peer serverless base at 2 epochs (the fusion suites
/// only need two generations to cross an epoch boundary).
fn serverless_cfg() -> TrainConfig {
    common::serverless_cfg(2)
}

fn engine_with_batch(exec_batch: usize, wait_us: u64) -> Arc<Engine> {
    Arc::new(
        Engine::with_exec_batching(0, exec_batch, Duration::from_micros(wait_us))
            .expect("PJRT CPU client"),
    )
}

/// Training results are invariant across the fusion matrix: the leader's
/// validation curve at `--exec-batch` 4/8 × `--exec-threads` 1/2/8
/// matches the unbatched single-thread reference.
#[test]
fn fused_cluster_results_invariant_across_batch_and_threads() {
    require_artifacts!();
    let run = |engine: &Arc<Engine>, exec_batch: usize, threads: usize| {
        let cfg = TrainConfig {
            exec_batch,
            exec_threads: threads,
            ..serverless_cfg()
        };
        Cluster::with_engine(cfg, engine.clone()).unwrap().run().unwrap()
    };
    let reference = run(&common::engine(), 1, 1);
    assert_eq!(reference.counter("engine.batched_execs"), Some(0), "fusion off");
    for exec_batch in [4usize, 8] {
        let engine = engine_with_batch(exec_batch, 500);
        for threads in [1usize, 2, 8] {
            let got = run(&engine, exec_batch, threads);
            assert_eq!(got.lambda_invocations, reference.lambda_invocations);
            assert_eq!(got.val_curve.len(), reference.val_curve.len());
            for ((e1, l1, a1), (e2, l2, a2)) in
                reference.val_curve.iter().zip(&got.val_curve)
            {
                assert_eq!(e1, e2);
                assert!(
                    (l1 - l2).abs() < 1e-6,
                    "val loss diverged at batch {exec_batch}, threads {threads}: \
                     {l1} vs {l2}"
                );
                assert!((a1 - a2).abs() < 1e-6);
            }
            assert_eq!(got.store_objects, 0);
        }
    }
}

/// The headline acceptance: an 8-branch single-peer epoch at
/// `--exec-batch 8` with 8 workers performs exactly ONE fused engine
/// dispatch per epoch, carrying all 8 branches (100% fill), and the
/// math matches the unbatched run.
#[test]
fn eight_branches_fuse_into_one_dispatch_per_epoch() {
    require_artifacts!();
    let epochs = 2usize;
    let cfg = |exec_batch: usize| TrainConfig {
        peers: 1,
        epochs,
        train_samples: 8 * 16, // 8 branches per epoch
        exec_threads: 8,
        exec_batch,
        // a generous collect window: the group closes the instant the
        // 8th branch arrives, so the window is never actually paid in
        // steady state — it only guards against scheduling hiccups
        exec_batch_wait_us: 5_000_000,
        ..serverless_cfg()
    };
    let fused = Cluster::with_engine(cfg(8), engine_with_batch(8, 5_000_000))
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        fused.counter("engine.batched_execs"),
        Some(epochs as u64),
        "8 branches at --exec-batch 8 must fuse into one dispatch per epoch"
    );
    assert_eq!(
        fused.counter("engine.fused_branches"),
        Some((epochs * 8) as u64)
    );
    assert_eq!(fused.counter("engine.batch_fill"), Some(100));

    let unbatched = Cluster::with_engine(cfg(1), common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(unbatched.counter("engine.batched_execs"), Some(0));
    assert_eq!(fused.lambda_invocations, unbatched.lambda_invocations);
    assert_eq!(fused.val_curve.len(), unbatched.val_curve.len());
    for ((_, l1, a1), (_, l2, a2)) in fused.val_curve.iter().zip(&unbatched.val_curve) {
        assert!((l1 - l2).abs() < 1e-6, "fused {l1} vs unbatched {l2}");
        assert!((a1 - a2).abs() < 1e-6);
    }
    assert_eq!(fused.store_objects, 0);
}

/// The PR-7 headline acceptance: with stacked artifacts (manifest v2),
/// every full fused group executes as exactly ONE stacked XLA
/// execution — `engine.stacked_execs` equals the fused dispatch count,
/// nothing is padded at an exact fit, and the validation curve still
/// matches the unbatched reference.
#[test]
fn full_groups_run_as_one_stacked_xla_execution() {
    require_artifacts!();
    let man = Manifest::load(common::artifacts_dir()).unwrap();
    let ks = match man.models.get("mini_squeezenet_mnist") {
        Some(entry) => entry.stacked_ks(16),
        None => Vec::new(),
    };
    // pick the largest stacking factor the artifacts offer for batch 16
    let Some(k) = [8usize, 4].into_iter().find(|k| ks.contains(k)) else {
        eprintln!(
            "SKIP full_groups_run_as_one_stacked_xla_execution: artifacts \
             have no stacked grad executables (manifest v1 — re-run aot.py)"
        );
        return;
    };
    let epochs = 2usize;
    let cfg = |exec_batch: usize| TrainConfig {
        peers: 1,
        epochs,
        train_samples: 8 * 16, // 8 branches per epoch
        exec_threads: 8,
        exec_batch,
        exec_batch_wait_us: 5_000_000,
        ..serverless_cfg()
    };
    let stacked = Cluster::with_engine(cfg(k), engine_with_batch(k, 5_000_000))
        .unwrap()
        .run()
        .unwrap();
    let groups = (epochs * 8 / k) as u64;
    assert_eq!(
        stacked.counter("engine.batched_execs"),
        Some(groups),
        "8 branches per epoch at --exec-batch {k} must pack into {groups} dispatches"
    );
    assert_eq!(
        stacked.counter("engine.stacked_execs"),
        Some(groups),
        "every full fused group must run as ONE stacked XLA execution"
    );
    assert_eq!(
        stacked.counter("engine.pad_waste"),
        Some(0),
        "exact-fit groups must not pad"
    );

    let unbatched = Cluster::with_engine(cfg(1), common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(unbatched.counter("engine.stacked_execs"), Some(0));
    assert_eq!(stacked.lambda_invocations, unbatched.lambda_invocations);
    assert_eq!(stacked.val_curve.len(), unbatched.val_curve.len());
    for ((_, l1, a1), (_, l2, a2)) in stacked.val_curve.iter().zip(&unbatched.val_curve) {
        assert!((l1 - l2).abs() < 1e-6, "stacked {l1} vs unbatched {l2}");
        assert!((a1 - a2).abs() < 1e-6);
    }
    assert_eq!(stacked.store_objects, 0);
}

/// `--exec-batch auto` never moves the math: the controller resizes
/// groups from live queue depth, but the validation curve matches the
/// unbatched single-thread reference and the store stays clean.
#[test]
fn auto_exec_batch_matches_unbatched_reference() {
    require_artifacts!();
    let reference = Cluster::with_engine(serverless_cfg(), common::engine())
        .unwrap()
        .run()
        .unwrap();
    let auto = Cluster::with_engine(
        TrainConfig {
            exec_batch: 8,
            exec_batch_auto: true,
            exec_threads: 4,
            ..serverless_cfg()
        },
        engine_with_batch(8, 500),
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(auto.lambda_invocations, reference.lambda_invocations);
    assert_eq!(auto.val_curve.len(), reference.val_curve.len());
    for ((_, l1, a1), (_, l2, a2)) in reference.val_curve.iter().zip(&auto.val_curve) {
        assert!((l1 - l2).abs() < 1e-6, "reference {l1} vs auto {l2}");
        assert!((a1 - a2).abs() < 1e-6);
    }
    assert_eq!(auto.store_objects, 0);
}

/// Fusion composes with cross-epoch dispatch: overlapping generations
/// never fuse (keyed by params version), the validation curve still
/// matches staged, and the lagged sweep leaves the store clean.
#[test]
fn fusion_composes_with_cross_epoch_mode() {
    require_artifacts!();
    let staged = Cluster::with_engine(
        TrainConfig { offload_mode: OffloadMode::Staged, ..serverless_cfg() },
        common::engine(),
    )
    .unwrap()
    .run()
    .unwrap();
    let fused_cross = Cluster::with_engine(
        TrainConfig {
            offload_mode: OffloadMode::CrossEpoch,
            exec_batch: 4,
            exec_threads: 4,
            ..serverless_cfg()
        },
        engine_with_batch(4, 500),
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(staged.val_curve.len(), fused_cross.val_curve.len());
    for ((_, l1, a1), (_, l2, a2)) in staged.val_curve.iter().zip(&fused_cross.val_curve) {
        assert!((l1 - l2).abs() < 1e-6, "staged {l1} vs fused cross-epoch {l2}");
        assert!((a1 - a2).abs() < 1e-6);
    }
    assert_eq!(staged.lambda_invocations, fused_cross.lambda_invocations);
    assert_eq!(fused_cross.store_objects, 0);
}
