//! Shared helpers for integration tests (they execute real PJRT against
//! the AOT artifacts, so `make artifacts` must have run).

use std::sync::{Arc, OnceLock};

use p2pless::config::{Backend, TrainConfig};
use p2pless::coordinator::{Cluster, TrainReport};
use p2pless::runtime::Engine;

/// Artifacts dir resolved against the workspace root (tests run with
/// cwd = the crate dir `rust/`).
pub fn artifacts_dir() -> String {
    format!("{}/../artifacts", env!("CARGO_MANIFEST_DIR"))
}

/// One PJRT engine per test binary (client creation is expensive and
/// the CPU client is process-wide).
pub fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new().expect("PJRT CPU client")))
        .clone()
}

/// The canonical 2-peer serverless cluster the data-plane acceptance
/// suites (`wire_plane`, `fused_exec`, `shard_plane`) all start from:
/// mini_squeezenet on MNIST, full batches only (no remainder), sized so
/// every peer runs `epochs` complete epochs.
#[allow(dead_code)]
pub fn serverless_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs,
        lr: 0.05,
        train_samples: 2 * 16 * epochs, // full batches per peer, no remainder
        val_samples: 64,
        backend: Backend::Serverless,
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    }
}

/// Run one cluster on the shared per-binary engine.
#[allow(dead_code)]
pub fn run(cfg: TrainConfig) -> TrainReport {
    Cluster::with_engine(cfg, engine()).unwrap().run().unwrap()
}

/// The counters a plane that claims byte-identity must not perturb: the
/// whole store data-plane fingerprint plus the fold-visible broker
/// number.
#[allow(dead_code)]
pub const PINNED_COUNTERS: &[&str] = &[
    "store.puts",
    "store.gets",
    "store.bytes_in",
    "store.dedup_hits",
    "store.decode_hits",
    "store.decode_misses",
    "broker.stale_drops",
];

/// Bit-exact validation-curve equality — epoch ids, loss bits and
/// accuracy bits all identical. `ctx` names the configuration under
/// test in the failure message.
#[allow(dead_code)]
pub fn assert_val_curves_bit_identical(a: &TrainReport, b: &TrainReport, ctx: &str) {
    assert_eq!(a.val_curve.len(), b.val_curve.len(), "curve length diverged: {ctx}");
    for ((e1, l1, a1), (e2, l2, a2)) in a.val_curve.iter().zip(&b.val_curve) {
        assert_eq!(e1, e2, "epoch ids diverged: {ctx}");
        assert_eq!(l1.to_bits(), l2.to_bits(), "val loss bits diverged: {ctx}");
        assert_eq!(a1.to_bits(), a2.to_bits(), "val acc bits diverged: {ctx}");
    }
}

/// Every [`PINNED_COUNTERS`] entry identical between two runs.
#[allow(dead_code)]
pub fn assert_pinned_counters_eq(a: &TrainReport, b: &TrainReport, ctx: &str) {
    for name in PINNED_COUNTERS {
        assert_eq!(a.counter(name), b.counter(name), "counter {name} diverged: {ctx}");
    }
}

/// Skip (with a loud message) when artifacts are missing — keeps
/// `cargo test` usable before `make artifacts`, while CI runs the
/// full path.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        if !std::path::Path::new(&$crate::common::artifacts_dir())
            .join("manifest.json")
            .exists()
        {
            eprintln!("SKIP: artifacts/manifest.json missing; run `make artifacts`");
            return;
        }
    };
}
