//! Shared helpers for integration tests (they execute real PJRT against
//! the AOT artifacts, so `make artifacts` must have run).

use std::sync::{Arc, OnceLock};

use p2pless::runtime::Engine;

/// Artifacts dir resolved against the workspace root (tests run with
/// cwd = the crate dir `rust/`).
pub fn artifacts_dir() -> String {
    format!("{}/../artifacts", env!("CARGO_MANIFEST_DIR"))
}

/// One PJRT engine per test binary (client creation is expensive and
/// the CPU client is process-wide).
pub fn engine() -> Arc<Engine> {
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new().expect("PJRT CPU client")))
        .clone()
}

/// Skip (with a loud message) when artifacts are missing — keeps
/// `cargo test` usable before `make artifacts`, while CI runs the
/// full path.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        if !std::path::Path::new(&$crate::common::artifacts_dir())
            .join("manifest.json")
            .exists()
        {
            eprintln!("SKIP: artifacts/manifest.json missing; run `make artifacts`");
            return;
        }
    };
}
