//! Cross-validation: the rust QSGD codec vs the L1 Pallas QSGD kernel,
//! executed through PJRT on the very same inputs. The two
//! implementations must agree *exactly* on integer levels (both compute
//! floor(|v|/norm * s + u)) and to f32 rounding on the reconstruction.

mod common;

use p2pless::compress::QsgdCodec;
use p2pless::runtime::QsgdKernel;
use p2pless::util::Rng;

#[test]
fn rust_codec_matches_pallas_kernel_bit_for_bit() {
    require_artifacts!();
    let kernel = QsgdKernel::load(common::engine(), &common::artifacts_dir()).unwrap();
    let n = kernel.n();
    let s = kernel.s();
    let codec = QsgdCodec::new(s, 0);

    for seed in [1u64, 2, 3] {
        let mut rng = Rng::seed_from_u64(seed);
        let v: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-3.0, 3.0)).collect();
        let u: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();

        let (q_kernel, norm_kernel) = kernel.encode(&v, &u).unwrap();
        let (q_rust, norm_rust) = codec.quantize_with_noise(&v, &u);

        assert!(
            (norm_kernel - norm_rust).abs() <= 1e-3 * norm_rust.abs(),
            "norms: kernel {norm_kernel} vs rust {norm_rust}"
        );
        let mismatches = q_kernel
            .iter()
            .zip(&q_rust)
            .filter(|(a, b)| a != b)
            .count();
        // floor() at a boundary can differ by 1 ulp of the scaled input;
        // allow a vanishing fraction of off-by-one levels.
        assert!(
            mismatches <= n / 1000,
            "seed {seed}: {mismatches}/{n} level mismatches"
        );
    }
}

#[test]
fn kernel_decode_matches_rust_dequantize() {
    require_artifacts!();
    let kernel = QsgdKernel::load(common::engine(), &common::artifacts_dir()).unwrap();
    let n = kernel.n();
    let s = kernel.s();
    let codec = QsgdCodec::new(s, 0);

    let mut rng = Rng::seed_from_u64(11);
    let q: Vec<i32> = (0..n)
        .map(|_| (rng.gen_below(2 * s as usize + 1) as i32) - s as i32)
        .collect();
    let norm = 17.25f32;

    let from_kernel = kernel.decode(&q, norm).unwrap();
    let from_rust = codec.dequantize(&q, norm);
    for (a, b) in from_kernel.iter().zip(&from_rust) {
        assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn quantize_roundtrip_error_bound_through_kernel() {
    require_artifacts!();
    let kernel = QsgdKernel::load(common::engine(), &common::artifacts_dir()).unwrap();
    let n = kernel.n();
    let s = kernel.s() as f32;

    let mut rng = Rng::seed_from_u64(23);
    let v: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
    let (q, norm) = kernel.encode(&v, &u).unwrap();
    let vhat = kernel.decode(&q, norm).unwrap();
    let bound = norm / s + 1e-4;
    for (a, b) in v.iter().zip(&vhat) {
        assert!((a - b).abs() <= bound, "|{a} - {b}| > {bound}");
    }
}
