//! Cluster-level acceptance for the compressed serverless wire plane:
//!
//! - invariance: `--wire-compression none` (the default) is byte-for-byte
//!   the pre-compression data plane on every offload mode — validation
//!   curves, modeled lambda numbers and store counters all bit-identical,
//!   with every `wire.*` counter pinned at zero;
//! - lossy convergence: a `qsgd:16` gradient plane with delta-encoded
//!   params uploads still trains (finite, near-baseline val loss), moves
//!   strictly fewer bytes through the store, and never needs a chain
//!   resync under the normal lagged sweep.

mod common;

use common::{run, serverless_cfg};
use p2pless::config::{Compression, OffloadMode, TrainConfig};

/// Explicitly passing `--wire-compression none` must be byte-identical
/// to the default plane on every offload mode: same validation curve
/// bits, same modeled cost, same store counters — and the wire plane
/// itself reports all-zero counters (it never touched a byte).
#[test]
fn none_wire_plane_is_byte_identical_on_every_mode() {
    require_artifacts!();
    for mode in [OffloadMode::Staged, OffloadMode::Pipelined, OffloadMode::CrossEpoch] {
        let base = run(TrainConfig { offload_mode: mode, ..serverless_cfg(3) });
        let explicit = run(TrainConfig {
            offload_mode: mode,
            wire_compression: Compression::None,
            params_delta_every: 0,
            ..serverless_cfg(3)
        });
        common::assert_val_curves_bit_identical(&base, &explicit, &format!("{mode:?}"));
        assert_eq!(base.lambda_invocations, explicit.lambda_invocations);
        assert_eq!(
            base.lambda_cost_usd.to_bits(),
            explicit.lambda_cost_usd.to_bits(),
            "modeled cost diverged with an explicit none plane: {mode:?}"
        );
        common::assert_pinned_counters_eq(&base, &explicit, &format!("{mode:?}"));
        for rep in [&base, &explicit] {
            for c in
                ["wire.bytes_raw", "wire.bytes_wire", "wire.encode_us", "wire.decode_us",
                 "wire.delta_resyncs"]
            {
                assert_eq!(rep.counter(c), Some(0), "{c} nonzero on the none plane: {mode:?}");
            }
            assert_eq!(rep.store_objects, 0, "mode {mode:?} leaked store objects");
        }
    }
}

/// A lossy plane (`qsgd:16` gradients, delta params every 4 generations)
/// still converges near the uncompressed baseline while moving strictly
/// fewer bytes through the store — and the delta chain never breaks
/// under the normal lagged sweep.
#[test]
fn qsgd16_delta_plane_converges_and_shrinks_the_wire() {
    require_artifacts!();
    let baseline = run(serverless_cfg(3));
    let quant = run(TrainConfig {
        wire_compression: Compression::Qsgd { s: 16 },
        params_delta_every: 4,
        ..serverless_cfg(3)
    });
    let l_base = baseline.final_val_loss().unwrap();
    let l_quant = quant.final_val_loss().unwrap();
    assert!(l_base.is_finite() && l_quant.is_finite());
    // 6-bit-quantized gradients on a 3-epoch MNIST run: stay within a
    // generous but regression-catching band of the exact plane
    assert!(
        (l_quant - l_base).abs() <= 0.5 * l_base.max(0.2),
        "qsgd:16 val loss {l_quant} too far from baseline {l_base}"
    );
    let raw = quant.counter("wire.bytes_raw").unwrap();
    let wire = quant.counter("wire.bytes_wire").unwrap();
    assert!(raw > 0 && wire > 0, "compressed plane reported no traffic");
    assert!(
        wire * 2 < raw,
        "wire bytes {wire} not under half of raw {raw} at qsgd:16"
    );
    // the store moved fewer bytes than the uncompressed plane did
    let b_base = baseline.counter("store.bytes_in").unwrap();
    let b_quant = quant.counter("store.bytes_in").unwrap();
    assert!(
        b_quant < b_base,
        "store bytes_in did not shrink: {b_quant} vs baseline {b_base}"
    );
    // v(e-1) stays resident under the lagged sweep, so the delta chain
    // never needs an emergency full-object resync in a clean run
    assert_eq!(quant.counter("wire.delta_resyncs"), Some(0));
    assert_eq!(quant.store_objects, 0, "compressed plane leaked store objects");
}
