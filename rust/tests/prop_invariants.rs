//! Property-based tests over randomized inputs (in-tree harness — the
//! offline build has no proptest). Each property runs against a few
//! hundred random cases drawn from a seeded PRNG; failures print the
//! offending seed for reproduction.

use p2pless::broker::{Broker, FaultPlan, Message, QueueMode};
use p2pless::compress::{codec_for, Codec, QsgdCodec, RawCodec, TopkCodec};
use p2pless::config::Compression;
use p2pless::coordinator::GradientDict;
use p2pless::faas::schedule_wall;
use p2pless::harness::faults::{FaultKind, FaultPlanSpec};
use p2pless::store::shard::{
    hash_f32s, upload_sharded, ShardManifest, ShardPlane, ShardSpec, ShardState,
    SHARD_KIND_RAW,
};
use p2pless::store::{ObjectStore, PARAMS_BUCKET};
use p2pless::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use p2pless::util::{Bytes, Rng};
use std::time::Duration;

const CASES: u64 = 200;

fn rand_vec(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = rng.gen_below(max_len + 1);
    (0..n).map(|_| rng.gen_range_f32(-10.0, 10.0)).collect()
}

// ---------------------------------------------------------- codecs

#[test]
fn prop_all_codecs_preserve_dimension() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let v = rand_vec(&mut rng, 500);
        for compression in [
            Compression::None,
            Compression::Qsgd { s: 1 + (seed % 100) as u8 },
            Compression::Topk { frac: 0.01 + rng.gen_f32() * 0.99 },
        ] {
            let codec = codec_for(compression, seed);
            let out = codec
                .decode(&codec.encode(&v).unwrap())
                .unwrap_or_else(|e| panic!("seed {seed} {compression:?}: {e}"));
            assert_eq!(out.len(), v.len(), "seed {seed} {compression:?}");
        }
    }
}

#[test]
fn prop_raw_is_lossless() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0xaaaa);
        let v = rand_vec(&mut rng, 300);
        let c = RawCodec;
        assert_eq!(c.decode(&c.encode(&v).unwrap()).unwrap(), v, "seed {seed}");
    }
}

#[test]
fn prop_qsgd_error_bounded_by_norm_over_s() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0xbbbb);
        let v = rand_vec(&mut rng, 400);
        if v.is_empty() {
            continue;
        }
        let s = 1 + (seed % 64) as u8;
        let c = QsgdCodec::new(s, seed);
        let out = c.decode(&c.encode(&v).unwrap()).unwrap();
        let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let bound = norm / s as f64 + 1e-4;
        for (a, b) in v.iter().zip(&out) {
            assert!(
                ((a - b).abs() as f64) <= bound,
                "seed {seed} s {s}: |{a} - {b}| > {bound}"
            );
        }
    }
}

#[test]
fn prop_topk_keeps_only_original_values() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0xcccc);
        let v = rand_vec(&mut rng, 400);
        if v.is_empty() {
            continue;
        }
        let frac = 0.05 + rng.gen_f32() * 0.9;
        let c = TopkCodec::new(frac);
        let out = c.decode(&c.encode(&v).unwrap()).unwrap();
        let k = c.k_for(v.len());
        let nonzero = out.iter().filter(|&&x| x != 0.0).count();
        assert!(nonzero <= k, "seed {seed}: {nonzero} > k {k}");
        for (i, &x) in out.iter().enumerate() {
            assert!(x == 0.0 || x == v[i], "seed {seed} i {i}");
        }
        // the largest |value| always survives
        if let Some((imax, _)) = v
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        {
            if v[imax] != 0.0 {
                assert_eq!(out[imax], v[imax], "seed {seed}: max dropped");
            }
        }
    }
}

#[test]
fn prop_qsgd_wire_never_larger_than_raw_plus_header() {
    for seed in 0..100 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xdddd);
        let v = rand_vec(&mut rng, 1000);
        let c = QsgdCodec::new(127, seed); // worst case: 8 bits/elem
        let wire = c.encode(&v).unwrap();
        assert!(wire.len() <= 10 + v.len() + 8, "seed {seed}");
    }
}

// ---------------------------------------------------------- shard codec

/// A random on-plane shard spec for `total` elements: either an N-way
/// cut or a `layer` cut along randomly drawn layer sizes (returned so
/// the plane can be built).
fn rand_spec(rng: &mut Rng, total: usize) -> (ShardSpec, Vec<usize>) {
    if rng.gen_below(2) == 0 {
        (ShardSpec::Count(1 + rng.gen_below(total)), Vec::new())
    } else {
        let mut sizes = Vec::new();
        let mut left = total;
        while left > 0 {
            let s = 1 + rng.gen_below(left);
            sizes.push(s);
            left -= s;
        }
        (ShardSpec::Layer, sizes)
    }
}

/// Raw-f32 encode closure (what the offload uses with the wire plane
/// off): put each slice as plain bytes.
fn raw_put(
    store: &ObjectStore,
    generation: u64,
) -> impl FnMut(usize, &[f32]) -> p2pless::Result<(p2pless::store::ObjectRef, Vec<f32>)> + '_ {
    move |_, slice| {
        let r = store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), generation)?;
        Ok((r, slice.to_vec()))
    }
}

/// Split → upload → reassemble is bit-lossless for arbitrary layouts,
/// and the manifest survives a wire roundtrip unchanged.
#[test]
fn prop_shard_split_reassemble_roundtrips_any_layout() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5a5a);
        let total = 1 + rng.gen_below(400);
        let v: Vec<f32> = (0..total).map(|_| rng.gen_range_f32(-10.0, 10.0)).collect();
        let (spec, sizes) = rand_spec(&mut rng, total);
        let plane = ShardPlane::new(spec, total, &sizes)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let state = ShardState::new(plane.shard_count());
        let store = ObjectStore::new();
        let up = upload_sharded(
            &plane, &state, &store, PARAMS_BUCKET, &v, 1, SHARD_KIND_RAW,
            raw_put(&store, 1),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let wire = store.get_ref(&up.manifest).unwrap();
        let m = ShardManifest::from_wire(&wire).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(m.to_wire(), wire.to_vec(), "seed {seed}: wire roundtrip not stable");
        assert_eq!(m.total_elems, total, "seed {seed}");
        let mut back = Vec::with_capacity(total);
        for e in &m.shards {
            back.extend_from_slice(&bytes_to_f32s(&store.get_ref(&e.object).unwrap()));
        }
        let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back), bits(&v), "seed {seed}: reassembly diverged");
    }
}

/// The shard content hash is stable (same bits → same hash) and
/// sensitive to any single-element bit change (FNV-1a folds every byte
/// through an injective step, so one changed byte always moves it).
#[test]
fn prop_shard_hash_stable_and_input_sensitive() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x6b6b);
        let n = 1 + rng.gen_below(300);
        let v: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-10.0, 10.0)).collect();
        let h = hash_f32s(&v);
        assert_eq!(h, hash_f32s(&v.clone()), "seed {seed}: hash not deterministic");
        let mut w = v.clone();
        let i = rng.gen_below(n);
        w[i] = f32::from_bits(w[i].to_bits() ^ 1);
        assert_ne!(h, hash_f32s(&w), "seed {seed}: single-bit change not detected at {i}");
    }
}

/// Every strict prefix of a valid `SPv1` manifest is rejected with an
/// actionable error (never a panic), as are unknown versions, trailing
/// bytes, and arbitrary single-byte corruption (which must either parse
/// or error — structured rejection, no crashes).
#[test]
fn prop_shard_manifest_rejects_malformed_wire_bytes() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x7c7c);
        let total = 1 + rng.gen_below(120);
        let v: Vec<f32> = (0..total).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
        let (spec, sizes) = rand_spec(&mut rng, total);
        let plane = ShardPlane::new(spec, total, &sizes).unwrap();
        let state = ShardState::new(plane.shard_count());
        let store = ObjectStore::new();
        let up = upload_sharded(
            &plane, &state, &store, PARAMS_BUCKET, &v, 1, SHARD_KIND_RAW,
            raw_put(&store, 1),
        )
        .unwrap();
        let wire = store.get_ref(&up.manifest).unwrap().to_vec();

        // one random strict prefix per case (the unit suite walks all)
        let cut = rng.gen_below(wire.len());
        let err = ShardManifest::from_wire(&wire[..cut]).unwrap_err().to_string();
        assert!(
            err.contains("SPv1") || err.contains("shard manifest"),
            "seed {seed} cut {cut}: unhelpful error {err:?}"
        );

        // trailing garbage is rejected
        let mut long = wire.clone();
        long.push(rng.next_u64() as u8);
        assert!(ShardManifest::from_wire(&long).is_err(), "seed {seed}: trailing byte");

        // unknown version byte is rejected
        let mut vers = wire.clone();
        vers[3] = vers[3].wrapping_add(1 + (rng.gen_below(200) as u8));
        assert!(ShardManifest::from_wire(&vers).is_err(), "seed {seed}: version");

        // arbitrary single-byte corruption: Ok or Err, never a panic
        let mut mutated = wire.clone();
        let i = rng.gen_below(mutated.len());
        mutated[i] ^= 1 << rng.gen_below(8);
        let _ = ShardManifest::from_wire(&mutated);
    }
}

// ---------------------------------------------------------- averaging

#[test]
fn prop_average_is_permutation_invariant_and_bounded() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0xeeee);
        let n = 1 + rng.gen_below(50);
        let peers = 1 + rng.gen_below(8);
        let mut dict_fwd = GradientDict::new();
        let mut dict_rev = GradientDict::new();
        let mut grads = Vec::new();
        for p in 0..peers {
            let g: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-5.0, 5.0)).collect();
            grads.push((p, g));
        }
        for (p, g) in &grads {
            dict_fwd.insert(*p, g.clone());
        }
        for (p, g) in grads.iter().rev() {
            dict_rev.insert(*p, g.clone());
        }
        let a = dict_fwd.average().unwrap();
        let b = dict_rev.average().unwrap();
        assert_eq!(a, b, "seed {seed}: average depends on insertion order");
        // average within [min, max] elementwise
        for i in 0..n {
            let lo = grads.iter().map(|(_, g)| g[i]).fold(f32::INFINITY, f32::min);
            let hi = grads.iter().map(|(_, g)| g[i]).fold(f32::NEG_INFINITY, f32::max);
            assert!(a[i] >= lo - 1e-4 && a[i] <= hi + 1e-4, "seed {seed} i {i}");
        }
    }
}

// ---------------------------------------------------------- broker

#[test]
fn prop_latest_only_queue_holds_last_accepted() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x1111);
        let broker = Broker::default();
        let q = broker.declare("g", QueueMode::LatestOnly).unwrap();
        let n = 1 + rng.gen_below(20);
        let mut last = None;
        for i in 0..n {
            let payload: Vec<u8> = (0..rng.gen_below(64)).map(|_| rng.next_u64() as u8).collect();
            q.publish(Message::new(0, i as u64, Bytes::from(payload.clone())))
                .unwrap();
            last = Some(payload);
        }
        let got = q.peek_latest().unwrap();
        assert_eq!(got.payload.to_vec(), last.unwrap(), "seed {seed}");
        assert_eq!(q.len(), 1);
    }
}

#[test]
fn prop_fifo_version_equals_accepted_publishes() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x2222);
        let drop_every = rng.gen_below(5) as u64; // 0 = no drops
        let broker = Broker::new(1024, FaultPlan { drop_every, delay_us: 0 });
        let q = broker.declare("sync", QueueMode::Fifo).unwrap();
        let n = rng.gen_below(40) as u64;
        for i in 0..n {
            q.publish(Message::new(0, i, Bytes::from_static(b"x"))).unwrap();
        }
        let dropped = if drop_every > 0 { n / drop_every } else { 0 };
        assert_eq!(q.version(), n - dropped, "seed {seed}");
        assert_eq!(q.len() as u64, n - dropped, "seed {seed}");
    }
}

// ---------------------------------------------------------- fault plans

/// Random valid spec entries covering every fault kind. Join ranks are
/// drawn so the admission sequence is well-formed (distinct revival
/// ranks in `1..peers`, growth ranks contiguous from `peers` with
/// non-decreasing epochs).
fn rand_fault_entries(rng: &mut Rng, peers: usize, epochs: usize) -> Vec<String> {
    let mut entries = Vec::new();
    for _ in 0..rng.gen_below(8) {
        let p = rng.gen_below(peers);
        let e = 1 + rng.gen_below(epochs);
        let ms = rng.gen_below(3);
        entries.push(match rng.gen_below(9) {
            0 => format!("kill:peer{p}@{e}"),
            1 => format!("delay:peer{p}@{e}:{ms}ms"),
            2 => format!("dup:peer{p}.branch{}@{e}", rng.gen_below(4)),
            3 => format!("storeput:peer{p}@{e}"),
            4 => format!("storeget:peer{p}@{e}"),
            5 => format!("storecorrupt:peer{p}@{e}"),
            6 => format!("storedelay:peer{p}@{e}:{ms}ms"),
            7 => format!("brokerdrop:peer{p}@{e}"),
            _ => format!("brokerdelay:peer{p}@{e}:{ms}ms"),
        });
    }
    for r in 1..peers {
        if rng.gen_below(3) == 0 {
            entries.push(format!("join:peer{r}@{}", 2 + rng.gen_below(epochs - 1)));
        }
    }
    let growth = rng.gen_below(3);
    let mut growth_epochs: Vec<usize> =
        (0..growth).map(|_| 2 + rng.gen_below(epochs - 1)).collect();
    growth_epochs.sort_unstable();
    for (i, e) in growth_epochs.into_iter().enumerate() {
        entries.push(format!("join:peer{}@{e}", peers + i));
    }
    entries
}

/// parse → resolve → to_spec → parse → resolve is a fixpoint: the
/// canonical rendering of any resolved plan resolves back to the same
/// sorted, deduplicated event list, for every fault kind including the
/// elastic-join and store/broker chaos kinds.
#[test]
fn prop_fault_plan_spec_roundtrips_through_canonical_rendering() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x4f4f);
        let peers = 2 + rng.gen_below(5);
        let epochs = 2 + rng.gen_below(6);
        let spec = rand_fault_entries(&mut rng, peers, epochs).join(";");
        let plan = FaultPlanSpec::parse(&spec)
            .unwrap_or_else(|e| panic!("seed {seed} parse {spec:?}: {e}"))
            .resolve(peers, epochs)
            .unwrap_or_else(|e| panic!("seed {seed} resolve {spec:?}: {e}"));
        // resolved events are sorted and deduplicated
        for w in plan.events().windows(2) {
            assert!(w[0] < w[1], "seed {seed}: events not strictly ascending");
        }
        let rendered = plan.to_spec();
        let back = FaultPlanSpec::parse(&rendered)
            .unwrap_or_else(|e| panic!("seed {seed} reparse {rendered:?}: {e}"))
            .resolve(peers, epochs)
            .unwrap_or_else(|e| panic!("seed {seed} re-resolve {rendered:?}: {e}"));
        assert_eq!(back.events(), plan.events(), "seed {seed}: roundtrip diverged");
        assert_eq!(back.to_spec(), rendered, "seed {seed}: rendering not a fixpoint");
    }
}

/// Seeded rate clauses resolve deterministically (same spec + shape →
/// identical event list), produce only in-bounds events, and their
/// expansion survives the canonical-rendering roundtrip as a plain
/// explicit plan.
#[test]
fn prop_fault_plan_rate_resolution_deterministic_and_in_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5e5e);
        let peers = 2 + rng.gen_below(6);
        let epochs = 2 + rng.gen_below(6);
        let kill = rng.gen_below(100) as f64 / 100.0;
        let join = rng.gen_below(100) as f64 / 100.0;
        let store = rng.gen_below(100) as f64 / 100.0;
        let spec = format!(
            "rate:kill={kill},join={join},store={store},seed={}",
            rng.next_u64() % 1000
        );
        let parsed = FaultPlanSpec::parse(&spec).unwrap();
        let a = parsed.resolve(peers, epochs).unwrap();
        let b = parsed.resolve(peers, epochs).unwrap();
        assert_eq!(a.events(), b.events(), "seed {seed}: rate resolution not deterministic");
        let joins = a.events().iter().filter(|e| e.kind == FaultKind::Join).count();
        assert_eq!(
            joins,
            (join * peers as f64).floor() as usize,
            "seed {seed}: join count off"
        );
        for ev in a.events() {
            assert!(ev.epoch >= 1 && ev.epoch <= epochs as u64, "seed {seed}: {ev}");
            if ev.kind == FaultKind::Join {
                assert!(ev.epoch >= 2, "seed {seed}: join in epoch 1: {ev}");
            } else {
                assert!(ev.peer < peers, "seed {seed}: out-of-cluster target {ev}");
            }
        }
        // the expansion is expressible as an explicit plan
        let back = FaultPlanSpec::parse(&a.to_spec()).unwrap().resolve(peers, epochs).unwrap();
        assert_eq!(back.events(), a.events(), "seed {seed}: expansion not re-resolvable");
    }
}

/// Malformed specs are structured `Err`s, never panics — both at parse
/// time (bad grammar) and at resolve time (out-of-shape targets,
/// ill-ordered joins).
#[test]
fn prop_malformed_fault_specs_error_never_panic() {
    for bad in [
        "join:banana",
        "join:peer1",
        "join:peer1.branch0@2",
        "kill:rank1@2",
        "kill:peer1",
        "kill:peer1.branch0@1",
        "dup:peer1@1",
        "delay:peer0@1",
        "storedelay:peer1@2",
        "storeput:peer1.branch0@1",
        "brokerdrop:peer1.branch0@1",
        "brokerdelay:peer1@2:xms",
        "frobnicate:peer0@1",
        "rate:seed=3",
        "rate:kill=1.5",
        "rate:kill=banana",
        "rate:churn=0.5",
        "storeput",
        ":@",
    ] {
        assert!(FaultPlanSpec::parse(bad).is_err(), "{bad:?} parsed");
    }
    // grammatically fine, rejected against the cluster shape (2 peers,
    // 4 epochs)
    for bad in [
        "kill:peer9@1",
        "kill:peer1@0",
        "kill:peer1@9",
        "join:peer0@2",
        "join:peer1@1",
        "join:peer1@9",
        "join:peer5@2",
        "join:peer1@2;join:peer1@3",
    ] {
        let spec = FaultPlanSpec::parse(bad).unwrap_or_else(|e| panic!("{bad:?}: {e}"));
        assert!(spec.resolve(2, 4).is_err(), "{bad:?} resolved");
    }
    // fuzz: arbitrary strings over the grammar's alphabet parse to Ok
    // or Err, never a crash
    const ALPHABET: &[u8] = b"kiljondupstrebcamy:@.;=0123456789, ";
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x6d6d);
        let n = rng.gen_below(40);
        let s: String =
            (0..n).map(|_| ALPHABET[rng.gen_below(ALPHABET.len())] as char).collect();
        if let Ok(spec) = FaultPlanSpec::parse(&s) {
            let _ = spec.resolve(2, 4);
        }
    }
}

// ---------------------------------------------------------- scheduler

#[test]
fn prop_schedule_wall_bounds() {
    // max(d) <= wall <= sum(d); monotone non-increasing in concurrency
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed ^ 0x3333);
        let n = 1 + rng.gen_below(30);
        let d: Vec<Duration> = (0..n)
            .map(|_| Duration::from_millis(1 + rng.gen_below(1000) as u64))
            .collect();
        let sum: Duration = d.iter().sum();
        let max = *d.iter().max().unwrap();
        let mut prev = None;
        for c in [1usize, 2, 4, 8, 64] {
            let w = schedule_wall(&d, c);
            assert!(w >= max, "seed {seed} c {c}: wall below max");
            assert!(w <= sum, "seed {seed} c {c}: wall above sum");
            if let Some(p) = prev {
                assert!(w <= p, "seed {seed}: wall increased with concurrency");
            }
            prev = Some(w);
        }
        assert_eq!(schedule_wall(&d, 1), sum, "seed {seed}: serial != sum");
    }
}
