//! Cluster-level acceptance for the sharded params manifest (PR 9):
//!
//! - cross-plane invariance: `--params-sharding 4` trains bit-identically
//!   to the monolithic plane — validation curves, final packed params
//!   (FNV fingerprints), modeled lambda invocations/billed cost, and
//!   broker traffic — across offload modes staged/pipelined/cross-epoch
//!   × `--wire-compression none|qsgd:16` × `--exec-threads` 1/2/8;
//! - exact-counter acceptance (no artifacts needed): a steady-state
//!   generation touching k of L shards puts exactly k shard objects
//!   + 1 manifest, and reused entries resolve to the prior generation's
//!   live objects;
//! - decode economy: each changed shard is decoded exactly once
//!   cluster-wide per generation (`store.decode_misses` grows by exactly
//!   the shard count over the monolithic plane);
//! - cache interactions: a decode cache far smaller than the live shard
//!   set still trains bit-identically under cross-epoch pipelining,
//!   because live generations' pinned shards are admitted over capacity
//!   instead of being evicted;
//! - `layer` mode rides the AOT manifest's `params_spec` when the
//!   artifacts carry one (skips loudly otherwise).

mod common;

use common::{run, serverless_cfg};
use p2pless::config::{Compression, OffloadMode, TrainConfig};
use p2pless::coordinator::TrainReport;
use p2pless::runtime::Manifest;
use p2pless::store::shard::{
    hash_f32s, upload_sharded, ShardManifest, ShardPlane, ShardSpec, ShardState,
    SHARD_KIND_RAW,
};
use p2pless::store::{ObjectStore, PARAMS_BUCKET};
use p2pless::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use p2pless::util::Bytes;

const SHARDS: usize = 4;

fn sharded(cfg: TrainConfig) -> TrainConfig {
    TrainConfig { params_sharding: SHARDS.to_string(), ..cfg }
}

/// Everything that must not move when the only change is how the params
/// object is cut up: the math, the fold, the modeled bill, the broker.
fn assert_cross_plane_invariant(mono: &TrainReport, shard: &TrainReport, ctx: &str) {
    common::assert_val_curves_bit_identical(mono, shard, ctx);
    assert_eq!(mono.peers.len(), shard.peers.len(), "{ctx}");
    for (a, b) in mono.peers.iter().zip(&shard.peers) {
        assert_ne!(a.params_fnv, 0, "peer {} reported no params fingerprint: {ctx}", a.rank);
        assert_eq!(
            a.params_fnv, b.params_fnv,
            "peer {} final params bits diverged under sharding: {ctx}",
            a.rank
        );
    }
    assert_eq!(mono.lambda_invocations, shard.lambda_invocations, "{ctx}");
    assert_eq!(
        mono.lambda_cost_usd.to_bits(),
        shard.lambda_cost_usd.to_bits(),
        "modeled billed cost diverged under sharding: {ctx}"
    );
    assert_eq!(mono.broker_msgs, shard.broker_msgs, "{ctx}");
    assert_eq!(mono.broker_bytes, shard.broker_bytes, "{ctx}");
    assert_eq!(
        mono.counter("broker.stale_drops"),
        shard.counter("broker.stale_drops"),
        "{ctx}"
    );
    for rep in [mono, shard] {
        assert_eq!(rep.store_objects, 0, "leaked store objects: {ctx}");
    }
    // the shard counters themselves: silent on the monolithic plane,
    // fully accounted on the sharded one
    for c in ["shard.total", "shard.changed", "shard.reused", "shard.bytes_saved"] {
        assert_eq!(mono.counter(c), Some(0), "{c} nonzero on the monolithic plane: {ctx}");
    }
    let total = shard.counter("shard.total").unwrap();
    assert!(total > 0, "sharded run reported no shard uploads: {ctx}");
    assert_eq!(
        shard.counter("shard.changed").unwrap() + shard.counter("shard.reused").unwrap(),
        total,
        "changed + reused must account for every shard upload: {ctx}"
    );
}

/// The headline invariance matrix: sharding is a pure data-plane
/// re-encoding at every offload mode × wire plane × thread count.
#[test]
fn sharded_plane_is_bit_identical_to_monolithic_everywhere() {
    require_artifacts!();
    for mode in [OffloadMode::Staged, OffloadMode::Pipelined, OffloadMode::CrossEpoch] {
        for compression in [Compression::None, Compression::Qsgd { s: 16 }] {
            for threads in [1usize, 2, 8] {
                let cfg = TrainConfig {
                    offload_mode: mode,
                    wire_compression: compression,
                    exec_threads: threads,
                    ..serverless_cfg(2)
                };
                let mono = run(cfg.clone());
                let shard = run(sharded(cfg));
                let ctx = format!("{mode:?} × {compression:?} × threads {threads}");
                assert_cross_plane_invariant(&mono, &shard, &ctx);
            }
        }
    }
}

/// Each changed shard is decoded exactly once cluster-wide: relative to
/// the monolithic plane (one params decode per generation), a sharded
/// generation adds exactly `SHARDS` decode misses — the manifest
/// assembly replaces the monolithic miss, and each shard misses once no
/// matter how many branches resolve the same generation.
#[test]
fn changed_shards_decode_exactly_once_cluster_wide() {
    require_artifacts!();
    let epochs = 2usize;
    let cfg = TrainConfig { exec_threads: 8, ..serverless_cfg(epochs) };
    let mono = run(cfg.clone());
    let shard = run(sharded(cfg));
    assert_cross_plane_invariant(&mono, &shard, "staged × none × threads 8");
    // per peer per epoch one upload of SHARDS shards; real training
    // perturbs every layer every epoch, so nothing is reusable here
    assert_eq!(
        shard.counter("shard.total"),
        Some((2 * epochs * SHARDS) as u64),
        "2 peers × {epochs} epochs × {SHARDS} shards"
    );
    let mono_misses = mono.counter("store.decode_misses").unwrap();
    let shard_misses = shard.counter("store.decode_misses").unwrap();
    assert_eq!(
        shard_misses - mono_misses,
        (epochs * SHARDS) as u64,
        "a sharded generation must cost exactly {SHARDS} extra decode misses \
         (manifest + {SHARDS} shards, vs one monolithic object)"
    );
}

/// A decode cache far smaller than one generation's live shard set
/// (capacity 2 vs manifest + 4 shards, × pipeline depth) still trains
/// bit-identically under cross-epoch dispatch: pinned live generations
/// are admitted over capacity, never evicted mid-flight.
#[test]
fn tiny_decode_cache_survives_cross_epoch_sharding() {
    require_artifacts!();
    let cfg = TrainConfig {
        offload_mode: OffloadMode::CrossEpoch,
        exec_threads: 4,
        decode_cache: 2,
        ..serverless_cfg(3)
    };
    let mono = run(cfg.clone());
    let shard = run(sharded(cfg));
    assert_cross_plane_invariant(&mono, &shard, "cross-epoch × tiny cache");
}

/// `--params-sharding layer` cuts along the AOT manifest's
/// `params_spec` and stays bit-identical to the monolithic plane.
/// Older artifacts (no `params_spec`) skip loudly — `N`-way mode and
/// the unit suite cover the codec either way.
#[test]
fn layer_mode_matches_monolithic_when_artifacts_carry_a_params_spec() {
    require_artifacts!();
    let man = Manifest::load(common::artifacts_dir()).unwrap();
    let has_spec = man
        .models
        .get("mini_squeezenet_mnist")
        .is_some_and(|e| !e.params_spec.is_empty());
    if !has_spec {
        eprintln!(
            "SKIP layer_mode_matches_monolithic_when_artifacts_carry_a_params_spec: \
             artifacts manifest has no params_spec (re-run aot.py)"
        );
        return;
    }
    let mono = run(serverless_cfg(2));
    let layered = run(TrainConfig {
        params_sharding: "layer".into(),
        ..serverless_cfg(2)
    });
    assert_cross_plane_invariant(&mono, &layered, "layer mode");
}

// ------------------------------------------------- store-level acceptance
// (no PJRT, no artifacts: the ISSUE's exact-counter bar, driven through
// the public shard API exactly as `ServerlessOffload` drives it)

fn raw_put(store: &ObjectStore, generation: u64) -> impl FnMut(usize, &[f32]) -> p2pless::Result<(p2pless::store::ObjectRef, Vec<f32>)> + '_ {
    move |_, slice| {
        let r = store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), generation)?;
        Ok((r, slice.to_vec()))
    }
}

/// A steady-state generation that touches k of L shards puts exactly k
/// shard objects + 1 manifest; the other L−k manifest entries resolve
/// to the prior generation's still-live objects, bit-identically.
#[test]
fn k_of_l_generation_puts_exactly_k_shards_plus_one_manifest() {
    let store = ObjectStore::new();
    let total = 60usize;
    let l = 5usize;
    let plane =
        ShardPlane::new(ShardSpec::Count(l), total, &[]).unwrap();
    let state = ShardState::new(plane.shard_count());
    let mut params: Vec<f32> = (0..total).map(|i| i as f32 * 0.25).collect();

    let up1 = upload_sharded(
        &plane, &state, &store, PARAMS_BUCKET, &params, 1, SHARD_KIND_RAW,
        raw_put(&store, 1),
    )
    .unwrap();
    let first_puts = store.stats().0;
    assert_eq!(first_puts, (l + 1) as u64, "first generation: L shards + manifest");

    // generation 2 touches k = 2 of the 5 shards
    let k = 2usize;
    params[0] += 1.0; // shard 0
    params[30] += 1.0; // shard 2
    let up2 = upload_sharded(
        &plane, &state, &store, PARAMS_BUCKET, &params, 2, SHARD_KIND_RAW,
        raw_put(&store, 2),
    )
    .unwrap();
    assert_eq!(
        store.stats().0 - first_puts,
        (k + 1) as u64,
        "k-of-L generation: exactly k shard puts + 1 manifest"
    );
    assert_eq!(plane.total(), (2 * l) as u64);
    assert_eq!(plane.changed(), (l + k) as u64);
    assert_eq!(plane.reused(), (l - k) as u64);

    // reused entries are the prior generation's objects, and decoding
    // through the new manifest reproduces the new params bit-exactly
    let m2 = ShardManifest::from_wire(&store.get_ref(&up2.manifest).unwrap()).unwrap();
    assert_eq!(m2.total_elems, total);
    for (i, e) in m2.shards.iter().enumerate() {
        if up2.reused[i] {
            assert_eq!(e.generation, 1, "shard {i}");
            assert_eq!(e.object, up1.shards[i], "shard {i}");
        } else {
            assert_eq!(e.generation, 2, "shard {i}");
        }
    }
    let mut back = Vec::with_capacity(total);
    for e in &m2.shards {
        back.extend_from_slice(&bytes_to_f32s(&store.get_ref(&e.object).unwrap()));
    }
    assert_eq!(hash_f32s(&back), hash_f32s(&params), "reassembly diverged");
    assert_eq!(
        back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        params.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
    );

    // lifecycle: generation 1's holder releases; generation 2's
    // retained refs keep the reused shards alive, then release clean
    for r in up1.shards.iter().chain([&up1.manifest]) {
        store.release(r);
    }
    for e in &m2.shards {
        assert!(store.get_ref(&e.object).is_ok(), "reused shard died with gen 1");
    }
    for r in up2.shards.iter().chain([&up2.manifest]) {
        store.release(r);
    }
    assert_eq!(store.total_objects(), 0, "lifecycle leaked objects");
}
