//! Cross-epoch pipelining, end to end:
//!
//! - modeled determinism: a multi-epoch cross-epoch dispatch sequence
//!   produces wall/billed/cost/cold-start numbers byte-identical to the
//!   staged `StateMachine` reference at pipeline depths 1/2 and thread
//!   counts 1/2/8 (the acceptance bar for paper tables);
//! - generation-keyed folds: the per-epoch f64 gradient folds are
//!   bit-identical to a sequential reference no matter the mode, depth
//!   or pool size — overlapping epochs never mix param versions;
//! - boundary overlap: with a simulated inter-epoch coordination gap,
//!   the cross-epoch dispatch order beats the pipelined order on
//!   measured wall (the pool keeps executing across the boundary);
//! - cluster acceptance (real PJRT, artifact-gated): cross-epoch runs
//!   match staged validation curves, pre-dispatch counters fire, the
//!   sweep lag keeps the store bounded and empty at teardown.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use p2pless::config::{Backend, OffloadMode, TrainConfig};
use p2pless::coordinator::{Cluster, GradAccumulator};
use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy, StateMachine,
};
use p2pless::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use p2pless::util::Bytes;

const GRAD_DIM: usize = 16;

/// Deterministic pseudo-gradient for (generation, branch index): what a
/// gradient Lambda would compute from params v(gen) on batch idx.
fn pseudo_grad(generation: u64, idx: usize) -> Vec<f32> {
    (0..GRAD_DIM)
        .map(|k| {
            let x = generation.wrapping_mul(31) + (idx as u64) * 7 + k as u64;
            (x as f32) * 0.001953125 - 0.5
        })
        .collect()
}

/// Branch payload: `[u64 generation][u32 idx]`, little endian.
fn grad_payload(generation: u64, idx: usize) -> Bytes {
    let mut out = Vec::with_capacity(12);
    out.extend_from_slice(&generation.to_le_bytes());
    out.extend_from_slice(&(idx as u32).to_le_bytes());
    Bytes::from(out)
}

/// Handler computing [`pseudo_grad`] from the payload tags.
fn grad_handler() -> Handler {
    Arc::new(|b: &Bytes| {
        assert_eq!(b.len(), 12, "payload is [gen u64][idx u32]");
        let generation = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let idx = u32::from_le_bytes(b[8..12].try_into().unwrap()) as usize;
        Ok(Bytes::from(f32s_to_bytes(&pseudo_grad(generation, idx))))
    })
}

fn platform(cold_ms: u64, handler: Handler) -> Arc<FaasPlatform> {
    let p = Arc::new(FaasPlatform::new(Duration::from_millis(cold_ms)));
    p.register(FunctionSpec::new("grad", 1024, handler)).unwrap();
    p
}

/// Per-epoch modeled branch durations (vary by epoch and branch so an
/// aggregation mix-up cannot cancel out).
fn modeled(epoch: usize, n: usize) -> Vec<Option<Duration>> {
    (0..n)
        .map(|i| Some(Duration::from_millis(700 + 13 * epoch as u64 + 7 * i as u64)))
        .collect()
}

type Modeled = (Duration, Duration, u64, usize, usize);
/// One epoch's outcome: modeled fingerprint + folded mean bit pattern.
type EpochOutcome = (Modeled, Vec<u32>);

fn fingerprint(r: &p2pless::faas::ExecutionReport) -> Modeled {
    (r.wall, r.billed, r.cost_usd.to_bits(), r.invocations, r.cold_starts)
}

/// The staged reference: one fresh platform, `epochs` sequential Map
/// states. Returns per-epoch modeled fingerprints + per-epoch folded
/// mean bit patterns.
fn staged_reference(epochs: usize, n: usize, concurrency: usize) -> Vec<EpochOutcome> {
    let p = platform(2500, grad_handler());
    let pool = Executor::new(1);
    let mut out = Vec::new();
    for epoch in 1..=epochs {
        let generation = epoch as u64;
        let items: Vec<Bytes> = (0..n).map(|i| grad_payload(generation, i)).collect();
        let sm = StateMachine::parallel_batches(
            "ref",
            "grad",
            items,
            modeled(epoch, n),
            concurrency,
        );
        let r = sm.execute_with(&p, &pool).unwrap();
        let mut acc = GradAccumulator::new();
        for branch in &r.outputs[0] {
            acc.add(&bytes_to_f32s(branch)).unwrap();
        }
        let mean: Vec<u32> = acc.mean().unwrap().iter().map(|v| v.to_bits()).collect();
        out.push((fingerprint(&r), mean));
    }
    out
}

/// The cross-epoch shape: keep up to `depth` epochs in flight, always
/// dispatching epoch e+1 after collecting epoch e (the synchronous
/// peer's order), with an optional coordination gap after dispatch.
fn cross_epoch_run(
    epochs: usize,
    n: usize,
    concurrency: usize,
    threads: usize,
    depth: usize,
    coord: Duration,
) -> Vec<EpochOutcome> {
    let p = platform(2500, grad_handler());
    let sched = BranchScheduler::new(Arc::new(Executor::new(threads)), true);
    let dispatch = |epoch: usize| {
        let generation = epoch as u64;
        let mut pipe = PipelinedMap::new(
            sched.clone(),
            p.clone(),
            0,
            "grad",
            n,
            concurrency,
            RetryPolicy::default(),
        )
        .unwrap()
        .with_generation(generation);
        for (i, m) in modeled(epoch, n).into_iter().enumerate() {
            pipe.submit(grad_payload(generation, i), m);
        }
        pipe
    };
    let collect = |mut pipe: PipelinedMap| {
        let mut acc = GradAccumulator::new();
        while let Some((_, branch)) = pipe.next_output() {
            acc.add(&bytes_to_f32s(&branch)).unwrap();
        }
        let r = pipe.finish().unwrap();
        let mean: Vec<u32> = acc.mean().unwrap().iter().map(|v| v.to_bits()).collect();
        (fingerprint(&r), mean)
    };
    let mut out = Vec::new();
    if depth >= 2 {
        // the synchronous peer's order: collect(e) → dispatch(e+1) →
        // coordination gap (eval/barrier) overlapping e+1's execution
        let mut pending = Some(dispatch(1));
        for epoch in 1..=epochs {
            if !coord.is_zero() {
                std::thread::sleep(coord);
            }
            out.push(collect(pending.take().unwrap()));
            if epoch < epochs {
                pending = Some(dispatch(epoch + 1));
            }
        }
    } else {
        for epoch in 1..=epochs {
            let pipe = dispatch(epoch);
            if !coord.is_zero() {
                std::thread::sleep(coord);
            }
            out.push(collect(pipe));
        }
    }
    out
}

/// Acceptance bar: modeled fingerprints and folded gradient bits from
/// the cross-epoch dispatch order equal the staged reference at any
/// depth/thread combination.
#[test]
fn cross_epoch_modeled_outputs_and_folds_match_staged() {
    let (epochs, n, concurrency) = (3usize, 8usize, 4usize);
    let reference = staged_reference(epochs, n, concurrency);
    for depth in [1usize, 2] {
        for threads in [1usize, 2, 8] {
            let got = cross_epoch_run(epochs, n, concurrency, threads, depth, Duration::ZERO);
            assert_eq!(got.len(), epochs);
            for (e, (got_ep, want_ep)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    got_ep.0,
                    want_ep.0,
                    "modeled fingerprint diverged: depth {depth}, threads {threads}, \
                     epoch {}",
                    e + 1
                );
                assert_eq!(
                    got_ep.1,
                    want_ep.1,
                    "gradient fold bits diverged: depth {depth}, threads {threads}, \
                     epoch {}",
                    e + 1
                );
            }
        }
    }
}

/// The folds stay generation-pure even when a coordination gap lets the
/// pre-dispatched epoch race ahead on the pool while nothing collects.
#[test]
fn generation_keyed_folds_survive_boundary_overlap() {
    let (epochs, n, concurrency) = (4usize, 6usize, 8usize);
    let reference = staged_reference(epochs, n, concurrency);
    let got = cross_epoch_run(
        epochs,
        n,
        concurrency,
        4,
        2,
        Duration::from_millis(20),
    );
    for ((got_m, got_bits), (want_m, want_bits)) in got.iter().zip(&reference) {
        assert_eq!(got_m, want_m);
        assert_eq!(got_bits, want_bits);
    }
}

/// Boundary overlap acceptance: with a real coordination gap between
/// epochs, the cross-epoch dispatch order (dispatch e+1 before the gap)
/// must beat the pipelined order (pool idle through the gap).
#[test]
fn cross_epoch_measured_wall_beats_pipelined_at_the_boundary() {
    const EPOCHS: usize = 3;
    const N: usize = 8;
    const HANDLER_MS: u64 = 40;
    const COORD_MS: u64 = 80;
    let run = |cross: bool| {
        let p = platform(0, sleepy(HANDLER_MS));
        let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
        let dispatch = |epoch: usize| {
            let mut pipe = PipelinedMap::new(
                sched.clone(),
                p.clone(),
                0,
                "grad",
                N,
                64,
                RetryPolicy::default(),
            )
            .unwrap()
            .with_generation(epoch as u64);
            for i in 0..N {
                pipe.submit(grad_payload(epoch as u64, i), None);
            }
            pipe
        };
        let collect = |mut pipe: PipelinedMap| {
            while pipe.next_output().is_some() {}
            pipe.finish().unwrap();
        };
        let t0 = Instant::now();
        if cross {
            let mut pending = dispatch(1);
            for epoch in 1..=EPOCHS {
                std::thread::sleep(Duration::from_millis(COORD_MS));
                collect(pending);
                pending = dispatch(epoch + 1);
            }
            collect(pending);
        } else {
            for epoch in 1..=EPOCHS + 1 {
                collect(dispatch(epoch));
                if epoch <= EPOCHS {
                    std::thread::sleep(Duration::from_millis(COORD_MS));
                }
            }
        }
        t0.elapsed()
    };
    let pipelined = run(false);
    let cross = run(true);
    // pipelined pays the full gap (pool idle); cross-epoch hides the
    // epoch execution behind it. Sleeps don't contend for cores, so a
    // 15% margin is comfortably stable.
    assert!(
        cross < pipelined.mul_f64(0.85),
        "cross-epoch {cross:?} did not beat pipelined {pipelined:?} at the boundary"
    );
}

fn sleepy(ms: u64) -> Handler {
    Arc::new(move |b: &Bytes| {
        std::thread::sleep(Duration::from_millis(ms));
        Ok(b.clone())
    })
}

// -------------------------------------------------------------- cluster

fn serverless_cfg() -> TrainConfig {
    TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 3,
        lr: 0.05,
        train_samples: 2 * 16 * 3, // 3 full batches per peer, no remainder
        val_samples: 64,
        backend: Backend::Serverless,
        artifacts_dir: common::artifacts_dir(),
        ..Default::default()
    }
}

/// Cross-epoch training must reproduce the staged validation curve at
/// any pipeline depth — the generation-keyed folds make the math
/// independent of the dispatch overlap.
#[test]
fn cross_epoch_val_curve_matches_staged_at_depths_1_and_2() {
    require_artifacts!();
    let run = |mode: OffloadMode, depth: usize| {
        let cfg = TrainConfig {
            offload_mode: mode,
            pipeline_depth: depth,
            ..serverless_cfg()
        };
        Cluster::with_engine(cfg, common::engine())
            .unwrap()
            .run()
            .unwrap()
    };
    let staged = run(OffloadMode::Staged, 2);
    for depth in [1usize, 2] {
        let cross = run(OffloadMode::CrossEpoch, depth);
        assert_eq!(staged.val_curve.len(), cross.val_curve.len());
        for ((e1, l1, a1), (e2, l2, a2)) in staged.val_curve.iter().zip(&cross.val_curve) {
            assert_eq!(e1, e2);
            assert!(
                (l1 - l2).abs() < 1e-6,
                "staged {l1} vs cross-epoch {l2} at depth {depth}"
            );
            assert!((a1 - a2).abs() < 1e-6);
        }
        assert_eq!(staged.lambda_invocations, cross.lambda_invocations);
        // the sweep lag still leaves nothing behind at teardown
        assert_eq!(cross.store_objects, 0, "depth {depth} leaked store objects");
        // no out-of-order gradient publish ever fired
        assert_eq!(cross.counter("broker.stale_drops"), Some(0));
    }
}

/// The pre-dispatch actually fires: every epoch but the last is
/// dispatched ahead of the boundary on every peer, the overlap window
/// is measured, and both generations coexist on the scheduler.
#[test]
fn cross_epoch_predispatches_and_overlaps_generations() {
    require_artifacts!();
    let cfg = TrainConfig {
        offload_mode: OffloadMode::CrossEpoch,
        ..serverless_cfg()
    };
    let (peers, epochs) = (cfg.peers, cfg.epochs);
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        rep.counter("offload.predispatched_epochs"),
        Some((peers * (epochs - 1)) as u64),
        "every epoch but the first (never speculative) and last must pre-dispatch"
    );
    assert!(
        rep.counter("offload.overlap_wall_us").unwrap_or(0) > 0,
        "pre-dispatched epochs must report a non-zero overlap window"
    );
    let peak = rep.counter("sched.peak_inflight_generations").unwrap_or(0);
    assert!(
        (1..=2).contains(&peak),
        "peak in-flight generations {peak} out of the synchronous window"
    );
    assert_eq!(rep.store_objects, 0);
}

/// Depth 1 disables the pre-dispatch but keeps cross-epoch collection
/// and the lagged sweep working.
#[test]
fn cross_epoch_depth_1_never_predispatches() {
    require_artifacts!();
    let cfg = TrainConfig {
        offload_mode: OffloadMode::CrossEpoch,
        pipeline_depth: 1,
        epochs: 2,
        ..serverless_cfg()
    };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.counter("offload.predispatched_epochs"), Some(0));
    assert_eq!(rep.store_objects, 0);
    assert!(rep.mean_train_loss_last_epoch().unwrap().is_finite());
}

/// `--sweep-scratch false` composes with the lagged sweep: nothing is
/// reclaimed, so the scratch of every epoch survives to teardown.
#[test]
fn cross_epoch_sweep_off_accumulates_scratch() {
    require_artifacts!();
    let cfg = TrainConfig {
        offload_mode: OffloadMode::CrossEpoch,
        sweep_scratch: false,
        ..serverless_cfg()
    };
    let (peers, epochs, batches) = (cfg.peers, cfg.epochs, 3usize);
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    // teardown removes the persistent batch objects; the unswept
    // scratch remains: one deduped params object per epoch (identical
    // bytes across synchronous peers) plus the parked gradients per
    // peer per epoch
    assert_eq!(rep.store_objects, epochs * (1 + peers * batches));
}
