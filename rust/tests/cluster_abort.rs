//! Fail-fast abort propagation: when one peer dies, the rest must not
//! stay parked on the epoch barrier or a gradient queue until the run
//! drags to an end — the broker-wide abort wakes them with
//! `Error::Aborted`. These tests exercise the mechanism the cluster
//! wires up (`Cluster::run` aborts the broker when a peer thread errors
//! or panics); no PJRT artifacts are needed.

use std::sync::Arc;
use std::time::Duration;

use p2pless::broker::{Broker, Message, QueueMode};
use p2pless::coordinator::EpochBarrier;
use p2pless::error::Error;
use p2pless::util::Bytes;

/// The satellite's regression shape: rank 0 "fails" before arriving at
/// the epoch barrier; rank 1 is already parked there. The abort must
/// release rank 1 promptly with the failing peer's reason.
#[test]
fn barrier_waiter_released_when_peer_fails() {
    let broker = Arc::new(Broker::default());
    let barrier = Arc::new(EpochBarrier::new(&broker, 2).unwrap());

    let b = barrier.clone();
    let parked = std::thread::spawn(move || b.arrive_and_wait(1, 1));

    // give rank 1 time to actually park
    std::thread::sleep(Duration::from_millis(20));
    // rank 0 errors instead of arriving; the cluster aborts the broker
    broker.abort("peer 0 failed: faas: no batches to offload");

    let err = parked.join().unwrap().unwrap_err();
    assert!(matches!(err, Error::Aborted(_)), "expected Aborted, got {err}");
    assert!(err.to_string().contains("peer 0 failed"), "{err}");
}

/// A synchronous consumer blocked on a dead peer's gradient queue is
/// released the same way.
#[test]
fn gradient_waiter_released_when_peer_fails() {
    let broker = Arc::new(Broker::default());
    broker
        .declare(&Broker::gradient_queue(0), QueueMode::LatestOnly)
        .unwrap();
    let q = broker.get(&Broker::gradient_queue(0)).unwrap();
    let parked = std::thread::spawn(move || q.await_epoch(3));

    std::thread::sleep(Duration::from_millis(20));
    broker.abort("peer 0 panicked");

    let err = parked.join().unwrap().unwrap_err();
    assert!(matches!(err, Error::Aborted(_)), "expected Aborted, got {err}");
}

/// Abort releases *every* parked peer of a larger cluster, not just one
/// (notify-all, not notify-one).
#[test]
fn abort_releases_all_parked_peers() {
    let peers = 4;
    let broker = Arc::new(Broker::default());
    let barrier = Arc::new(EpochBarrier::new(&broker, peers).unwrap());

    // peers 1..4 arrive; peer 0 never does
    let parked: Vec<_> = (1..peers)
        .map(|rank| {
            let b = barrier.clone();
            std::thread::spawn(move || b.arrive_and_wait(rank, 1))
        })
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    broker.abort("peer 0 failed");

    for h in parked {
        assert!(h.join().unwrap().is_err());
    }
}

/// An abort raised *before* a peer reaches the barrier still stops it —
/// no lost-wakeup window between the flag and the condvar.
#[test]
fn abort_before_arrival_is_not_lost() {
    let broker = Arc::new(Broker::default());
    let barrier = EpochBarrier::new(&broker, 2).unwrap();
    broker.abort("early failure");
    let err = barrier.arrive_and_wait(0, 1).unwrap_err();
    assert!(matches!(err, Error::Aborted(_)));
}

/// Publishing still works after an abort (late peers flushing state must
/// not panic), and non-blocking consumption is unaffected.
#[test]
fn abort_does_not_break_publish_or_peek() {
    let broker = Arc::new(Broker::default());
    broker.declare("q", QueueMode::LatestOnly).unwrap();
    broker.abort("stop");
    broker
        .publish("q", Message::new(0, 1, Bytes::from_static(b"late")))
        .unwrap();
    let q = broker.get("q").unwrap();
    assert_eq!(&q.peek_latest().unwrap().payload[..], b"late");
}
