//! The pipelined epoch offload and the global branch scheduler, end to
//! end with synthetic handlers (no PJRT artifacts needed):
//!
//! - modeled determinism: the pipelined path's wall/billed/cost are
//!   byte-identical to the staged `StateMachine` path at any
//!   `--exec-threads` (the acceptance bar for paper tables);
//! - overlap: the pipelined measured wall beats the sum of the staged
//!   stages (upload + fan-out) on a multi-thread executor;
//! - fairness: with peers sharing the pool, round-robin dispatch keeps
//!   per-peer served counts within one branch of each other.

use std::sync::Arc;
use std::time::{Duration, Instant};

use p2pless::faas::{
    BranchScheduler, Executor, FaasPlatform, FunctionSpec, Handler, PipelinedMap,
    RetryPolicy, StateMachine,
};
use p2pless::util::Bytes;

fn echo() -> Handler {
    Arc::new(|b: &Bytes| Ok(b.clone()))
}

fn sleepy(ms: u64) -> Handler {
    Arc::new(move |b: &Bytes| {
        std::thread::sleep(Duration::from_millis(ms));
        Ok(b.clone())
    })
}

fn platform(cold_ms: u64, handler: Handler) -> Arc<FaasPlatform> {
    let p = Arc::new(FaasPlatform::new(Duration::from_millis(cold_ms)));
    p.register(FunctionSpec::new("grad", 1024, handler)).unwrap();
    p
}

/// The acceptance bar: modeled wall / billed / cost / cold starts from
/// the pipelined path are byte-identical to the staged Map state, no
/// matter how many worker threads execute the branches.
#[test]
fn pipelined_modeled_outputs_match_staged_at_any_thread_count() {
    let n = 16usize;
    let concurrency = 4usize;
    let modeled: Vec<Option<Duration>> =
        (0..n).map(|i| Some(Duration::from_millis(900 + i as u64 * 7))).collect();

    let staged = |threads: usize| {
        let p = platform(2500, echo());
        let pool = Executor::new(threads);
        let items: Vec<Bytes> = (0..n).map(|_| Bytes::from_static(b"b")).collect();
        let sm = StateMachine::parallel_batches(
            "det",
            "grad",
            items,
            modeled.clone(),
            concurrency,
        );
        let r = sm.execute_with(&p, &pool).unwrap();
        (r.wall, r.billed, r.cost_usd.to_bits(), r.invocations, r.cold_starts)
    };
    let pipelined = |threads: usize| {
        let p = platform(2500, echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(threads)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            p.clone(),
            0,
            "grad",
            n,
            concurrency,
            RetryPolicy::default(),
        )
        .unwrap();
        for m in &modeled {
            pipe.submit(Bytes::from_static(b"b"), *m);
        }
        while pipe.next_output().is_some() {}
        let r = pipe.finish().unwrap();
        (r.wall, r.billed, r.cost_usd.to_bits(), r.invocations, r.cold_starts)
    };

    let reference = staged(1);
    for threads in [1usize, 2, 8] {
        assert_eq!(
            staged(threads),
            reference,
            "staged modeled outputs moved with thread count {threads}"
        );
        assert_eq!(
            pipelined(threads),
            reference,
            "pipelined modeled outputs diverge from staged at {threads} threads"
        );
    }
}

/// Overlap acceptance: uploads take real caller-thread time, handlers
/// take real worker time; the pipelined epoch must beat the sum of the
/// staged stages (upload everything, then fan out) on a 4-thread pool.
#[test]
fn pipelined_measured_wall_beats_staged_stage_sum() {
    const N: usize = 8;
    const UPLOAD_MS: u64 = 15;
    const HANDLER_MS: u64 = 100;

    // staged: upload barrier first, then the Map state
    let staged_sum = {
        let p = platform(0, sleepy(HANDLER_MS));
        let pool = Executor::new(4);
        let t0 = Instant::now();
        let mut items = Vec::with_capacity(N);
        for _ in 0..N {
            std::thread::sleep(Duration::from_millis(UPLOAD_MS)); // "upload"
            items.push(Bytes::from_static(b"b"));
        }
        let sm = StateMachine::parallel_batches("staged", "grad", items, vec![], 64);
        sm.execute_with(&p, &pool).unwrap();
        t0.elapsed()
    };

    // pipelined: each branch dispatched the moment its upload lands
    let pipelined = {
        let p = platform(0, sleepy(HANDLER_MS));
        let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            p,
            0,
            "grad",
            N,
            64,
            RetryPolicy::default(),
        )
        .unwrap();
        for _ in 0..N {
            std::thread::sleep(Duration::from_millis(UPLOAD_MS)); // "upload"
            pipe.submit(Bytes::from_static(b"b"), None);
            while pipe.poll_output().is_some() {}
        }
        while pipe.next_output().is_some() {}
        pipe.finish().unwrap().measured_wall
    };

    // 8 uploads of 15 ms + 2 handler waves of 100 ms staged ≈ 320 ms;
    // pipelined hides the second wave's queueing behind the uploads
    // (≈ 260 ms). Sleeps don't contend for cores, so the gap is stable.
    assert!(
        pipelined < staged_sum.mul_f64(0.95),
        "pipelined {pipelined:?} did not beat staged stage sum {staged_sum:?}"
    );
}

/// Fairness acceptance: two peers submitting equal work through the
/// fair scheduler are served within one branch of each other at every
/// point of the dispatch sequence.
#[test]
fn fair_dispatch_keeps_peers_within_one_branch() {
    const PER_PEER: usize = 8;
    let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
    sched.enable_dispatch_log();
    sched.register_peer(0, 4);
    sched.register_peer(1, 4);
    // hold dispatch so both lanes are fully queued before the first
    // branch is released — makes the dispatch order deterministic
    sched.pause();
    let mut handles = Vec::new();
    for i in 0..PER_PEER {
        for peer in 0..2usize {
            handles.push(sched.submit(peer, move || {
                std::thread::sleep(Duration::from_millis(2));
                i
            }));
        }
    }
    sched.resume();
    for h in handles {
        h.join().unwrap();
    }

    let log = sched.dispatch_log();
    assert_eq!(log.len(), 2 * PER_PEER);
    let (mut c0, mut c1) = (0i64, 0i64);
    for (i, &rank) in log.iter().enumerate() {
        if rank == 0 {
            c0 += 1;
        } else {
            c1 += 1;
        }
        assert!(
            (c0 - c1).abs() <= 1,
            "unfair prefix at dispatch {i}: peer0={c0} peer1={c1}, log={log:?}"
        );
    }
    let stats = sched.stats();
    let served: Vec<u64> = stats.per_peer_served.iter().map(|&(_, s)| s).collect();
    assert_eq!(served, vec![PER_PEER as u64, PER_PEER as u64]);
}

/// The greedy baseline (`--sched-fair false`) serves the lowest rank
/// first — documenting why round-robin is the default.
#[test]
fn unfair_dispatch_starves_higher_ranks() {
    const PER_PEER: usize = 6;
    let sched = BranchScheduler::new(Arc::new(Executor::new(2)), false);
    sched.enable_dispatch_log();
    sched.register_peer(0, 64);
    sched.register_peer(1, 64);
    sched.pause();
    let mut handles = Vec::new();
    for i in 0..PER_PEER {
        for peer in 0..2usize {
            handles.push(sched.submit(peer, move || {
                std::thread::sleep(Duration::from_millis(2));
                i
            }));
        }
    }
    sched.resume();
    for h in handles {
        h.join().unwrap();
    }
    let log = sched.dispatch_log();
    assert_eq!(
        log[..PER_PEER],
        [0usize; PER_PEER],
        "greedy mode must drain peer 0 first: {log:?}"
    );
}

/// Two peers running pipelined fan-outs concurrently over one scheduler:
/// every branch lands, per-peer accounting is exact, and the pool serves
/// both (the multi-peer cluster shape, minus PJRT).
#[test]
fn concurrent_pipelines_share_the_scheduler() {
    let p = Arc::new(FaasPlatform::new(Duration::ZERO));
    p.register(FunctionSpec::new("grad-p0", 512, sleepy(3))).unwrap();
    p.register(FunctionSpec::new("grad-p1", 512, sleepy(3))).unwrap();
    let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
    sched.register_peer(0, 8);
    sched.register_peer(1, 8);

    const N: usize = 12;
    let mut workers = Vec::new();
    for peer in 0..2usize {
        let sched = sched.clone();
        let p = p.clone();
        workers.push(std::thread::spawn(move || {
            let mut pipe = PipelinedMap::new(
                sched,
                p,
                peer,
                &format!("grad-p{peer}"),
                N,
                8,
                RetryPolicy::default(),
            )
            .unwrap();
            for i in 0..N as u8 {
                pipe.submit(Bytes::from(vec![i]), None);
            }
            let mut seen = 0usize;
            while let Some((idx, out)) = pipe.next_output() {
                assert_eq!(out[0] as usize, idx);
                seen += 1;
            }
            assert_eq!(seen, N);
            pipe.finish().unwrap()
        }));
    }
    let mut invocations = 0;
    for w in workers {
        invocations += w.join().unwrap().invocations;
    }
    assert_eq!(invocations, 2 * N);
    let stats = sched.stats();
    assert_eq!(stats.submitted, (2 * N) as u64);
    let served: Vec<u64> = stats.per_peer_served.iter().map(|&(_, s)| s).collect();
    assert_eq!(served, vec![N as u64, N as u64]);
}

/// A failing branch fails the pipelined epoch (after all branches are
/// drained), and a panicking handler is contained — no hang, no poisoned
/// scheduler.
#[test]
fn pipelined_errors_and_panics_are_contained() {
    let p = Arc::new(FaasPlatform::new(Duration::ZERO));
    let flaky: Handler = Arc::new(|b: &Bytes| {
        if &b[..] == b"bad" {
            Err(p2pless::error::Error::Faas("always fails".into()))
        } else if &b[..] == b"boom" {
            panic!("handler exploded");
        } else {
            Ok(b.clone())
        }
    });
    p.register(FunctionSpec::new("grad", 512, flaky)).unwrap();
    let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);

    for poison in [&b"bad"[..], &b"boom"[..]] {
        let mut pipe = PipelinedMap::new(
            sched.clone(),
            p.clone(),
            0,
            "grad",
            3,
            8,
            RetryPolicy { max_attempts: 2, ..RetryPolicy::default() },
        )
        .unwrap();
        pipe.submit(Bytes::from_static(b"ok1"), None);
        pipe.submit(Bytes::from(poison.to_vec()), None);
        pipe.submit(Bytes::from_static(b"ok2"), None);
        while pipe.next_output().is_some() {}
        let err = pipe.finish().unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("always fails") || msg.contains("panicked"),
            "unexpected error: {msg}"
        );
    }
    // the scheduler keeps serving afterwards
    assert_eq!(sched.submit(0, || 5usize).join().unwrap(), 5);
}

/// Retries in the pipelined path match the staged accounting: a branch
/// succeeding on attempt k records k-1 retries.
#[test]
fn pipelined_retry_accounting_matches_staged() {
    use std::sync::atomic::{AtomicU32, Ordering};
    let run = |staged: bool| {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        let fails = Arc::new(AtomicU32::new(0));
        let f2 = fails.clone();
        let flaky: Handler = Arc::new(move |b: &Bytes| {
            if &b[..] == b"flaky" && f2.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(p2pless::error::Error::Faas("transient".into()))
            } else {
                Ok(b.clone())
            }
        });
        p.register(FunctionSpec::new("grad", 512, flaky)).unwrap();
        let items = vec![
            Bytes::from_static(b"ok1"),
            Bytes::from_static(b"flaky"),
            Bytes::from_static(b"ok2"),
        ];
        if staged {
            let pool = Executor::new(2);
            let sm = StateMachine::parallel_batches("r", "grad", items, vec![], 8);
            let r = sm.execute_with(&p, &pool).unwrap();
            (r.invocations, r.retries)
        } else {
            let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
            let mut pipe =
                PipelinedMap::new(sched, p, 0, "grad", 3, 8, RetryPolicy::default())
                    .unwrap();
            for item in items {
                pipe.submit(item, None);
            }
            while pipe.next_output().is_some() {}
            let r = pipe.finish().unwrap();
            (r.invocations, r.retries)
        }
    };
    assert_eq!(run(true), (3, 2));
    assert_eq!(run(false), (3, 2));
}
