//! The zero-redundancy serverless data plane:
//!
//! - store semantics (no PJRT needed): generation-scoped sweeps keep
//!   persistent batch objects and reclaim epoch scratch, the store stays
//!   bounded over many generations, and the decoded-object cache decodes
//!   a hot key exactly once under concurrency;
//! - cluster acceptance (real PJRT, artifact-gated): steady-state epochs
//!   perform O(1) store puts for inputs (the params object only) instead
//!   of O(batches), decode hit/miss counters match branch counts, and
//!   the `--sweep-scratch` knob behaves as documented.
//!
//! The modeled wall/billed/cost invariance across thread counts and
//! offload modes is pinned at the faas layer by
//! `rust/tests/pipeline_scheduler.rs`; nothing in the data plane touches
//! that aggregation.

mod common;

use std::sync::{Arc, Barrier};

use p2pless::config::{Backend, OffloadMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::store::{DecodedCache, ObjectStore, GEN_PERSISTENT};
use p2pless::util::bytes::f32s_to_bytes;
use p2pless::util::Bytes;

// ---------------------------------------------------------------- store

#[test]
fn generation_sweep_keeps_persistent_reclaims_scratch() {
    let s = ObjectStore::new();
    // the run-long batch objects
    let batches: Vec<_> = (0..4)
        .map(|i| s.put_new("peer-0-batches", Bytes::from(vec![i as u8])).unwrap())
        .collect();
    // epoch 1 scratch: params + parked gradients
    let params = s.put_new_gen("peer-0-batches", Bytes::from_static(b"p1"), 1).unwrap();
    let grads: Vec<_> = (0..4)
        .map(|_| s.put_new_gen("peer-0-batches", Bytes::from_static(b"g"), 1).unwrap())
        .collect();
    assert_eq!(s.total_objects(), 9);
    assert_eq!(s.sweep_generation("peer-0-batches", 1), 5);
    assert_eq!(s.total_objects(), 4);
    for b in &batches {
        assert!(s.get_ref(b).is_ok(), "persistent batch object swept");
        assert_eq!(s.generation_of(b), Some(GEN_PERSISTENT));
    }
    assert!(s.get_ref(&params).is_err());
    for g in &grads {
        assert!(s.get_ref(g).is_err());
    }
}

#[test]
fn store_stays_bounded_over_many_generations() {
    let s = ObjectStore::new();
    let n_batches = 6usize;
    for i in 0..n_batches {
        s.put_new("b", Bytes::from(vec![i as u8])).unwrap();
    }
    for generation in 1..=200u64 {
        s.put_new_gen("b", Bytes::from_static(b"params"), generation).unwrap();
        for _ in 0..n_batches {
            s.put_new_gen("b", Bytes::from_static(b"grad"), generation).unwrap();
        }
        assert_eq!(s.total_objects(), n_batches + 1 + n_batches);
        assert_eq!(s.sweep_generation("b", generation), 1 + n_batches);
        assert_eq!(
            s.total_objects(),
            n_batches,
            "generation {generation}: store must hold exactly the persistent objects"
        );
    }
    let (puts, _, _) = s.stats();
    assert_eq!(puts as usize, n_batches + 200 * (1 + n_batches));
}

#[test]
fn decoded_cache_decodes_once_under_concurrency() {
    let store = Arc::new(ObjectStore::new());
    let v: Vec<f32> = (0..1024).map(|i| i as f32 * 0.5).collect();
    let r = store.put_new("b", Bytes::from(f32s_to_bytes(&v))).unwrap();
    let cache = Arc::new(DecodedCache::new(8));

    const THREADS: usize = 8;
    let barrier = Arc::new(Barrier::new(THREADS));
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let store = store.clone();
            let cache = cache.clone();
            let r = r.clone();
            let barrier = barrier.clone();
            let want = v.clone();
            std::thread::spawn(move || {
                barrier.wait();
                let got = cache.get_or_decode(&r, &store).unwrap();
                assert_eq!(*got, want);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // the per-key in-flight guard makes the counts exact, not racy:
    // one miss, everyone else a hit, one store get total
    assert_eq!(cache.misses(), 1, "concurrent branches must decode once");
    assert_eq!(cache.hits(), (THREADS - 1) as u64);
    assert_eq!(store.stats().1, 1, "one store get for {THREADS} readers");

    // a second "epoch" (fresh params key) costs exactly one more miss
    let r2 = store.put_new("b", Bytes::from(f32s_to_bytes(&v))).unwrap();
    for _ in 0..THREADS {
        cache.get_or_decode(&r2, &store).unwrap();
    }
    assert_eq!(cache.misses(), 2);
    assert_eq!(cache.hits(), (2 * (THREADS - 1)) as u64);
}

// -------------------------------------------------------------- cluster

fn serverless_cfg() -> TrainConfig {
    TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 3,
        lr: 0.05,
        train_samples: 2 * 16 * 3, // 3 full batches per peer, no remainder
        val_samples: 64,
        backend: Backend::Serverless,
        artifacts_dir: common::artifacts_dir(),
        ..Default::default()
    }
}

/// The acceptance bar: with epoch-persistent batch objects, a
/// steady-state epoch puts exactly one input object (the params) plus
/// the parked per-batch gradients — the per-epoch batch re-upload is
/// gone; content dedupe collapses the N identical per-peer params
/// uploads to one stored object per epoch, and the decode cache turns
/// the whole cluster's params reads into one decode.
#[test]
fn steady_state_epochs_put_only_params() {
    require_artifacts!();
    let cfg = serverless_cfg();
    let (peers, epochs, batches) = (cfg.peers as u64, cfg.epochs as u64, 3u64);
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    let branches = peers * epochs * batches;
    assert_eq!(rep.lambda_invocations, branches);

    // puts: batch objects once per peer, then per epoch ONE deduped
    // params object for the whole cluster (synchronous peers upload
    // identical bytes) + one parked gradient per branch. The old plane
    // paid an extra `batches` puts per peer per epoch, and until the
    // dedupe an extra params object per peer per epoch.
    let want_puts = peers * batches + epochs * (1 + peers * batches);
    assert_eq!(
        rep.counter("store.puts"),
        Some(want_puts),
        "steady-state epochs must store params once per epoch (O(1) input puts)"
    );
    // every peer after the first hits the dedupe
    assert_eq!(
        rep.counter("store.dedup_hits"),
        Some(epochs * (peers - 1)),
        "N synchronous peers must put 1 params object"
    );

    // decode counters: one miss per epoch — the deduplicated params
    // object is shared cluster-wide, so even across peers the decode
    // happens once; every other branch is a hit. Exact even under
    // concurrent branches (per-key in-flight guard).
    let want_misses = epochs;
    assert_eq!(rep.counter("store.decode_misses"), Some(want_misses));
    assert_eq!(rep.counter("store.decode_hits"), Some(branches - want_misses));

    // packed-literal sidecar: each batch object's input literals are
    // packed exactly once (epoch 1), then checked out on every later
    // epoch
    assert_eq!(rep.counter("store.pack_misses"), Some(peers * batches));
    assert_eq!(
        rep.counter("store.pack_hits"),
        Some((epochs - 1) * peers * batches)
    );

    // generation sweeps + teardown leave nothing behind
    assert_eq!(rep.store_objects, 0);
}

/// `--sweep-scratch false` keeps every epoch's scratch: the store grows
/// with the epoch count (the knob exists exactly to make leaks visible).
#[test]
fn sweep_scratch_off_accumulates_epoch_scratch() {
    require_artifacts!();
    let cfg = TrainConfig { sweep_scratch: false, ..serverless_cfg() };
    let (peers, epochs, batches) = (cfg.peers, cfg.epochs, 3usize);
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    // teardown removes the persistent batch objects; the unswept
    // scratch remains: one deduped params object per epoch plus the
    // parked gradients per peer per epoch
    assert_eq!(rep.store_objects, epochs * (1 + peers * batches));
}

/// Staged and pipelined dispatch consume the same cached batch refs and
/// fold in the same branch order, so the leader's validation curve must
/// match between the modes.
#[test]
fn staged_and_pipelined_val_curves_match() {
    require_artifacts!();
    let run = |mode: OffloadMode| {
        let cfg = TrainConfig { offload_mode: mode, ..serverless_cfg() };
        Cluster::with_engine(cfg, common::engine())
            .unwrap()
            .run()
            .unwrap()
    };
    let staged = run(OffloadMode::Staged);
    let pipelined = run(OffloadMode::Pipelined);
    assert_eq!(staged.val_curve.len(), pipelined.val_curve.len());
    for ((e1, l1, a1), (e2, l2, a2)) in staged.val_curve.iter().zip(&pipelined.val_curve) {
        assert_eq!(e1, e2);
        assert!((l1 - l2).abs() < 1e-6, "staged {l1} vs pipelined {l2}");
        assert!((a1 - a2).abs() < 1e-6);
    }
    // both paths drove the same number of branches through the platform
    assert_eq!(staged.lambda_invocations, pipelined.lambda_invocations);
}

/// Disabling the decode cache changes counters only — the math and the
/// store's boundedness are untouched.
#[test]
fn decode_cache_disabled_still_trains_and_sweeps() {
    require_artifacts!();
    let cfg = TrainConfig { decode_cache: 0, epochs: 2, ..serverless_cfg() };
    let rep = Cluster::with_engine(cfg, common::engine())
        .unwrap()
        .run()
        .unwrap();
    assert!(rep.lambda_invocations > 0);
    assert_eq!(rep.counter("store.decode_hits"), Some(0));
    assert_eq!(
        rep.counter("store.decode_misses"),
        Some(rep.lambda_invocations),
        "disabled cache: every branch decodes"
    );
    assert_eq!(rep.store_objects, 0);
    assert!(rep.mean_train_loss_last_epoch().unwrap().is_finite());
}
