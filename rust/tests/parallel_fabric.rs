//! The parallel execution fabric, end to end: worker-pool Map dispatch,
//! platform counter consistency under thread pressure, modeled-time
//! determinism across pool sizes, and the measured-speedup acceptance
//! check (parallel fan-out < 0.7x the sequential measured wall).
//!
//! None of these need the PJRT artifacts — handlers are synthetic, so
//! the fabric itself is what is under test.

use std::sync::Arc;
use std::time::Duration;

use p2pless::faas::{
    invocation_cost, Arch, Executor, FaasPlatform, FunctionSpec, Handler, StateMachine,
};
use p2pless::util::Bytes;

fn echo() -> Handler {
    Arc::new(|b: &Bytes| Ok(b.clone()))
}

fn sleepy(ms: u64) -> Handler {
    Arc::new(move |b: &Bytes| {
        std::thread::sleep(Duration::from_millis(ms));
        Ok(b.clone())
    })
}

/// N threads hammering one registered function: every platform counter
/// and the accumulated cost must stay consistent.
#[test]
fn stress_platform_counters_consistent() {
    const THREADS: usize = 8;
    const ITERS: usize = 50;
    let p = Arc::new(FaasPlatform::new(Duration::from_millis(100)));
    p.register(FunctionSpec::new("grad", 1024, echo())).unwrap();
    let modeled = Duration::from_secs(1);

    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let p = p.clone();
            std::thread::spawn(move || {
                for _ in 0..ITERS {
                    p.invoke("grad", &Bytes::from_static(b"x"), Some(modeled)).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = (THREADS * ITERS) as u64;
    let stats = p.stats();
    assert_eq!(stats.invocations, total);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.billed_ms, total * 1000);
    // cold starts happen only while the warm pool ramps up
    assert!(stats.cold_starts >= 1 && stats.cold_starts <= THREADS as u64);
    let per_call = invocation_cost(1024, 1000, Arch::Arm64);
    let want = per_call * total as f64;
    // the accumulator truncates to microcents per call
    assert!(
        (p.total_cost_usd() - want).abs() < 1e-5,
        "cost {} vs {}",
        p.total_cost_usd(),
        want
    );
}

/// Modeled wall / billed / cost must be byte-identical whether the
/// fan-out runs on 1 worker thread or 8 — the pool is physical
/// concurrency only; the model is the paper's source of truth.
#[test]
fn modeled_outputs_identical_across_pool_sizes() {
    let run = |threads: usize| {
        let p = Arc::new(FaasPlatform::new(Duration::from_millis(2500)));
        p.register(FunctionSpec::new("grad", 2048, echo())).unwrap();
        let pool = Executor::new(threads);
        let items: Vec<Bytes> = (0..16).map(|_| Bytes::from_static(b"b")).collect();
        let modeled = (0..16).map(|i| Some(Duration::from_millis(900 + i * 7))).collect();
        let sm = StateMachine::parallel_batches("det", "grad", items, modeled, 4);
        let r = sm.execute_with(&p, &pool).unwrap();
        (r.wall, r.billed, r.cost_usd, r.invocations, r.cold_starts, p.stats().cold_starts)
    };
    let a = run(1);
    let b = run(8);
    assert_eq!(a.0, b.0, "modeled wall must not depend on pool size");
    assert_eq!(a.1, b.1, "billed must not depend on pool size");
    assert_eq!(
        a.2.to_bits(),
        b.2.to_bits(),
        "cost must be byte-identical: {} vs {}",
        a.2,
        b.2
    );
    assert_eq!(a.3, b.3);
    assert_eq!((a.4, a.5), (b.4, b.5), "wave cold-start accounting must be deterministic");
}

/// Acceptance: with >= 8 branches, the measured wall of a parallel
/// fan-out is < 0.7x the sequential (1-thread) measured wall.
#[test]
fn parallel_measured_wall_beats_sequential() {
    let run = |threads: usize| {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        p.register(FunctionSpec::new("grad", 512, sleepy(40))).unwrap();
        let pool = Executor::new(threads);
        let items: Vec<Bytes> = (0..8).map(|_| Bytes::from_static(b"b")).collect();
        let sm = StateMachine::parallel_batches("speed", "grad", items, vec![], 64);
        sm.execute_with(&p, &pool).unwrap()
    };
    let seq = run(1);
    let par = run(8);
    assert_eq!(seq.invocations, 8);
    assert_eq!(par.invocations, 8);
    // 8 x 40 ms sequentially is >= 320 ms; 8 sleeping workers finish in
    // roughly one 40 ms wave (sleeps do not contend for cores)
    assert!(
        par.measured_wall < seq.measured_wall.mul_f64(0.7),
        "parallel {:?} vs sequential {:?}",
        par.measured_wall,
        seq.measured_wall
    );
}

/// The *physical* in-flight branches are capped by the Map state's
/// modeled max_concurrency, not just by the pool width — the measured
/// wall must never show parallelism the platform would not allow.
#[test]
fn measured_wall_respects_modeled_concurrency_cap() {
    let run = |max_concurrency: usize| {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        p.register(FunctionSpec::new("grad", 512, sleepy(30))).unwrap();
        let pool = Executor::new(8);
        let items: Vec<Bytes> = (0..8).map(|_| Bytes::from_static(b"b")).collect();
        let sm = StateMachine::parallel_batches("cap", "grad", items, vec![], max_concurrency);
        sm.execute_with(&p, &pool).unwrap()
    };
    // 8 branches of 30 ms at concurrency 2 need >= 4 physical waves —
    // the sleeps guarantee this lower bound on any machine
    let capped = run(2);
    assert!(
        capped.measured_wall >= Duration::from_millis(120),
        "cap violated: {:?}",
        capped.measured_wall
    );
    // uncapped, the same fan-out collapses toward one wave; compare
    // against the capped run (a ratio is robust to machine load,
    // an absolute bound is not)
    let open = run(64);
    assert!(
        open.measured_wall < capped.measured_wall.mul_f64(0.7),
        "uncapped {:?} vs capped {:?}",
        open.measured_wall,
        capped.measured_wall
    );
}

/// A panicking handler must surface as an error from execute, leave the
/// platform usable, and not poison the worker pool.
#[test]
fn handler_panic_is_contained() {
    let p = Arc::new(FaasPlatform::new(Duration::ZERO));
    let bomb: Handler = Arc::new(|b: &Bytes| {
        if &b[..] == b"boom" {
            panic!("handler exploded");
        }
        Ok(b.clone())
    });
    p.register(FunctionSpec::new("grad", 512, bomb)).unwrap();
    let pool = Executor::new(4);

    let items = vec![
        Bytes::from_static(b"ok"),
        Bytes::from_static(b"boom"),
        Bytes::from_static(b"ok"),
    ];
    let sm = StateMachine::parallel_batches("panic", "grad", items, vec![], 64);
    let err = sm.execute_with(&p, &pool).unwrap_err();
    assert!(err.to_string().contains("panicked"), "{err}");

    // platform and pool both keep serving
    let items: Vec<Bytes> = (0..4).map(|_| Bytes::from_static(b"ok")).collect();
    let sm = StateMachine::parallel_batches("after", "grad", items, vec![], 64);
    let r = sm.execute_with(&p, &pool).unwrap();
    assert_eq!(r.invocations, 4);
}

/// The shared pool serves interleaved fan-outs from several state
/// machines at once (the multi-peer cluster shape).
#[test]
fn shared_pool_serves_concurrent_state_machines() {
    let pool = Arc::new(Executor::new(4));
    let p = Arc::new(FaasPlatform::new(Duration::ZERO));
    p.register(FunctionSpec::new("grad", 512, sleepy(5))).unwrap();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            let pool = pool.clone();
            let p = p.clone();
            std::thread::spawn(move || {
                let items: Vec<Bytes> = (0..6).map(|_| Bytes::from_static(b"b")).collect();
                let sm = StateMachine::parallel_batches("peer", "grad", items, vec![], 64);
                sm.execute_with(&p, &pool).unwrap()
            })
        })
        .collect();
    let mut total = 0;
    for h in handles {
        total += h.join().unwrap().invocations;
    }
    assert_eq!(total, 24);
    assert_eq!(p.stats().invocations, 24);
    assert_eq!(p.stats().errors, 0);
}
