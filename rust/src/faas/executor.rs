//! The execution fabric: a shared worker pool that runs FaaS
//! invocations on real OS threads.
//!
//! The paper's speedup comes from *parallel* per-batch Lambda fan-out;
//! this pool is what makes that fan-out physically concurrent instead
//! of a modeled fiction. Design points:
//!
//! - **bounded concurrency** — a fixed number of worker threads pulls
//!   jobs off one shared queue, so a 200-branch Map state never spawns
//!   200 threads;
//! - **per-invocation result channels** — every [`Executor::submit`]
//!   returns a [`JobHandle`] backed by its own rendezvous channel, so
//!   callers collect results in dispatch order (keeping modeled-time
//!   aggregation deterministic);
//! - **panic-safe error propagation** — a panicking handler is caught
//!   with `catch_unwind` and surfaced as [`Error::Faas`] from
//!   [`JobHandle::join`]; the worker thread survives and keeps serving.
//!
//! Jobs must not submit-and-join on the same pool (a saturated pool
//! would deadlock); the state machine only dispatches leaf invocations,
//! which never recurse. Leaf invocations *may* block briefly inside the
//! engine's fused-execution collector (`--exec-batch`): that wait is
//! bounded by the collect window and resolved by a group leader that is
//! itself a pool worker making progress, so it cannot deadlock the
//! pool — only trade a window's latency for fewer engine dispatches.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool for FaaS invocation dispatch.
pub struct Executor {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    busy: Arc<AtomicUsize>,
    peak_busy: Arc<AtomicUsize>,
}

impl Executor {
    /// Build a pool with `threads` workers; `0` sizes the pool to the
    /// machine (`available_parallelism`). `1` reproduces sequential
    /// dispatch for honest single-core timing comparisons.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 { default_threads() } else { threads };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let busy = Arc::new(AtomicUsize::new(0));
        let peak_busy = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                let busy = busy.clone();
                let peak = peak_busy.clone();
                std::thread::Builder::new()
                    .name(format!("faas-exec-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while waiting for a job
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let now = busy.fetch_add(1, Ordering::SeqCst) + 1;
                                peak.fetch_max(now, Ordering::SeqCst);
                                job();
                                busy.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // executor dropped
                        }
                    })
                    .expect("spawn faas executor worker")
            })
            .collect();
        Self { tx: Some(tx), workers, threads, busy, peak_busy }
    }

    /// The process-wide shared pool, sized to the machine. Used by
    /// call sites that have no `TrainConfig` to thread a pool through
    /// (cloud-scale harness drivers, tests).
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| Executor::new(0))
    }

    /// Number of worker threads (the physical concurrency bound).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers currently executing a job (utilization gauge).
    pub fn busy_threads(&self) -> usize {
        self.busy.load(Ordering::SeqCst)
    }

    /// High-water mark of simultaneously busy workers.
    pub fn peak_busy(&self) -> usize {
        self.peak_busy.load(Ordering::SeqCst)
    }

    /// Dispatch a job; the returned handle yields the result (or the
    /// panic, as an error) on [`JobHandle::join`].
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = sync_channel(1);
        let job: Job = Box::new(move || {
            let out = catch_unwind(AssertUnwindSafe(move || f())).map_err(|p| panic_message(&*p));
            // receiver may have been dropped by an abandoning caller
            let _ = tx.send(out);
        });
        self.tx
            .as_ref()
            .expect("executor is alive until dropped")
            .send(job)
            .expect("executor workers outlive the sender");
        JobHandle { rx }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // closing the channel wakes every idle worker with RecvError
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One submitted job's result slot.
pub struct JobHandle<T> {
    rx: Receiver<std::result::Result<T, String>>,
}

impl<T> JobHandle<T> {
    /// A handle plus the sender that fulfils it — for schedulers that
    /// queue jobs before releasing them to the pool.
    pub(crate) fn channel() -> (SyncSender<std::result::Result<T, String>>, JobHandle<T>) {
        let (tx, rx) = sync_channel(1);
        (tx, JobHandle { rx })
    }

    /// Block until the job finishes. A panic inside the job surfaces
    /// here as [`Error::Faas`]; the worker pool is unaffected.
    pub fn join(self) -> Result<T> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(panic)) => Err(Error::Faas(format!("invocation worker panicked: {panic}"))),
            Err(_) => Err(Error::Faas("invocation worker disconnected".into())),
        }
    }
}

// Re-exported here because the state machine gates in-flight fan-out
// branches on a Map state's `max_concurrency` with it.
pub use crate::util::sync::{Semaphore, SemaphorePermit};

pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn submit_returns_results_in_join_order() {
        let pool = Executor::new(4);
        let handles: Vec<_> = (0..16).map(|i| pool.submit(move || i * 2)).collect();
        let got: Vec<i32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got, (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn panic_is_contained_and_pool_survives() {
        let pool = Executor::new(2);
        let bad = pool.submit(|| -> u32 { panic!("handler exploded") });
        let err = bad.join().unwrap_err();
        assert!(err.to_string().contains("handler exploded"), "{err}");
        // the worker that caught the panic still serves jobs
        for i in 0..8 {
            assert_eq!(pool.submit(move || i + 1).join().unwrap(), i + 1);
        }
    }

    #[test]
    fn concurrency_is_bounded_by_thread_count() {
        let pool = Executor::new(2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let live = live.clone();
                let peak = peak.clone();
                pool.submit(move || {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {:?}", peak);
    }

    #[test]
    fn busy_tracking_observes_utilization() {
        let pool = Executor::new(2);
        assert_eq!(pool.busy_threads(), 0);
        assert_eq!(pool.peak_busy(), 0);
        let handles: Vec<_> = (0..4)
            .map(|_| pool.submit(|| std::thread::sleep(Duration::from_millis(10))))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.busy_threads(), 0);
        let peak = pool.peak_busy();
        assert!(peak >= 1 && peak <= 2, "peak {peak}");
    }

    #[test]
    fn zero_sizes_to_machine() {
        let pool = Executor::new(0);
        assert!(pool.threads() >= 1);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = Executor::new(3);
        let h = pool.submit(|| 7u8);
        assert_eq!(h.join().unwrap(), 7);
        drop(pool); // must not hang
    }
}
