//! AWS-Lambda-like function platform: registry, warm pool, cold starts,
//! timeout enforcement, GB-second billing.
//!
//! Handlers are in-process closures (the "deployment package"); the
//! gradient handler used by the coordinator captures the PJRT executable
//! and the object store, mirroring the paper's Lambda that pulls its
//! batch from S3 (§IV-D.1).
//!
//! Time accounting is dual:
//! - **measured** — wall time of the real handler (PJRT execution);
//! - **modeled** — a caller-supplied duration from the perfmodel for
//!   cloud-scale extrapolation. Billing uses the modeled duration when
//!   present, else the measured one minus any time the handler reported
//!   as an in-process artifact via [`report_unbilled`] (e.g. engine
//!   slot queue wait, which a real per-environment Lambda never pays).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::Bytes;
use std::sync::{Mutex, RwLock};

use super::pricing::{self, Arch};
use crate::error::{Error, Result};

/// AWS Lambda's hard limits the paper designs around (§III-A, §IV-D.1).
pub const MAX_TIMEOUT: Duration = Duration::from_secs(15 * 60);
pub const MAX_MEMORY_MB: u32 = 10_240;
/// Zipped deployment package limit (paper packs PyTorch under 50 MB).
pub const MAX_ZIP_MB: u32 = 50;
/// Unzipped layers limit.
pub const MAX_UNZIPPED_MB: u32 = 250;

/// A function handler: request bytes in, response bytes out.
pub type Handler = Arc<dyn Fn(&Bytes) -> Result<Bytes> + Send + Sync>;

thread_local! {
    static UNBILLED: std::cell::Cell<Duration> = std::cell::Cell::new(Duration::ZERO);
}

/// Called from *inside* a handler to report time that must be excluded
/// from measured billing — in-process simulation artifacts like the
/// engine-semaphore queue wait, which a real per-environment Lambda
/// never pays (it has its own compute). The engine's execution batcher
/// reports through the same channel: a fused branch's collect window
/// and the other group members' turns are artifacts of coalescing
/// in-process executions, not this invocation's compute. Accumulates
/// across calls within one invocation; without this, billed seconds and
/// cost would grow with `--exec-threads` (or `--exec-batch`) as
/// branches queue behind each other. Real handler work (S3 I/O, decode,
/// the branch's own execution) stays billed, and an explicit `modeled`
/// duration wins outright.
pub fn report_unbilled(d: Duration) {
    UNBILLED.with(|c| c.set(c.get() + d));
}

fn take_unbilled() -> Duration {
    UNBILLED.with(|c| c.replace(Duration::ZERO))
}

/// Registered function configuration.
#[derive(Clone)]
pub struct FunctionSpec {
    pub name: String,
    pub memory_mb: u32,
    pub timeout: Duration,
    pub arch: Arch,
    pub handler: Handler,
}

impl FunctionSpec {
    pub fn new(name: impl Into<String>, memory_mb: u32, handler: Handler) -> Self {
        Self {
            name: name.into(),
            memory_mb,
            timeout: MAX_TIMEOUT,
            arch: Arch::Arm64,
            handler,
        }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

/// One finished invocation.
#[derive(Debug, Clone)]
pub struct Invocation {
    pub function: String,
    pub output: Bytes,
    /// Real handler wall time.
    pub measured: Duration,
    /// Duration used for billing/wall aggregation (modeled if supplied).
    pub billed: Duration,
    /// Cold-start latency (zero for warm starts) — affects wall time,
    /// not billing (AWS does not bill init for managed runtimes).
    pub cold_start: Duration,
    pub memory_mb: u32,
    pub cost_usd: f64,
}

impl Invocation {
    /// Wall-clock contribution of this invocation (init + execution).
    pub fn wall(&self) -> Duration {
        self.cold_start + self.billed
    }
}

/// Platform-wide counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct PlatformStats {
    pub invocations: u64,
    pub cold_starts: u64,
    pub errors: u64,
    pub billed_ms: u64,
}

/// The Lambda platform.
pub struct FaasPlatform {
    functions: RwLock<HashMap<String, FunctionSpec>>,
    /// Warm execution environments per function.
    warm: Mutex<HashMap<String, usize>>,
    cold_start: Duration,
    invocations: AtomicU64,
    cold_starts: AtomicU64,
    errors: AtomicU64,
    billed_ms: AtomicU64,
    cost_microcents: AtomicU64,
}

impl Default for FaasPlatform {
    fn default() -> Self {
        Self::new(Duration::from_millis(2500))
    }
}

impl FaasPlatform {
    /// `cold_start`: modeled init latency for a fresh environment (the
    /// paper's PyTorch-on-ARM images land in the seconds range).
    pub fn new(cold_start: Duration) -> Self {
        Self {
            functions: RwLock::new(HashMap::new()),
            warm: Mutex::new(HashMap::new()),
            cold_start,
            invocations: AtomicU64::new(0),
            cold_starts: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            billed_ms: AtomicU64::new(0),
            cost_microcents: AtomicU64::new(0),
        }
    }

    pub fn register(&self, spec: FunctionSpec) -> Result<()> {
        if spec.memory_mb > MAX_MEMORY_MB {
            return Err(Error::Faas(format!(
                "{}: {} MB exceeds the {} MB Lambda cap",
                spec.name, spec.memory_mb, MAX_MEMORY_MB
            )));
        }
        if spec.timeout > MAX_TIMEOUT {
            return Err(Error::Faas(format!(
                "{}: timeout {:?} exceeds the 15-minute Lambda cap",
                spec.name, spec.timeout
            )));
        }
        self.functions.write().unwrap().insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<FunctionSpec> {
        self.functions
            .read().unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Faas(format!("unknown function {name:?}")))
    }

    /// Take up to `n` warm environments for `name`; returns how many
    /// were available. The remaining `n - taken` invocations of a
    /// fan-out wave are cold. Making the cold/warm split an up-front
    /// atomic decision (instead of per-invoke pool probing) keeps the
    /// modeled accounting deterministic under real thread concurrency.
    pub fn acquire_environments(&self, name: &str, n: usize) -> usize {
        let mut warm = self.warm.lock().unwrap();
        let slot = warm.entry(name.to_string()).or_insert(0);
        let taken = (*slot).min(n);
        *slot -= taken;
        taken
    }

    /// Return `n` environments to the warm pool — after a fan-out,
    /// every environment that ran stays warm for the next wave.
    pub fn release_environments(&self, name: &str, n: usize) {
        *self.warm.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    /// Invoke synchronously; `modeled` overrides the billed duration for
    /// perfmodel-driven extrapolation runs.
    pub fn invoke(&self, name: &str, payload: &Bytes, modeled: Option<Duration>) -> Result<Invocation> {
        self.get(name)?; // unknown functions must not touch the warm pool
        let cold = self.acquire_environments(name, 1) == 0;
        let result = self.invoke_prepared(name, payload, modeled, cold);
        // the environment stays warm even after a handler error
        self.release_environments(name, 1);
        result
    }

    /// Invoke with the cold/warm decision already made by the caller
    /// (the state machine's deterministic first-wave accounting). Does
    /// not touch the warm pool; pair with [`Self::acquire_environments`]
    /// / [`Self::release_environments`].
    pub fn invoke_prepared(
        &self,
        name: &str,
        payload: &Bytes,
        modeled: Option<Duration>,
        cold: bool,
    ) -> Result<Invocation> {
        let spec = self.get(name)?;
        self.invocations.fetch_add(1, Ordering::Relaxed);

        let cold_start = if cold {
            self.cold_starts.fetch_add(1, Ordering::Relaxed);
            self.cold_start
        } else {
            Duration::ZERO
        };

        let _ = take_unbilled(); // drop any stale report
        let t0 = Instant::now();
        let result = (spec.handler)(payload);
        let measured = t0.elapsed();
        let unbilled = take_unbilled();

        let output = match result {
            Ok(o) => o,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };

        let billed = modeled.unwrap_or_else(|| measured.saturating_sub(unbilled));
        if billed > spec.timeout {
            self.errors.fetch_add(1, Ordering::Relaxed);
            return Err(Error::FaasTimeout {
                elapsed_ms: billed.as_millis() as u64,
                limit_ms: spec.timeout.as_millis() as u64,
            });
        }
        let billed_ms = billed.as_millis() as u64;
        let cost = pricing::invocation_cost(spec.memory_mb, billed_ms, spec.arch);
        self.billed_ms.fetch_add(billed_ms, Ordering::Relaxed);
        self.cost_microcents
            .fetch_add((cost * 1e8) as u64, Ordering::Relaxed);

        Ok(Invocation {
            function: spec.name,
            output,
            measured,
            billed,
            cold_start,
            memory_mb: spec.memory_mb,
            cost_usd: cost,
        })
    }

    pub fn stats(&self) -> PlatformStats {
        PlatformStats {
            invocations: self.invocations.load(Ordering::Relaxed),
            cold_starts: self.cold_starts.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            billed_ms: self.billed_ms.load(Ordering::Relaxed),
        }
    }

    /// Total accumulated USD billed across invocations.
    pub fn total_cost_usd(&self) -> f64 {
        self.cost_microcents.load(Ordering::Relaxed) as f64 / 1e8
    }

    /// Pre-warm `n` environments (provisioned concurrency).
    pub fn prewarm(&self, name: &str, n: usize) {
        *self.warm.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo() -> Handler {
        Arc::new(|b: &Bytes| Ok(b.clone()))
    }

    fn platform() -> FaasPlatform {
        FaasPlatform::new(Duration::from_millis(100))
    }

    #[test]
    fn register_and_invoke() {
        let p = platform();
        p.register(FunctionSpec::new("echo", 512, echo())).unwrap();
        let inv = p.invoke("echo", &Bytes::from_static(b"hi"), None).unwrap();
        assert_eq!(&inv.output[..], b"hi");
        assert!(inv.cost_usd > 0.0);
    }

    #[test]
    fn unknown_function_errors() {
        let p = platform();
        assert!(p.invoke("nope", &Bytes::new(), None).is_err());
    }

    #[test]
    fn rejects_oversized_memory() {
        let p = platform();
        let spec = FunctionSpec::new("big", MAX_MEMORY_MB + 1, echo());
        assert!(p.register(spec).is_err());
    }

    #[test]
    fn first_invoke_is_cold_then_warm() {
        let p = platform();
        p.register(FunctionSpec::new("f", 512, echo())).unwrap();
        let i1 = p.invoke("f", &Bytes::new(), None).unwrap();
        let i2 = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(i1.cold_start, Duration::from_millis(100));
        assert_eq!(i2.cold_start, Duration::ZERO);
        assert_eq!(p.stats().cold_starts, 1);
    }

    #[test]
    fn wave_acquire_release_cold_accounting() {
        let p = platform();
        p.register(FunctionSpec::new("f", 512, echo())).unwrap();
        assert_eq!(p.acquire_environments("f", 3), 0); // fresh pool: all cold
        p.release_environments("f", 3); // the wave leaves 3 warm envs
        assert_eq!(p.acquire_environments("f", 2), 2);
        p.release_environments("f", 2);
        let inv = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(inv.cold_start, Duration::ZERO);
    }

    #[test]
    fn prewarm_avoids_cold_start() {
        let p = platform();
        p.register(FunctionSpec::new("f", 512, echo())).unwrap();
        p.prewarm("f", 1);
        let inv = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(inv.cold_start, Duration::ZERO);
    }

    #[test]
    fn modeled_time_drives_billing_and_timeout() {
        let p = platform();
        p.register(
            FunctionSpec::new("f", 1024, echo())
                .with_timeout(Duration::from_secs(10)),
        )
        .unwrap();
        let inv = p
            .invoke("f", &Bytes::new(), Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(inv.billed, Duration::from_secs(5));
        // exceeding the function timeout errors (15-min class behaviour)
        let err = p.invoke("f", &Bytes::new(), Some(Duration::from_secs(11)));
        assert!(matches!(err, Err(Error::FaasTimeout { .. })));
    }

    #[test]
    fn unbilled_time_is_excluded_from_measured_billing() {
        let p = platform();
        let h: Handler = Arc::new(|b: &Bytes| {
            // report far more than the handler takes: billing saturates
            // to zero instead of going negative
            report_unbilled(Duration::from_secs(30));
            report_unbilled(Duration::from_secs(30)); // accumulates
            Ok(b.clone())
        });
        p.register(FunctionSpec::new("f", 512, h)).unwrap();
        let inv = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(inv.billed, Duration::ZERO);
        // an explicit modeled duration wins outright
        let inv = p.invoke("f", &Bytes::new(), Some(Duration::from_secs(5))).unwrap();
        assert_eq!(inv.billed, Duration::from_secs(5));
        // the report is consumed: a plain handler bills measured time
        p.register(FunctionSpec::new("plain", 512, echo())).unwrap();
        let inv = p.invoke("plain", &Bytes::new(), None).unwrap();
        assert_eq!(inv.billed, inv.measured);
    }

    #[test]
    fn handler_error_counted() {
        let p = platform();
        let failing: Handler = Arc::new(|_| Err(Error::Faas("boom".into())));
        p.register(FunctionSpec::new("f", 512, failing)).unwrap();
        assert!(p.invoke("f", &Bytes::new(), None).is_err());
        assert_eq!(p.stats().errors, 1);
    }

    #[test]
    fn cost_accumulates() {
        let p = platform();
        p.register(FunctionSpec::new("f", 2048, echo())).unwrap();
        for _ in 0..3 {
            p.invoke("f", &Bytes::new(), Some(Duration::from_secs(1))).unwrap();
        }
        let want = 3.0 * pricing::invocation_cost(2048, 1000, Arch::Arm64);
        // microcent-granular accumulator => ~1e-8 truncation per call
        assert!((p.total_cost_usd() - want).abs() < 1e-6);
    }
}
