//! AWS-Lambda-like function platform: registry, warm pool, cold starts,
//! timeout enforcement, GB-second billing.
//!
//! Handlers are in-process closures (the "deployment package"); the
//! gradient handler used by the coordinator captures the PJRT executable
//! and the object store, mirroring the paper's Lambda that pulls its
//! batch from S3 (§IV-D.1).
//!
//! Time accounting is dual:
//! - **measured** — wall time of the real handler (PJRT execution);
//! - **modeled** — a caller-supplied duration from the perfmodel for
//!   cloud-scale extrapolation. Billing uses the modeled duration when
//!   present, else the measured one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::Bytes;
use std::sync::{Mutex, RwLock};

use super::pricing::{self, Arch};
use crate::error::{Error, Result};

/// AWS Lambda's hard limits the paper designs around (§III-A, §IV-D.1).
pub const MAX_TIMEOUT: Duration = Duration::from_secs(15 * 60);
pub const MAX_MEMORY_MB: u32 = 10_240;
/// Zipped deployment package limit (paper packs PyTorch under 50 MB).
pub const MAX_ZIP_MB: u32 = 50;
/// Unzipped layers limit.
pub const MAX_UNZIPPED_MB: u32 = 250;

/// A function handler: request bytes in, response bytes out.
pub type Handler = Arc<dyn Fn(&Bytes) -> Result<Bytes> + Send + Sync>;

/// Registered function configuration.
#[derive(Clone)]
pub struct FunctionSpec {
    pub name: String,
    pub memory_mb: u32,
    pub timeout: Duration,
    pub arch: Arch,
    pub handler: Handler,
}

impl FunctionSpec {
    pub fn new(name: impl Into<String>, memory_mb: u32, handler: Handler) -> Self {
        Self {
            name: name.into(),
            memory_mb,
            timeout: MAX_TIMEOUT,
            arch: Arch::Arm64,
            handler,
        }
    }

    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

/// One finished invocation.
#[derive(Debug, Clone)]
pub struct Invocation {
    pub function: String,
    pub output: Bytes,
    /// Real handler wall time.
    pub measured: Duration,
    /// Duration used for billing/wall aggregation (modeled if supplied).
    pub billed: Duration,
    /// Cold-start latency (zero for warm starts) — affects wall time,
    /// not billing (AWS does not bill init for managed runtimes).
    pub cold_start: Duration,
    pub memory_mb: u32,
    pub cost_usd: f64,
}

impl Invocation {
    /// Wall-clock contribution of this invocation (init + execution).
    pub fn wall(&self) -> Duration {
        self.cold_start + self.billed
    }
}

/// Platform-wide counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct PlatformStats {
    pub invocations: u64,
    pub cold_starts: u64,
    pub errors: u64,
    pub billed_ms: u64,
}

/// The Lambda platform.
pub struct FaasPlatform {
    functions: RwLock<HashMap<String, FunctionSpec>>,
    /// Warm execution environments per function.
    warm: Mutex<HashMap<String, usize>>,
    cold_start: Duration,
    invocations: AtomicU64,
    cold_starts: AtomicU64,
    errors: AtomicU64,
    billed_ms: AtomicU64,
    cost_microcents: AtomicU64,
}

impl Default for FaasPlatform {
    fn default() -> Self {
        Self::new(Duration::from_millis(2500))
    }
}

impl FaasPlatform {
    /// `cold_start`: modeled init latency for a fresh environment (the
    /// paper's PyTorch-on-ARM images land in the seconds range).
    pub fn new(cold_start: Duration) -> Self {
        Self {
            functions: RwLock::new(HashMap::new()),
            warm: Mutex::new(HashMap::new()),
            cold_start,
            invocations: AtomicU64::new(0),
            cold_starts: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            billed_ms: AtomicU64::new(0),
            cost_microcents: AtomicU64::new(0),
        }
    }

    pub fn register(&self, spec: FunctionSpec) -> Result<()> {
        if spec.memory_mb > MAX_MEMORY_MB {
            return Err(Error::Faas(format!(
                "{}: {} MB exceeds the {} MB Lambda cap",
                spec.name, spec.memory_mb, MAX_MEMORY_MB
            )));
        }
        if spec.timeout > MAX_TIMEOUT {
            return Err(Error::Faas(format!(
                "{}: timeout {:?} exceeds the 15-minute Lambda cap",
                spec.name, spec.timeout
            )));
        }
        self.functions.write().unwrap().insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<FunctionSpec> {
        self.functions
            .read().unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Faas(format!("unknown function {name:?}")))
    }

    /// Invoke synchronously; `modeled` overrides the billed duration for
    /// perfmodel-driven extrapolation runs.
    pub fn invoke(&self, name: &str, payload: &Bytes, modeled: Option<Duration>) -> Result<Invocation> {
        let spec = self.get(name)?;
        self.invocations.fetch_add(1, Ordering::Relaxed);

        // warm-pool bookkeeping: take a warm environment if available,
        // otherwise this is a cold start (returned to the pool after).
        let cold = {
            let mut warm = self.warm.lock().unwrap();
            let slot = warm.entry(spec.name.clone()).or_insert(0);
            if *slot > 0 {
                *slot -= 1;
                false
            } else {
                true
            }
        };
        let cold_start = if cold {
            self.cold_starts.fetch_add(1, Ordering::Relaxed);
            self.cold_start
        } else {
            Duration::ZERO
        };

        let t0 = Instant::now();
        let result = (spec.handler)(payload);
        let measured = t0.elapsed();

        // environment becomes warm for subsequent invokes
        {
            let mut warm = self.warm.lock().unwrap();
            *warm.entry(spec.name.clone()).or_insert(0) += 1;
        }

        let output = match result {
            Ok(o) => o,
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };

        let billed = modeled.unwrap_or(measured);
        if billed > spec.timeout {
            self.errors.fetch_add(1, Ordering::Relaxed);
            return Err(Error::FaasTimeout {
                elapsed_ms: billed.as_millis() as u64,
                limit_ms: spec.timeout.as_millis() as u64,
            });
        }
        let billed_ms = billed.as_millis() as u64;
        let cost = pricing::invocation_cost(spec.memory_mb, billed_ms, spec.arch);
        self.billed_ms.fetch_add(billed_ms, Ordering::Relaxed);
        self.cost_microcents
            .fetch_add((cost * 1e8) as u64, Ordering::Relaxed);

        Ok(Invocation {
            function: spec.name,
            output,
            measured,
            billed,
            cold_start,
            memory_mb: spec.memory_mb,
            cost_usd: cost,
        })
    }

    pub fn stats(&self) -> PlatformStats {
        PlatformStats {
            invocations: self.invocations.load(Ordering::Relaxed),
            cold_starts: self.cold_starts.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            billed_ms: self.billed_ms.load(Ordering::Relaxed),
        }
    }

    /// Total accumulated USD billed across invocations.
    pub fn total_cost_usd(&self) -> f64 {
        self.cost_microcents.load(Ordering::Relaxed) as f64 / 1e8
    }

    /// Pre-warm `n` environments (provisioned concurrency).
    pub fn prewarm(&self, name: &str, n: usize) {
        *self.warm.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo() -> Handler {
        Arc::new(|b: &Bytes| Ok(b.clone()))
    }

    fn platform() -> FaasPlatform {
        FaasPlatform::new(Duration::from_millis(100))
    }

    #[test]
    fn register_and_invoke() {
        let p = platform();
        p.register(FunctionSpec::new("echo", 512, echo())).unwrap();
        let inv = p.invoke("echo", &Bytes::from_static(b"hi"), None).unwrap();
        assert_eq!(&inv.output[..], b"hi");
        assert!(inv.cost_usd > 0.0);
    }

    #[test]
    fn unknown_function_errors() {
        let p = platform();
        assert!(p.invoke("nope", &Bytes::new(), None).is_err());
    }

    #[test]
    fn rejects_oversized_memory() {
        let p = platform();
        let spec = FunctionSpec::new("big", MAX_MEMORY_MB + 1, echo());
        assert!(p.register(spec).is_err());
    }

    #[test]
    fn first_invoke_is_cold_then_warm() {
        let p = platform();
        p.register(FunctionSpec::new("f", 512, echo())).unwrap();
        let i1 = p.invoke("f", &Bytes::new(), None).unwrap();
        let i2 = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(i1.cold_start, Duration::from_millis(100));
        assert_eq!(i2.cold_start, Duration::ZERO);
        assert_eq!(p.stats().cold_starts, 1);
    }

    #[test]
    fn prewarm_avoids_cold_start() {
        let p = platform();
        p.register(FunctionSpec::new("f", 512, echo())).unwrap();
        p.prewarm("f", 1);
        let inv = p.invoke("f", &Bytes::new(), None).unwrap();
        assert_eq!(inv.cold_start, Duration::ZERO);
    }

    #[test]
    fn modeled_time_drives_billing_and_timeout() {
        let p = platform();
        p.register(
            FunctionSpec::new("f", 1024, echo())
                .with_timeout(Duration::from_secs(10)),
        )
        .unwrap();
        let inv = p
            .invoke("f", &Bytes::new(), Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(inv.billed, Duration::from_secs(5));
        // exceeding the function timeout errors (15-min class behaviour)
        let err = p.invoke("f", &Bytes::new(), Some(Duration::from_secs(11)));
        assert!(matches!(err, Err(Error::FaasTimeout { .. })));
    }

    #[test]
    fn handler_error_counted() {
        let p = platform();
        let failing: Handler = Arc::new(|_| Err(Error::Faas("boom".into())));
        p.register(FunctionSpec::new("f", 512, failing)).unwrap();
        assert!(p.invoke("f", &Bytes::new(), None).is_err());
        assert_eq!(p.stats().errors, 1);
    }

    #[test]
    fn cost_accumulates() {
        let p = platform();
        p.register(FunctionSpec::new("f", 2048, echo())).unwrap();
        for _ in 0..3 {
            p.invoke("f", &Bytes::new(), Some(Duration::from_secs(1))).unwrap();
        }
        let want = 3.0 * pricing::invocation_cost(2048, 1000, Arch::Arm64);
        // microcent-granular accumulator => ~1e-8 truncation per call
        assert!((p.total_cost_usd() - want).abs() < 1e-6);
    }
}
