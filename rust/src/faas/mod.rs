//! Serverless substrate: AWS-Lambda-like platform + Step-Functions-like
//! orchestration + Lambda pricing, dispatched over a real worker pool.
//!
//! See DESIGN.md's substitution table — this is the paper's serverless
//! layer rebuilt in-process so the gradient fan-out path is exercised by
//! real code (the handlers execute the same PJRT artifacts the peers
//! use). The [`executor`] worker pool makes Map-state fan-out physically
//! concurrent while the modeled time accounting stays deterministic, and
//! the [`scheduler`] admits every peer's branches onto that shared pool
//! with round-robin fairness, per-peer caps, and streaming pipelines.

pub mod executor;
pub mod lambda;
pub mod pricing;
pub mod scheduler;
pub mod state_machine;

pub use executor::{Executor, JobHandle, Semaphore};
pub use lambda::{
    report_unbilled, FaasPlatform, FunctionSpec, Handler, Invocation, PlatformStats,
};
pub use pricing::{invocation_cost, price_per_second, Arch};
pub use scheduler::{BranchScheduler, MapCollector, PipelinedMap, SchedulerStats};
pub use state_machine::{schedule_wall, ExecutionReport, RetryPolicy, State, StateMachine};
