//! Serverless substrate: AWS-Lambda-like platform + Step-Functions-like
//! orchestration + Lambda pricing.
//!
//! See DESIGN.md's substitution table — this is the paper's serverless
//! layer rebuilt in-process so the gradient fan-out path is exercised by
//! real code (the handlers execute the same PJRT artifacts the peers use).

pub mod lambda;
pub mod pricing;
pub mod state_machine;

pub use lambda::{FaasPlatform, FunctionSpec, Handler, Invocation, PlatformStats};
pub use pricing::{invocation_cost, price_per_second, Arch};
pub use state_machine::{schedule_wall, ExecutionReport, RetryPolicy, State, StateMachine};
