//! AWS Lambda pricing (ARM/Graviton rate — the paper deploys on a
//! "custom ARM architecture", §IV-D.1).

/// USD per GB-second, arm64 (matches the paper's Table II rates:
/// 4400 MB -> $0.0000573/s).
pub const ARM_USD_PER_GB_S: f64 = 0.0000133334;

/// USD per GB-second, x86_64 (for comparison experiments).
pub const X86_USD_PER_GB_S: f64 = 0.0000166667;

/// USD per million requests.
pub const USD_PER_1M_REQUESTS: f64 = 0.20;

/// Billing granularity: AWS bills per 1 ms.
pub const BILLING_QUANTUM_MS: u64 = 1;

/// S3-class request fee per PUT ($0.005 per 1000, standard tier).
pub const S3_USD_PER_PUT: f64 = 5.0e-6;

/// S3-class request fee per GET ($0.0004 per 1000).
pub const S3_USD_PER_GET: f64 = 4.0e-7;

/// Per-GB transfer rate on the data plane (cross-region replication
/// rate — intra-region Lambda<->S3 bandwidth itself is free, so this is
/// the geo-distributed-peers term the wire plane's compression shrinks).
pub const S3_USD_PER_GB_XREGION: f64 = 0.02;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Arm64,
    X86_64,
}

/// Per-second execution price of a function sized at `memory_mb`.
pub fn price_per_second(memory_mb: u32, arch: Arch) -> f64 {
    let rate = match arch {
        Arch::Arm64 => ARM_USD_PER_GB_S,
        Arch::X86_64 => X86_USD_PER_GB_S,
    };
    memory_mb as f64 / 1024.0 * rate
}

/// Total invocation cost: duration (rounded up to the billing quantum)
/// times the memory rate, plus the per-request fee.
pub fn invocation_cost(memory_mb: u32, billed_ms: u64, arch: Arch) -> f64 {
    let quantized = billed_ms.div_ceil(BILLING_QUANTUM_MS) * BILLING_QUANTUM_MS;
    price_per_second(memory_mb, arch) * quantized as f64 / 1000.0
        + USD_PER_1M_REQUESTS / 1_000_000.0
}

/// Data-plane transfer cost of a run: request fees for `puts`/`gets`
/// plus the per-GB rate on the bytes that actually crossed the wire.
/// Fed by the wire plane's `wire.bytes_wire` and the store's put/get
/// counters — the cost term compression moves, orthogonal to
/// [`invocation_cost`]'s compute term.
pub fn transfer_cost(wire_bytes: u64, puts: u64, gets: u64) -> f64 {
    puts as f64 * S3_USD_PER_PUT
        + gets as f64 * S3_USD_PER_GET
        + wire_bytes as f64 / 1e9 * S3_USD_PER_GB_XREGION
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table2_rates() {
        // Table II "Estimated Lambda Cost (USD / seconds)" per memory size
        let cases = [
            (4400u32, 0.0000573f64),
            (2800, 0.0000362),
            (1800, 0.0000233),
            (1700, 0.0000220),
        ];
        for (mem, want) in cases {
            let got = price_per_second(mem, Arch::Arm64);
            assert!(
                (got - want).abs() / want < 0.02,
                "mem {mem}: got {got}, paper {want}"
            );
        }
    }

    #[test]
    fn x86_is_pricier() {
        assert!(
            price_per_second(1024, Arch::X86_64) > price_per_second(1024, Arch::Arm64)
        );
    }

    #[test]
    fn invocation_includes_request_fee() {
        let c = invocation_cost(1024, 0, Arch::Arm64);
        assert!((c - 0.2e-6).abs() < 1e-12);
    }

    #[test]
    fn transfer_cost_terms() {
        // zero bytes: pure request fees
        let fees = transfer_cost(0, 10, 100);
        assert!((fees - (10.0 * S3_USD_PER_PUT + 100.0 * S3_USD_PER_GET)).abs() < 1e-15);
        // bytes term is linear at the cross-region rate
        let a = transfer_cost(1_000_000_000, 0, 0);
        assert!((a - S3_USD_PER_GB_XREGION).abs() < 1e-12);
        // compression moves the cost: a qsgd:16 plane (18.75% of raw)
        // must be cheaper for the same request counts
        let dense = transfer_cost(1_000_004, 16, 64);
        let quant = transfer_cost(187_510, 16, 64);
        assert!(quant < dense);
    }

    #[test]
    fn invocation_scales_linearly() {
        let c1 = invocation_cost(2048, 1000, Arch::Arm64);
        let c2 = invocation_cost(2048, 2000, Arch::Arm64);
        let fee = USD_PER_1M_REQUESTS / 1e6;
        assert!(((c2 - fee) - 2.0 * (c1 - fee)).abs() < 1e-12);
    }
}
