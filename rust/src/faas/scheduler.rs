//! The cluster-wide branch scheduler: every peer's Map branches are
//! admitted onto the *shared* worker pool through one gate, instead of
//! each peer fanning out independently and racing for workers.
//!
//! Motivation (SPIRT, arXiv 2309.14148; "Towards Demystifying Serverless
//! ML Training", arXiv 2105.07806): end-to-end serverless training time
//! is dominated by communication/staging overlap, and per-peer batch
//! queues feeding a shared worker fleet beat lockstep per-peer waves.
//! Two pieces implement that here:
//!
//! - [`BranchScheduler`] — per-peer admission lanes over the
//!   [`Executor`]. Dispatch is round-robin across peers (`fair`), each
//!   lane has an in-flight cap (the peer's `lambda_concurrency`, now an
//!   *admission limit* rather than a per-fan-out wave size), and the
//!   total released to the pool never exceeds the worker count — so the
//!   scheduler, not the executor's FIFO, owns all queueing and the
//!   queue-depth/utilization stats are meaningful.
//! - [`PipelinedMap`] — a streaming Map state: branches are submitted
//!   one by one as their inputs become ready (no "upload everything,
//!   then invoke" barrier) and outputs are yielded *in branch order* as
//!   they land, so collection overlaps the remaining uploads and
//!   handler waves. The modeled accounting (wall / billed / cost /
//!   cold-start waves) reproduces [`StateMachine::execute_with`]
//!   byte-for-byte; only the measured wall changes.
//!
//! Branches may carry a **generation** tag (the epoch / param version —
//! see [`PipelinedMap::with_generation`]). Once epochs overlap in
//! cross-epoch offload mode, a peer's lane can hold branches of two
//! generations at once; lanes stay FIFO (a new epoch can never overtake
//! the old epoch's tail within a lane), round-robin fairness across
//! peers is generation-agnostic, and the per-generation occupancy is
//! tracked so [`BranchScheduler::await_generation_drained`] can act as
//! a drain barrier before a generation's scratch is swept. With the
//! engine's execution batcher on (`--exec-batch > 1`), the scheduler
//! additionally **coalesces releases** ([`BranchScheduler::set_coalesce`]):
//! up to a burst of same-generation branches from one lane go to the
//! pool back-to-back, so they meet in the batcher and fuse instead of
//! arriving interleaved with other generations.
//!
//! Two control-plane extensions ride on those lanes:
//!
//! - **Adaptive exec-batch** (`--exec-batch auto`): an
//!   [`auto_exec_batch`] feedback controller, ticked on every submit
//!   and completion ([`BranchScheduler::enable_autotune`]), retargets
//!   the coalesce burst *and* the engine's effective fused-group size
//!   from the live queue-depth/utilization counters — ramping up under
//!   deep backlogs, backing off toward unfused when lanes are starved.
//! - **Priority lanes**: [`BranchScheduler::submit_detached_prio`]
//!   queues a branch (validation / convergence work) at the FRONT of
//!   its lane and rotation, and [`BranchScheduler::await_generation_drained`]
//!   promotes a straggling generation's lane to the front of the
//!   rotation while a collector blocks on its tail. Both are counted
//!   as `lane_promotions` in [`SchedulerStats`].
//!
//! ```
//! use std::sync::Arc;
//! use p2pless::faas::{BranchScheduler, Executor};
//!
//! let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
//! sched.register_peer(0, 4); // lane with an in-flight cap of 4
//! let answer = sched.submit(0, || 21 * 2);
//! assert_eq!(answer.join().unwrap(), 42);
//! assert_eq!(sched.stats().per_peer_served, vec![(0, 1)]);
//! ```
//!
//! [`StateMachine::execute_with`]: super::state_machine::StateMachine::execute_with

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use super::executor::{panic_message, Executor, JobHandle};
use super::lambda::{FaasPlatform, Invocation};
use super::state_machine::{invoke_with_retry, schedule_wall, ExecutionReport, RetryPolicy};
use crate::error::{Error, Result};
use crate::util::Bytes;

type DetachedJob = Box<dyn FnOnce() + Send + 'static>;

/// One step of the `--exec-batch auto` feedback controller: given the
/// current effective batch target and the scheduler's live signals,
/// return the next target in `1..=max`.
///
/// The policy is deliberately simple and hysteresis-free in each
/// direction (multiplicative ramp, additive back-off — AIMD inverted
/// for a sizing knob):
///
/// - a backlog at least as deep as the pool means branches are waiting
///   on slots anyway, so bigger fused groups cost no latency — double
///   toward `max`;
/// - an empty backlog with idle workers means a collecting group would
///   only fill by waiting out its window — step down toward 1 (unfused);
/// - anything in between holds.
pub fn auto_exec_batch(cur: usize, queued: usize, busy: usize, pool: usize, max: usize) -> usize {
    let max = max.max(1);
    let pool = pool.max(1);
    if queued >= pool {
        (cur.max(1).saturating_mul(2)).min(max)
    } else if queued == 0 && busy < pool {
        cur.saturating_sub(1).max(1)
    } else {
        cur.clamp(1, max)
    }
}

/// Live state of [`BranchScheduler::enable_autotune`].
struct AutoTune {
    /// The `--exec-batch` ceiling.
    max: usize,
    /// Last target handed to `on_change`.
    target: usize,
    /// Applies a new target to the engine (batcher effective size).
    on_change: Box<dyn Fn(usize) + Send + Sync>,
}

/// One peer's admission lane. Jobs carry an optional generation tag
/// (the epoch / param version) so overlapping epochs are observable and
/// drainable per generation; the queue itself stays FIFO, which is what
/// keeps an old epoch's tail ahead of a newly dispatched epoch.
struct Lane {
    queue: VecDeque<(Option<u64>, DetachedJob)>,
    in_flight: usize,
    cap: usize,
    served: u64,
    /// Evicted lanes (dead peers) never dispatch; queued work was
    /// dropped at eviction and new submissions are refused.
    evicted: bool,
    /// Queued branches per generation (tagged submissions only).
    gen_queued: BTreeMap<u64, usize>,
    /// Released-to-pool branches per generation (tagged only).
    gen_inflight: BTreeMap<u64, usize>,
}

impl Lane {
    fn new(cap: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            in_flight: 0,
            cap: cap.max(1),
            served: 0,
            evicted: false,
            gen_queued: BTreeMap::new(),
            gen_inflight: BTreeMap::new(),
        }
    }

    /// Branches of `generation` still queued or in flight on this lane.
    fn generation_live(&self, generation: u64) -> usize {
        self.gen_queued.get(&generation).copied().unwrap_or(0)
            + self.gen_inflight.get(&generation).copied().unwrap_or(0)
    }
}

struct SchedState {
    lanes: BTreeMap<usize, Lane>,
    /// Round-robin rotation of peer ranks (fair mode).
    rr: VecDeque<usize>,
    paused: bool,
    submitted: u64,
    completed: u64,
    queued: usize,
    peak_queued: usize,
    in_flight_total: usize,
    peak_in_flight: usize,
    /// In-flight branches per generation, across every lane. The map's
    /// cardinality is "how many epochs overlap on the pool right now".
    inflight_gens: BTreeMap<u64, usize>,
    /// High-water mark of distinct generations simultaneously in flight
    /// (1 in steady state; 2 once cross-epoch dispatch overlaps epochs).
    peak_inflight_gens: usize,
    /// Active same-generation release burst: (rank, generation,
    /// releases left). See [`BranchScheduler::set_coalesce`].
    burst: Option<(usize, u64, usize)>,
    /// Priority-lane events: front-of-lane submissions plus straggler
    /// lane promotions at the drain barrier.
    lane_promotions: u64,
    /// Lanes evicted because their peer was declared dead.
    lane_evictions: u64,
    /// Peer rank per dispatch, in dispatch order (tests/fairness audits;
    /// off by default — it grows with every branch).
    dispatch_log: Option<Vec<usize>>,
}

impl SchedState {
    /// Pop the next dispatchable job under the fairness policy, updating
    /// lane + aggregate accounting. `pool_cap` bounds the total released
    /// to the executor so the scheduler owns all queueing; `burst_cap`
    /// (> 1) keeps releasing same-generation branches from the last
    /// picked lane so they reach the engine's execution batcher together.
    fn next_ready(
        &mut self,
        fair: bool,
        pool_cap: usize,
        burst_cap: usize,
    ) -> Option<(usize, Option<u64>, DetachedJob)> {
        if self.in_flight_total >= pool_cap {
            return None;
        }
        let eligible =
            |lane: &Lane| !lane.evicted && !lane.queue.is_empty() && lane.in_flight < lane.cap;
        // coalescing hint: if the last release opened a same-generation
        // burst and the lane's next branch continues it, skip the
        // rotation — one epoch's branches then hit the worker pool (and
        // the engine batcher) back-to-back instead of interleaved with
        // other peers' generations
        let mut continued = false;
        let mut pick = None;
        if burst_cap > 1 {
            if let Some((rank, generation, left)) = self.burst {
                let continues = left > 0
                    && self
                        .lanes
                        .get(&rank)
                        .filter(|lane| eligible(lane))
                        .and_then(|lane| lane.queue.front())
                        .map(|(g, _)| *g == Some(generation))
                        .unwrap_or(false);
                if continues {
                    pick = Some(rank);
                    continued = true;
                }
            }
        }
        if pick.is_none() {
            self.burst = None;
            pick = if fair {
                let mut found = None;
                for _ in 0..self.rr.len() {
                    let rank = self.rr.pop_front().unwrap();
                    self.rr.push_back(rank);
                    if self.lanes.get(&rank).map(eligible).unwrap_or(false) {
                        found = Some(rank);
                        break;
                    }
                }
                found
            } else {
                // unfair baseline: lowest rank with work always wins
                self.lanes
                    .iter()
                    .find(|(_, lane)| eligible(lane))
                    .map(|(&rank, _)| rank)
            };
        }
        let pick = pick?;
        let lane = self.lanes.get_mut(&pick).unwrap();
        let (generation, job) = lane.queue.pop_front().unwrap();
        lane.in_flight += 1;
        lane.served += 1;
        if let Some(g) = generation {
            if let Some(c) = lane.gen_queued.get_mut(&g) {
                *c -= 1;
                if *c == 0 {
                    lane.gen_queued.remove(&g);
                }
            }
            *lane.gen_inflight.entry(g).or_insert(0) += 1;
        }
        self.queued -= 1;
        self.in_flight_total += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight_total);
        if let Some(g) = generation {
            *self.inflight_gens.entry(g).or_insert(0) += 1;
            self.peak_inflight_gens = self.peak_inflight_gens.max(self.inflight_gens.len());
        }
        if let Some(log) = self.dispatch_log.as_mut() {
            log.push(pick);
        }
        // open (or continue) the same-generation burst for the next call
        self.burst = match generation {
            Some(g) if burst_cap > 1 => {
                let left = if continued {
                    self.burst
                        .map(|(_, _, l)| l.saturating_sub(1))
                        .unwrap_or(0)
                } else {
                    burst_cap - 1
                };
                Some((pick, g, left))
            }
            _ => None,
        };
        Some((pick, generation, job))
    }
}

/// Utilization snapshot of the scheduler (plus its executor).
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Branches admitted into lanes so far.
    pub submitted: u64,
    /// Branches that finished executing.
    pub completed: u64,
    /// Branches currently queued in lanes (not yet on the pool).
    pub queued: usize,
    /// High-water mark of `queued`.
    pub peak_queued: usize,
    /// Branches currently released to the pool.
    pub in_flight: usize,
    /// High-water mark of `in_flight`.
    pub peak_in_flight: usize,
    /// (rank, branches served) per registered lane.
    pub per_peer_served: Vec<(usize, u64)>,
    /// Distinct generations currently in flight (tagged branches only).
    pub inflight_generations: usize,
    /// High-water mark of distinct generations simultaneously in flight
    /// — the cross-epoch overlap witness (2 when epochs overlap).
    pub peak_inflight_generations: usize,
    /// Worker threads in the underlying executor.
    pub exec_threads: usize,
    /// High-water mark of simultaneously busy executor workers.
    pub exec_peak_busy: usize,
    /// Priority-lane events: front-of-lane submissions
    /// ([`BranchScheduler::submit_detached_prio`]) plus straggler lane
    /// promotions at the generation drain barrier.
    pub lane_promotions: u64,
    /// Lanes evicted because their peer was declared dead
    /// ([`BranchScheduler::evict_peer`]).
    pub lane_evictions: u64,
}

/// Cluster-wide admission control over the shared [`Executor`].
pub struct BranchScheduler {
    executor: Arc<Executor>,
    fair: bool,
    /// Same-generation release burst size (<= 1 off). See
    /// [`Self::set_coalesce`].
    coalesce: AtomicUsize,
    /// Self-handle: dispatched jobs carry a strong clone so completion
    /// bookkeeping can re-pump the queue from a worker thread.
    me: Weak<BranchScheduler>,
    state: Mutex<SchedState>,
    /// Signalled on every branch completion; the generation drain
    /// barrier parks here.
    drained: Condvar,
    /// `--exec-batch auto` controller; `None` for fixed knobs. Lock
    /// order: `state` before `autotune`, never the reverse.
    autotune: Mutex<Option<AutoTune>>,
}

impl BranchScheduler {
    /// `fair = true` dispatches round-robin across peer lanes; `false`
    /// is the greedy lowest-rank-first baseline (observably unfair).
    pub fn new(executor: Arc<Executor>, fair: bool) -> Arc<Self> {
        Arc::new_cyclic(|me| Self {
            executor,
            fair,
            coalesce: AtomicUsize::new(1),
            me: me.clone(),
            state: Mutex::new(SchedState {
                lanes: BTreeMap::new(),
                rr: VecDeque::new(),
                paused: false,
                submitted: 0,
                completed: 0,
                queued: 0,
                peak_queued: 0,
                in_flight_total: 0,
                peak_in_flight: 0,
                inflight_gens: BTreeMap::new(),
                peak_inflight_gens: 0,
                burst: None,
                lane_promotions: 0,
                lane_evictions: 0,
                dispatch_log: None,
            }),
            drained: Condvar::new(),
            autotune: Mutex::new(None),
        })
    }

    /// Turn on the `--exec-batch auto` controller: on every submit and
    /// completion, [`auto_exec_batch`] recomputes the effective fused
    /// batch target from the live queue depth / pool utilization, and a
    /// changed target is applied to both this scheduler's coalesce
    /// burst and (through `on_change`) the engine's effective group
    /// size. `max` is the `--exec-batch` ceiling; the controller starts
    /// at 1 (unfused) and ramps only when backlog evidence arrives.
    pub fn enable_autotune(&self, max: usize, on_change: Box<dyn Fn(usize) + Send + Sync>) {
        let start = 1;
        self.coalesce.store(start, Ordering::Relaxed);
        on_change(start);
        *self.autotune.lock().unwrap() =
            Some(AutoTune { max: max.max(1), target: start, on_change });
    }

    /// One controller step (no-op unless [`Self::enable_autotune`]).
    fn autotune_tick(&self) {
        // signals are read under the state lock, the decision applied
        // under the autotune lock — in that order, matching every other
        // path that takes both
        let (queued, busy) = {
            let st = self.state.lock().unwrap();
            (st.queued, st.in_flight_total)
        };
        let pool = self.executor.threads();
        let mut slot = self.autotune.lock().unwrap();
        if let Some(at) = slot.as_mut() {
            let next = auto_exec_batch(at.target, queued, busy, pool, at.max);
            if next != at.target {
                at.target = next;
                self.coalesce.store(next, Ordering::Relaxed);
                (at.on_change)(next);
            }
        }
    }

    /// Enable same-generation branch coalescing: once a tagged branch of
    /// `(rank, generation)` is released, up to `burst - 1` further
    /// branches continuing that generation on the same lane are released
    /// before the round-robin rotation resumes. The cluster sets this to
    /// `--exec-batch`, so a peer's Map branches arrive at the engine's
    /// execution batcher together instead of interleaved with other
    /// peers' generations — which is what lets fused groups fill.
    /// Fairness degrades gracefully from per-branch to per-burst
    /// rotation; `burst <= 1` (the default) is strict round-robin.
    pub fn set_coalesce(&self, burst: usize) {
        self.coalesce.store(burst.max(1), Ordering::Relaxed);
    }

    /// Record the peer rank of every dispatch (fairness audits / tests).
    /// Enable before submitting; the log grows with every branch.
    pub fn enable_dispatch_log(&self) {
        let mut st = self.state.lock().unwrap();
        if st.dispatch_log.is_none() {
            st.dispatch_log = Some(Vec::new());
        }
    }

    pub fn is_fair(&self) -> bool {
        self.fair
    }

    /// The pool this scheduler admits onto.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// Declare `rank`'s lane with an in-flight admission cap (clamped to
    /// >= 1). Submitting to an undeclared rank auto-registers the lane
    /// with an unbounded cap (the pool width still binds).
    pub fn register_peer(&self, rank: usize, cap: usize) {
        let mut st = self.state.lock().unwrap();
        match st.lanes.get_mut(&rank) {
            Some(lane) => lane.cap = cap.max(1),
            None => {
                st.lanes.insert(rank, Lane::new(cap));
                st.rr.push_back(rank);
            }
        }
    }

    /// Evict a dead peer's lane: queued (undispatched) branches are
    /// dropped — their result receivers observe a disconnect — the lane
    /// is removed from dispatch, and later submissions to it are
    /// refused. Branches already released to the pool drain naturally.
    /// Called by the cluster after the dead peer's thread has exited,
    /// so nothing is concurrently collecting the dropped branches.
    /// Returns the number of queued branches dropped.
    pub fn evict_peer(&self, rank: usize) -> usize {
        let dropped = {
            let mut st = self.state.lock().unwrap();
            let Some(lane) = st.lanes.get_mut(&rank) else {
                return 0;
            };
            if lane.evicted {
                return 0;
            }
            lane.evicted = true;
            let dropped = lane.queue.len();
            lane.queue.clear();
            lane.gen_queued.clear();
            st.queued -= dropped;
            st.lane_evictions += 1;
            // a burst pinned to this lane must not stall the rotation
            if st.burst.map(|(r, _, _)| r) == Some(rank) {
                st.burst = None;
            }
            dropped
        };
        // generation occupancy changed: wake drain barriers, then hand
        // the rotation to surviving lanes
        self.drained.notify_all();
        self.pump();
        dropped
    }

    /// Undo [`Self::evict_peer`] (a re-admitted peer in a future
    /// elastic-join flow); the lane resumes dispatching new work.
    pub fn readmit_peer(&self, rank: usize) {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(lane) = st.lanes.get_mut(&rank) {
                lane.evicted = false;
            }
        }
        self.pump();
    }

    /// Hold all dispatch (queued branches accumulate in lanes).
    pub fn pause(&self) {
        self.state.lock().unwrap().paused = true;
    }

    /// Resume dispatch and drain whatever is eligible.
    pub fn resume(&self) {
        self.state.lock().unwrap().paused = false;
        self.pump();
    }

    /// Admit a fire-and-forget branch into `rank`'s lane. The job runs
    /// on the shared pool once admission (per-peer cap, pool width,
    /// round-robin turn) allows; panics inside `f` are contained.
    pub fn submit_detached(&self, rank: usize, f: impl FnOnce() + Send + 'static) {
        self.submit_detached_tagged(rank, None, f)
    }

    /// [`Self::submit_detached`] with a generation tag (the epoch /
    /// param version). Tagged branches are counted per generation so
    /// overlapping epochs show up in [`SchedulerStats`] and can be
    /// awaited by [`Self::await_generation_drained`].
    pub fn submit_detached_tagged(
        &self,
        rank: usize,
        generation: Option<u64>,
        f: impl FnOnce() + Send + 'static,
    ) {
        {
            let mut st = self.state.lock().unwrap();
            if !st.lanes.contains_key(&rank) {
                st.lanes.insert(rank, Lane::new(usize::MAX));
                st.rr.push_back(rank);
            }
            let lane = st.lanes.get_mut(&rank).unwrap();
            if lane.evicted {
                // dead peer: drop the job; its receiver sees a disconnect
                return;
            }
            lane.queue.push_back((generation, Box::new(f)));
            if let Some(g) = generation {
                *lane.gen_queued.entry(g).or_insert(0) += 1;
            }
            st.submitted += 1;
            st.queued += 1;
            st.peak_queued = st.peak_queued.max(st.queued);
        }
        self.autotune_tick();
        self.pump();
    }

    /// [`Self::submit_detached_tagged`], but the branch is queued at the
    /// FRONT of its lane and the lane moves to the front of the
    /// round-robin rotation — the priority path for work the whole
    /// cluster waits on (the leader's validation / convergence branch
    /// must not sit behind a full epoch of gradient branches). Counted
    /// in [`SchedulerStats::lane_promotions`] whenever it actually
    /// overtakes queued work. In-flight caps and pool width still bind:
    /// priority reorders the queue, it never over-admits.
    pub fn submit_detached_prio(
        &self,
        rank: usize,
        generation: Option<u64>,
        f: impl FnOnce() + Send + 'static,
    ) {
        {
            let mut st = self.state.lock().unwrap();
            if !st.lanes.contains_key(&rank) {
                st.lanes.insert(rank, Lane::new(usize::MAX));
                st.rr.push_back(rank);
            }
            let overtakes = st.queued > 0;
            let lane = st.lanes.get_mut(&rank).unwrap();
            if lane.evicted {
                return;
            }
            lane.queue.push_front((generation, Box::new(f)));
            if let Some(g) = generation {
                *lane.gen_queued.entry(g).or_insert(0) += 1;
            }
            st.submitted += 1;
            st.queued += 1;
            st.peak_queued = st.peak_queued.max(st.queued);
            if st.rr.front() != Some(&rank) {
                st.rr.retain(|&r| r != rank);
                st.rr.push_front(rank);
            }
            // a priority branch also cuts any open release burst: the
            // next free slot must not keep streaming another lane's
            // generation past it
            st.burst = None;
            if overtakes {
                st.lane_promotions += 1;
            }
        }
        self.autotune_tick();
        self.pump();
    }

    /// [`Self::submit`] through the priority path (see
    /// [`Self::submit_detached_prio`]).
    pub fn submit_prio<T, F>(&self, rank: usize, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, handle) = JobHandle::channel();
        self.submit_detached_prio(rank, None, move || {
            let out = catch_unwind(AssertUnwindSafe(f)).map_err(|p| panic_message(&*p));
            let _ = tx.send(out);
        });
        handle
    }

    /// Drain barrier: block until none of `rank`'s branches tagged with
    /// `generation` are queued or in flight. Cross-epoch mode uses this
    /// before sweeping a generation's store scratch, so the sweep can
    /// never race a tail branch that still reads the old params.
    /// Returns immediately for unknown lanes or already-drained
    /// generations.
    pub fn await_generation_drained(&self, rank: usize, generation: u64) {
        let mut st = self.state.lock().unwrap();
        // straggler priority: a collector is now blocked on this
        // generation's tail, so any of its branches still *queued* are
        // the cluster's critical path — move the lane to the front of
        // the rotation so they win the next free slots. Within the
        // lane FIFO already orders the old generation first.
        let straggling = st
            .lanes
            .get(&rank)
            .and_then(|lane| lane.gen_queued.get(&generation))
            .copied()
            .unwrap_or(0)
            > 0;
        if straggling && st.rr.front() != Some(&rank) {
            st.rr.retain(|&r| r != rank);
            st.rr.push_front(rank);
            st.lane_promotions += 1;
        }
        while st
            .lanes
            .get(&rank)
            .map(|lane| lane.generation_live(generation))
            .unwrap_or(0)
            > 0
        {
            st = self.drained.wait(st).unwrap();
        }
    }

    /// Branches of `(rank, generation)` still queued or in flight.
    pub fn generation_live(&self, rank: usize, generation: u64) -> usize {
        self.state
            .lock()
            .unwrap()
            .lanes
            .get(&rank)
            .map(|lane| lane.generation_live(generation))
            .unwrap_or(0)
    }

    /// Admit a branch and get a handle for its result (panics surface as
    /// [`Error::Faas`] on join, matching [`Executor::submit`]).
    pub fn submit<T, F>(&self, rank: usize, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, handle) = JobHandle::channel();
        self.submit_detached(rank, move || {
            let out = catch_unwind(AssertUnwindSafe(f)).map_err(|p| panic_message(&*p));
            // receiver may have been dropped by an abandoning caller
            let _ = tx.send(out);
        });
        handle
    }

    /// Release every eligible queued branch to the pool.
    fn pump(&self) {
        loop {
            let (rank, generation, job) = {
                let mut st = self.state.lock().unwrap();
                if st.paused {
                    return;
                }
                let burst = self.coalesce.load(Ordering::Relaxed);
                match st.next_ready(self.fair, self.executor.threads(), burst) {
                    Some(next) => next,
                    None => return,
                }
            };
            let sched = self.me.upgrade().expect("scheduler alive while dispatching");
            // the handle is dropped: completion bookkeeping happens in
            // the wrapper, and result delivery (if any) inside `job`
            drop(self.executor.submit(move || {
                let _ = catch_unwind(AssertUnwindSafe(job));
                sched.complete(rank, generation);
            }));
        }
    }

    fn complete(&self, rank: usize, generation: Option<u64>) {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(lane) = st.lanes.get_mut(&rank) {
                lane.in_flight -= 1;
                if let Some(g) = generation {
                    if let Some(c) = lane.gen_inflight.get_mut(&g) {
                        *c -= 1;
                        if *c == 0 {
                            lane.gen_inflight.remove(&g);
                        }
                    }
                }
            }
            st.in_flight_total -= 1;
            st.completed += 1;
            if let Some(g) = generation {
                if let Some(c) = st.inflight_gens.get_mut(&g) {
                    *c -= 1;
                    if *c == 0 {
                        st.inflight_gens.remove(&g);
                    }
                }
            }
        }
        // wake any drain barrier, then hand the freed slot to the next
        // eligible branch
        self.drained.notify_all();
        self.autotune_tick();
        self.pump();
    }

    pub fn stats(&self) -> SchedulerStats {
        let st = self.state.lock().unwrap();
        SchedulerStats {
            submitted: st.submitted,
            completed: st.completed,
            queued: st.queued,
            peak_queued: st.peak_queued,
            in_flight: st.in_flight_total,
            peak_in_flight: st.peak_in_flight,
            per_peer_served: st.lanes.iter().map(|(&r, l)| (r, l.served)).collect(),
            inflight_generations: st.inflight_gens.len(),
            peak_inflight_generations: st.peak_inflight_gens,
            exec_threads: self.executor.threads(),
            exec_peak_busy: self.executor.peak_busy(),
            lane_promotions: st.lane_promotions,
            lane_evictions: st.lane_evictions,
        }
    }

    /// Dispatch order (peer rank per dispatch); empty unless
    /// [`Self::enable_dispatch_log`] was called.
    pub fn dispatch_log(&self) -> Vec<usize> {
        self.state
            .lock()
            .unwrap()
            .dispatch_log
            .clone()
            .unwrap_or_default()
    }
}

/// Deterministic aggregation of Map-branch landings. Branches may land
/// in any order; consumption ([`Self::pop_ready`]) is forced into branch
/// -index order, so the fold of billed / cost / wall / retries is the
/// exact sequence [`StateMachine::execute_with`] produces when joining
/// handles in submission order — byte-identical modeled numbers.
///
/// [`StateMachine::execute_with`]: super::state_machine::StateMachine::execute_with
#[derive(Default)]
pub struct MapCollector {
    concurrency: usize,
    /// Fold quorum `k`: only the first `k` branches (by branch index)
    /// are folded into the wall and yielded; the rest are stragglers —
    /// executed and billed, but off the modeled critical path. 0 = all.
    quorum: usize,
    pending: BTreeMap<usize, (Result<Invocation>, u32)>,
    next: usize,
    landed: usize,
    yielded: usize,
    stragglers: usize,
    walls: Vec<Duration>,
    billed: Duration,
    cost_usd: f64,
    invocations: usize,
    cold_starts: usize,
    retries: usize,
    first_err: Option<Error>,
}

impl MapCollector {
    pub fn new(concurrency: usize) -> Self {
        Self { concurrency: concurrency.max(1), ..Default::default() }
    }

    /// Fold only the first `k` branches (by branch index) into the
    /// modeled wall / yielded outputs; later branches are counted as
    /// [`ExecutionReport::stragglers`]. Deterministic by construction —
    /// "first k by index", not "first k to land", so the folded
    /// gradient is identical across pool sizes and timings. `k = 0`
    /// (the default) folds everything.
    pub fn with_quorum(mut self, k: usize) -> Self {
        self.set_quorum(k);
        self
    }

    /// In-place form of [`Self::with_quorum`].
    pub fn set_quorum(&mut self, k: usize) {
        self.quorum = k;
    }

    /// Branches landed so far (any order).
    pub fn landed(&self) -> usize {
        self.landed
    }

    /// Record branch `idx`'s outcome (`attempts` as returned by the
    /// retry loop).
    pub fn push(&mut self, idx: usize, outcome: (Result<Invocation>, u32)) {
        self.landed += 1;
        self.pending.insert(idx, outcome);
    }

    /// Yield the next in-order successful output, folding its stats.
    /// Failed branches are folded (retries, first error) and skipped.
    /// `None` means the next branch has not landed yet (or everything
    /// landed so far is consumed).
    pub fn pop_ready(&mut self) -> Option<(usize, Bytes)> {
        loop {
            let (res, attempts) = self.pending.remove(&self.next)?;
            let idx = self.next;
            self.next += 1;
            self.retries += attempts.saturating_sub(1) as usize;
            match res {
                Ok(inv) => {
                    self.invocations += 1;
                    if !inv.cold_start.is_zero() {
                        self.cold_starts += 1;
                    }
                    self.billed += inv.billed;
                    self.cost_usd += inv.cost_usd;
                    if self.quorum > 0 && self.yielded >= self.quorum {
                        // straggler: billed honestly, but neither on the
                        // modeled critical path nor in the fold
                        self.stragglers += 1;
                        continue;
                    }
                    self.yielded += 1;
                    self.walls.push(inv.wall());
                    return Some((idx, inv.output));
                }
                Err(e) => {
                    if self.first_err.is_none() {
                        self.first_err = Some(e);
                    }
                }
            }
        }
    }

    /// Consume any un-popped outputs and produce the aggregate report
    /// (`measured_wall` is left zero — the caller owns that clock).
    /// The first branch error, if any, wins over the report.
    pub fn finish(mut self) -> Result<ExecutionReport> {
        while self.pop_ready().is_some() {}
        if let Some(e) = self.first_err.take() {
            return Err(e);
        }
        Ok(ExecutionReport {
            outputs: Vec::new(),
            wall: schedule_wall(&self.walls, self.concurrency),
            measured_wall: Duration::ZERO,
            billed: self.billed,
            cost_usd: self.cost_usd,
            invocations: self.invocations,
            cold_starts: self.cold_starts,
            retries: self.retries,
            stragglers: self.stragglers,
        })
    }
}

/// One branch landing: index, the moment the worker finished it (so the
/// measured wall ends at the last landing even when the caller collects
/// much later — cross-epoch mode drains the channel only after the
/// inter-epoch coordination gap), and the invocation outcome.
type Landing = (usize, Instant, (Result<Invocation>, u32));

/// A streaming Map state over the [`BranchScheduler`]: submit branch
/// payloads as their inputs become ready, consume outputs (in branch
/// order) while later branches are still uploading or executing.
///
/// Cold-start accounting matches the staged Map exactly: the first
/// `min(total, concurrency)` branches form the cold wave, decided up
/// front — so modeled numbers do not depend on pool size or timing.
pub struct PipelinedMap {
    scheduler: Arc<BranchScheduler>,
    platform: Arc<FaasPlatform>,
    function: String,
    peer: usize,
    retry: RetryPolicy,
    total: usize,
    first_wave: usize,
    warm: usize,
    submitted: usize,
    /// Generation tag stamped on every scheduler submission (the epoch
    /// / param version in cross-epoch mode; None = untagged).
    generation: Option<u64>,
    tx: Sender<Landing>,
    rx: Receiver<Landing>,
    collector: MapCollector,
    t0: Instant,
    /// Latest branch-landing instant seen so far (drives measured_wall).
    last_landing: Option<Instant>,
    finished: bool,
}

impl PipelinedMap {
    /// Start a pipelined fan-out of `total` branches of `function` for
    /// peer `rank`. Reserves the cold/warm wave split immediately
    /// (fail-fast on unknown functions, before touching the warm pool).
    pub fn new(
        scheduler: Arc<BranchScheduler>,
        platform: Arc<FaasPlatform>,
        rank: usize,
        function: &str,
        total: usize,
        concurrency: usize,
        retry: RetryPolicy,
    ) -> Result<Self> {
        platform.get(function)?;
        let first_wave = total.min(concurrency.max(1));
        let warm = platform.acquire_environments(function, first_wave);
        let (tx, rx) = channel();
        Ok(Self {
            scheduler,
            platform,
            function: function.to_string(),
            peer: rank,
            retry,
            total,
            first_wave,
            warm,
            submitted: 0,
            generation: None,
            tx,
            rx,
            collector: MapCollector::new(concurrency),
            t0: Instant::now(),
            last_landing: None,
            finished: false,
        })
    }

    /// Tag every branch of this fan-out with `generation` (the epoch /
    /// param version). Must be set before the first [`Self::submit`];
    /// the scheduler then tracks this fan-out's queue/in-flight
    /// occupancy per generation, which is what makes cross-epoch
    /// overlap observable and drainable.
    pub fn with_generation(mut self, generation: u64) -> Self {
        assert_eq!(self.submitted, 0, "set the generation before submitting");
        self.generation = Some(generation);
        self
    }

    /// Apply a fold quorum to this fan-out's collector (see
    /// [`MapCollector::with_quorum`]); `k = 0` folds everything.
    pub fn with_quorum(mut self, k: usize) -> Self {
        self.collector.set_quorum(k);
        self
    }

    /// Branches submitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Submit the next branch (branch index = call order). `modeled`
    /// overrides billed time for perfmodel-driven runs, exactly like the
    /// Map state's modeled vector.
    pub fn submit(&mut self, payload: Bytes, modeled: Option<Duration>) {
        assert!(self.submitted < self.total, "more submissions than declared");
        let i = self.submitted;
        self.submitted += 1;
        let cold = i >= self.warm && i < self.first_wave;
        let platform = self.platform.clone();
        let function = self.function.clone();
        let retry = self.retry;
        let tx = self.tx.clone();
        self.scheduler.submit_detached_tagged(self.peer, self.generation, move || {
            let out = catch_unwind(AssertUnwindSafe(|| {
                invoke_with_retry(&platform, &function, &payload, modeled, Some(cold), retry)
            }))
            .unwrap_or_else(|p| {
                (
                    Err(Error::Faas(format!(
                        "invocation worker panicked: {}",
                        panic_message(&*p)
                    ))),
                    1,
                )
            });
            // receiver gone = the fan-out was abandoned mid-epoch
            let _ = tx.send((i, Instant::now(), out));
        });
    }

    /// Record one landing into the collector, advancing the last-landing
    /// clock.
    fn land(&mut self, i: usize, at: Instant, out: (Result<Invocation>, u32)) {
        self.last_landing = Some(match self.last_landing {
            Some(t) => t.max(at),
            None => at,
        });
        self.collector.push(i, out);
    }

    /// Non-blocking: the next in-order output if it already landed.
    pub fn poll_output(&mut self) -> Option<(usize, Bytes)> {
        while let Ok((i, at, out)) = self.rx.try_recv() {
            self.land(i, at, out);
        }
        self.collector.pop_ready()
    }

    /// Blocking: the next in-order output, or `None` once every
    /// submitted branch has landed and been yielded.
    pub fn next_output(&mut self) -> Option<(usize, Bytes)> {
        loop {
            if let Some(out) = self.collector.pop_ready() {
                return Some(out);
            }
            if self.collector.landed() >= self.submitted {
                return None;
            }
            match self.rx.recv() {
                Ok((i, at, out)) => self.land(i, at, out),
                Err(_) => return None,
            }
        }
    }

    /// Wait for all outstanding branches, release the warm wave, and
    /// produce the aggregate report. `measured_wall` spans from
    /// construction to the *last branch landing* — the true pipelined
    /// epoch time, uploads and collection included, but not any idle
    /// gap between the landing and a late `finish()` call (cross-epoch
    /// collection happens after the inter-epoch coordination wait, and
    /// that wait must not inflate the epoch's measured wall).
    pub fn finish(mut self) -> Result<ExecutionReport> {
        while self.collector.landed() < self.submitted {
            match self.rx.recv() {
                Ok((i, at, out)) => self.land(i, at, out),
                Err(_) => break,
            }
        }
        self.platform
            .release_environments(&self.function, self.first_wave);
        self.finished = true;
        let measured = self
            .last_landing
            .map(|t| t.duration_since(self.t0))
            .unwrap_or_default();
        let mut report = std::mem::take(&mut self.collector).finish()?;
        report.measured_wall = measured;
        Ok(report)
    }
}

impl Drop for PipelinedMap {
    fn drop(&mut self) {
        // abandoned mid-epoch (error between submit and finish): the
        // reserved wave must go back or later fan-outs over-count colds
        if !self.finished {
            self.platform
                .release_environments(&self.function, self.first_wave);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::lambda::{FunctionSpec, Handler};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn echo() -> Handler {
        Arc::new(|b: &Bytes| Ok(b.clone()))
    }

    fn platform_with(name: &str, h: Handler) -> Arc<FaasPlatform> {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        p.register(FunctionSpec::new(name, 512, h)).unwrap();
        p
    }

    /// Completion bookkeeping runs *after* result delivery, so tests
    /// that assert on `completed` must wait for it to catch up.
    fn await_completed(sched: &BranchScheduler, n: u64) {
        for _ in 0..500 {
            if sched.stats().completed >= n {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("scheduler never completed {n} branches: {:?}", sched.stats());
    }

    #[test]
    fn typed_submit_returns_result() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        let h = sched.submit(0, || 21 * 2);
        assert_eq!(h.join().unwrap(), 42);
        let s = sched.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.per_peer_served, vec![(0, 1)]);
    }

    #[test]
    fn panic_in_branch_is_contained() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        let bad = sched.submit(0, || -> u32 { panic!("branch exploded") });
        let err = bad.join().unwrap_err();
        assert!(err.to_string().contains("branch exploded"), "{err}");
        // the lane slot was returned: the scheduler keeps serving
        assert_eq!(sched.submit(0, || 7).join().unwrap(), 7);
        await_completed(&sched, 2);
        assert_eq!(sched.stats().in_flight, 0);
    }

    #[test]
    fn per_peer_cap_bounds_in_flight() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(8)), true);
        sched.register_peer(0, 2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let live = live.clone();
                let peak = peak.clone();
                sched.submit(0, move || {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "cap violated: {peak:?}");
        await_completed(&sched, 8);
        assert!(sched.stats().peak_in_flight <= 2);
    }

    #[test]
    fn pause_holds_resume_drains() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        sched.pause();
        let handles: Vec<_> = (0..4).map(|i| sched.submit(0, move || i)).collect();
        std::thread::sleep(Duration::from_millis(10));
        let s = sched.stats();
        assert_eq!(s.queued, 4, "paused scheduler must not dispatch");
        assert_eq!(s.completed, 0);
        sched.resume();
        let got: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn generation_drain_barrier_waits_for_tail() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        sched.register_peer(0, 4);
        // nothing submitted: an unknown generation is already drained
        sched.await_generation_drained(0, 7);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let done = done.clone();
            sched.submit_detached_tagged(0, Some(7), move || {
                std::thread::sleep(Duration::from_millis(5));
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert!(sched.generation_live(0, 7) > 0);
        sched.await_generation_drained(0, 7);
        assert_eq!(done.load(Ordering::SeqCst), 4, "barrier released early");
        assert_eq!(sched.generation_live(0, 7), 0);
        // unknown lane: immediate return, no panic
        sched.await_generation_drained(99, 7);
    }

    #[test]
    fn coalesce_bursts_release_same_generation_together() {
        // two peers, four tagged branches each, a 1-thread pool so the
        // dispatch order is exactly the release order: with a burst of
        // 4 the scheduler drains one peer's generation before rotating,
        // instead of strict per-branch alternation
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.set_coalesce(4);
        sched.enable_dispatch_log();
        sched.register_peer(0, 8);
        sched.register_peer(1, 8);
        sched.pause();
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            for (rank, generation) in [(0usize, 10u64), (1, 20)] {
                let done = done.clone();
                sched.submit_detached_tagged(rank, Some(generation), move || {
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        sched.resume();
        await_completed(&sched, 8);
        assert_eq!(
            sched.dispatch_log(),
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            "a burst must drain one generation before rotating"
        );
        // burst off: strict alternation comes back
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.enable_dispatch_log();
        sched.register_peer(0, 8);
        sched.register_peer(1, 8);
        sched.pause();
        for _ in 0..2 {
            for rank in 0..2usize {
                sched.submit_detached_tagged(rank, Some(1), || {});
            }
        }
        sched.resume();
        await_completed(&sched, 4);
        assert_eq!(sched.dispatch_log(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn overlapping_generations_are_counted() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
        sched.register_peer(0, 4);
        sched.register_peer(1, 4);
        // peer 0 runs generation 1 branches while peer 1 runs
        // generation 2 — the cross-epoch boundary shape
        let mut handles = Vec::new();
        for (rank, gen) in [(0usize, 1u64), (1, 2)] {
            for _ in 0..3 {
                let (tx, handle) = JobHandle::<()>::channel();
                sched.submit_detached_tagged(rank, Some(gen), move || {
                    std::thread::sleep(Duration::from_millis(10));
                    let _ = tx.send(Ok(()));
                });
                handles.push(handle);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        await_completed(&sched, 6);
        let s = sched.stats();
        assert_eq!(s.peak_inflight_generations, 2, "both epochs must overlap");
        assert_eq!(s.inflight_generations, 0, "everything drained");
    }

    #[test]
    fn pipelined_map_generation_tags_reach_the_scheduler() {
        let p = platform_with("grad", echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.pause();
        let mut pipe = PipelinedMap::new(
            sched.clone(),
            p,
            0,
            "grad",
            2,
            8,
            RetryPolicy::default(),
        )
        .unwrap()
        .with_generation(5);
        pipe.submit(Bytes::from_static(b"a"), None);
        pipe.submit(Bytes::from_static(b"b"), None);
        assert_eq!(sched.generation_live(0, 5), 2, "queued branches are tagged");
        sched.resume();
        while pipe.next_output().is_some() {}
        pipe.finish().unwrap();
        sched.await_generation_drained(0, 5);
        assert_eq!(sched.stats().peak_inflight_generations, 1);
    }

    #[test]
    fn collector_orders_and_aggregates() {
        let mut c = MapCollector::new(4);
        let inv = |billed_ms: u64| Invocation {
            function: "f".into(),
            output: Bytes::from_static(b"o"),
            measured: Duration::from_millis(billed_ms),
            billed: Duration::from_millis(billed_ms),
            cold_start: Duration::ZERO,
            memory_mb: 512,
            cost_usd: 0.0,
        };
        // branches land out of order
        c.push(1, (Ok(inv(20)), 1));
        assert!(c.pop_ready().is_none(), "branch 0 has not landed");
        c.push(0, (Ok(inv(10)), 2));
        assert_eq!(c.pop_ready().unwrap().0, 0);
        assert_eq!(c.pop_ready().unwrap().0, 1);
        c.push(2, (Err(Error::Faas("boom".into())), 3));
        let report = c.finish();
        assert!(report.is_err(), "branch error must win over the report");
    }

    #[test]
    fn evicted_lane_drops_queue_and_refuses_new_work() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.register_peer(0, 8);
        sched.register_peer(1, 8);
        sched.pause();
        let ran = Arc::new(AtomicUsize::new(0));
        for rank in [0usize, 1] {
            for _ in 0..2 {
                let ran = ran.clone();
                sched.submit_detached_tagged(rank, Some(1), move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(sched.evict_peer(1), 2, "both queued branches dropped");
        assert_eq!(sched.evict_peer(1), 0, "idempotent");
        // the dead peer's generation is drained (nothing will run it)
        sched.await_generation_drained(1, 1);
        // new work for the dead peer is refused
        let orphan = sched.submit(1, || 1);
        assert!(orphan.join().is_err(), "evicted lane must refuse work");
        sched.resume();
        await_completed(&sched, 2);
        assert_eq!(ran.load(Ordering::SeqCst), 2, "survivor lane unaffected");
        assert_eq!(sched.stats().lane_evictions, 1);
        // re-admission restores dispatch
        sched.readmit_peer(1);
        assert_eq!(sched.submit(1, || 7).join().unwrap(), 7);
    }

    #[test]
    fn quorum_folds_first_k_and_bills_stragglers() {
        let inv = |ms: u64| Invocation {
            function: "f".into(),
            output: Bytes::from_static(b"o"),
            measured: Duration::from_millis(ms),
            billed: Duration::from_millis(ms),
            cold_start: Duration::ZERO,
            memory_mb: 512,
            cost_usd: 1.0,
        };
        let mut c = MapCollector::new(64).with_quorum(2);
        for i in 0..4 {
            c.push(i, (Ok(inv(10)), 1));
        }
        let mut got = Vec::new();
        while let Some((idx, _)) = c.pop_ready() {
            got.push(idx);
        }
        assert_eq!(got, vec![0, 1], "only the first k yield");
        let r = c.finish().unwrap();
        assert_eq!(r.stragglers, 2);
        assert_eq!(r.invocations, 4, "stragglers still execute");
        assert_eq!(r.billed, Duration::from_millis(40), "and bill honestly");
        assert_eq!(r.cost_usd, 4.0);
        assert_eq!(r.wall, Duration::from_millis(10), "wall spans the quorum only");
        // quorum 0 = fold everything (the byte-identical default)
        let mut all = MapCollector::new(64);
        for i in 0..4 {
            all.push(i, (Ok(inv(10)), 1));
        }
        while all.pop_ready().is_some() {}
        let r = all.finish().unwrap();
        assert_eq!(r.stragglers, 0);
        assert_eq!(r.wall, Duration::from_millis(10));
    }

    #[test]
    fn pipelined_map_streams_in_order() {
        let p = platform_with("grad", echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(4)), true);
        let mut pipe = PipelinedMap::new(
            sched,
            p.clone(),
            0,
            "grad",
            6,
            64,
            RetryPolicy::default(),
        )
        .unwrap();
        for i in 0..6u8 {
            pipe.submit(Bytes::from(vec![i]), None);
        }
        let mut seen = Vec::new();
        while let Some((idx, out)) = pipe.next_output() {
            assert_eq!(out[0] as usize, idx);
            seen.push(idx);
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        let report = pipe.finish().unwrap();
        assert_eq!(report.invocations, 6);
        assert_eq!(report.cold_starts, 6, "fresh fan-out: one env per branch");
        // the wave went back warm
        assert_eq!(p.acquire_environments("grad", 6), 6);
    }

    #[test]
    fn pipelined_map_unknown_function_fails_fast() {
        let p = platform_with("grad", echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        assert!(PipelinedMap::new(sched, p, 0, "nope", 3, 4, RetryPolicy::default()).is_err());
    }

    #[test]
    fn abandoned_pipeline_releases_wave() {
        let p = platform_with("grad", echo());
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        {
            let _pipe = PipelinedMap::new(
                sched,
                p.clone(),
                0,
                "grad",
                4,
                8,
                RetryPolicy::default(),
            )
            .unwrap();
            // dropped without finish (simulates an error mid-epoch)
        }
        // the reserved wave went back to the warm pool, exactly as the
        // staged Map's unconditional release does on its error paths
        assert_eq!(p.acquire_environments("grad", 4), 4);
    }

    #[test]
    fn auto_exec_batch_ramps_up_under_deep_queues() {
        // a backlog at least as deep as the pool doubles toward the cap
        assert_eq!(auto_exec_batch(1, 8, 4, 4, 8), 2);
        assert_eq!(auto_exec_batch(2, 8, 4, 4, 8), 4);
        assert_eq!(auto_exec_batch(4, 8, 4, 4, 8), 8);
        assert_eq!(auto_exec_batch(8, 8, 4, 4, 8), 8, "ceiling binds");
        // a (defensively clamped) zero current target still ramps
        assert_eq!(auto_exec_batch(0, 8, 4, 4, 8), 2);
    }

    #[test]
    fn auto_exec_batch_backs_off_when_starved() {
        // empty queue with idle workers: a collecting group would only
        // fill by waiting out its window — step down toward unfused
        assert_eq!(auto_exec_batch(8, 0, 2, 4, 8), 7);
        assert_eq!(auto_exec_batch(2, 0, 0, 4, 8), 1);
        assert_eq!(auto_exec_batch(1, 0, 0, 4, 8), 1, "floor binds");
    }

    #[test]
    fn auto_exec_batch_holds_without_clear_evidence() {
        // shallow backlog: neither ramp nor starvation evidence
        assert_eq!(auto_exec_batch(4, 2, 4, 4, 8), 4);
        // empty queue but a saturated pool: work is flowing, hold
        assert_eq!(auto_exec_batch(4, 0, 4, 4, 8), 4);
        // a held value is still clamped into the configured range
        assert_eq!(auto_exec_batch(9, 2, 4, 4, 8), 8);
    }

    #[test]
    fn autotune_ramps_with_backlog_and_backs_off_when_drained() {
        let sched = BranchScheduler::new(Arc::new(Executor::new(2)), true);
        let targets = Arc::new(Mutex::new(Vec::new()));
        let t = targets.clone();
        sched.enable_autotune(8, Box::new(move |n| t.lock().unwrap().push(n)));
        assert_eq!(*targets.lock().unwrap(), vec![1], "controller starts unfused");

        // pile up a backlog deeper than the pool while paused: each
        // submit tick that sees queued >= pool doubles the target
        sched.pause();
        for _ in 0..8 {
            sched.submit_detached_tagged(0, Some(1), || {});
        }
        assert_eq!(
            *targets.lock().unwrap(),
            vec![1, 2, 4, 8],
            "deep queue ramps the target toward the ceiling"
        );
        sched.resume();
        await_completed(&sched, 8);

        // starvation: single submit/join cycles never build a backlog
        // (queued == 1 < pool at submit, empty on completion), so the
        // completion ticks walk the target back down to 1
        for i in 0..10u64 {
            sched.submit(0, || ()).join().unwrap();
            await_completed(&sched, 9 + i);
        }
        assert_eq!(
            targets.lock().unwrap().last(),
            Some(&1),
            "starved controller backs off to unfused"
        );
    }

    #[test]
    fn priority_submission_overtakes_queued_branches() {
        // 1-thread pool, paused: queue two normal branches per lane,
        // then a priority branch on lane 1 — it must win the first
        // slot even though four branches were queued ahead of it
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.enable_dispatch_log();
        sched.register_peer(0, 8);
        sched.register_peer(1, 8);
        sched.pause();
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..2 {
            for rank in [0usize, 1] {
                let order = order.clone();
                sched.submit_detached_tagged(rank, Some(1), move || {
                    order.lock().unwrap().push(format!("n{rank}.{i}"));
                });
            }
        }
        let o = order.clone();
        sched.submit_detached_prio(1, Some(1), move || {
            o.lock().unwrap().push("prio".to_string());
        });
        assert_eq!(sched.stats().lane_promotions, 1, "overtake is counted");
        sched.resume();
        await_completed(&sched, 5);
        assert_eq!(order.lock().unwrap()[0], "prio", "priority branch ran first");
        assert_eq!(sched.dispatch_log()[0], 1);
        // admission caps / pool width still bound everything else
        assert_eq!(sched.stats().completed, 5);
    }

    #[test]
    fn drain_barrier_promotes_straggler_lane() {
        // lane 1 holds the awaited generation's tail but sits behind
        // lane 0 in the rotation; a collector blocking on the drain
        // barrier moves it to the rotation front so the tail wins the
        // next free slot instead of waiting out lane 0's backlog
        let sched = BranchScheduler::new(Arc::new(Executor::new(1)), true);
        sched.enable_dispatch_log();
        sched.register_peer(0, 8);
        sched.register_peer(1, 8);
        sched.pause();
        for _ in 0..2 {
            sched.submit_detached(0, || {});
            sched.submit_detached_tagged(1, Some(3), || {});
        }
        let s2 = sched.clone();
        let collector = std::thread::spawn(move || s2.await_generation_drained(1, 3));
        // the promotion happens as the barrier starts waiting
        for _ in 0..500 {
            if sched.stats().lane_promotions >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(sched.stats().lane_promotions, 1, "straggler lane promoted");
        sched.resume();
        collector.join().unwrap();
        await_completed(&sched, 4);
        assert_eq!(
            sched.dispatch_log()[0],
            1,
            "promoted lane won the first slot"
        );
    }
}
