//! AWS-Step-Functions-like state machine (the paper's §IV-D.3 "Dynamic
//! State Machine for Parallel Batch Processing").
//!
//! The paper generates the state machine *dynamically from the batch
//! count*: a parallel Map over the peer's batches, each branch invoking
//! the gradient Lambda with its batch's S3 location. [`StateMachine`]
//! reproduces that: Task / Map / sequence states, bounded concurrency,
//! retry policy, and wall-clock aggregation.
//!
//! Time accounting is dual:
//!
//! - **modeled wall** ([`ExecutionReport::wall`]) — a deterministic
//!   greedy schedule over the branch durations (`schedule_wall`): with
//!   enough concurrency it is the max branch; with bounded concurrency,
//!   waves form — exactly the behaviour that makes serverless fan-out
//!   beat the sequential instance loop in fig 3. Cold starts are
//!   assigned per *wave*, not per pool probe: the first
//!   `min(branches, max_concurrency)` branches each need their own
//!   environment, so a fresh fan-out of N correctly takes N cold
//!   starts. Because the split is decided up front, the modeled numbers
//!   are byte-identical no matter how many worker threads execute the
//!   branches.
//! - **measured wall** ([`ExecutionReport::measured_wall`]) — the real
//!   elapsed time of dispatching the branches across the
//!   [`Executor`] worker pool. This is what shrinks as `--exec-threads`
//!   grows; the modeled wall does not move.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::Bytes;

use super::executor::{Executor, Semaphore};
use super::lambda::{FaasPlatform, Invocation};
use crate::error::{Error, Result};

/// The branch retry policy now lives in [`crate::util::retry`] so the
/// store/broker chaos planes share the exact same exhaustion and
/// backoff semantics; re-exported here because `faas::RetryPolicy` is
/// the historical path every call site (and the public API) uses.
pub use crate::util::retry::RetryPolicy;

/// A state in the machine.
pub enum State {
    /// Invoke one function with a payload.
    Task { function: String, payload: Bytes, modeled: Option<Duration> },
    /// Parallel Map: invoke `function` once per item, at most
    /// `max_concurrency` in flight.
    Map {
        function: String,
        items: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    },
}

/// Execution report: outputs in state order, plus aggregate timing/cost.
#[derive(Debug, Default)]
pub struct ExecutionReport {
    pub outputs: Vec<Vec<Bytes>>,
    /// Modeled wall-clock (parallel branches overlap under the greedy
    /// `schedule_wall` scheduler; deterministic across pool sizes).
    pub wall: Duration,
    /// Measured wall-clock of the real worker-pool dispatch.
    pub measured_wall: Duration,
    /// Sum of billed durations (what AWS charges for).
    pub billed: Duration,
    pub cost_usd: f64,
    pub invocations: usize,
    pub cold_starts: usize,
    pub retries: usize,
    /// Branches beyond a fold quorum: executed and billed, but excluded
    /// from the modeled wall and the folded output (k-of-n folds).
    pub stragglers: usize,
}

/// A dynamically-built state machine.
pub struct StateMachine {
    pub name: String,
    states: Vec<State>,
    retry: RetryPolicy,
}

impl StateMachine {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), states: Vec::new(), retry: RetryPolicy::default() }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn task(mut self, function: &str, payload: Bytes, modeled: Option<Duration>) -> Self {
        self.states.push(State::Task { function: function.into(), payload, modeled });
        self
    }

    pub fn map(
        mut self,
        function: &str,
        items: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    ) -> Self {
        assert!(modeled.is_empty() || modeled.len() == items.len());
        self.states.push(State::Map {
            function: function.into(),
            items,
            modeled,
            max_concurrency: max_concurrency.max(1),
        });
        self
    }

    /// The paper's generator: one Map branch per data batch.
    pub fn parallel_batches(
        name: impl Into<String>,
        function: &str,
        batch_payloads: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    ) -> Self {
        Self::new(name).map(function, batch_payloads, modeled, max_concurrency)
    }

    pub fn num_states(&self) -> usize {
        self.states.len()
    }

    /// Execute against a platform on the process-wide worker pool.
    pub fn execute(&self, platform: &Arc<FaasPlatform>) -> Result<ExecutionReport> {
        self.execute_with(platform, Executor::global())
    }

    /// Execute against a platform, dispatching Map branches across
    /// `pool`'s worker threads. Results are joined in branch order, so
    /// modeled wall/billed/cost aggregation is deterministic regardless
    /// of the pool size; `measured_wall` reflects the real concurrency.
    pub fn execute_with(
        &self,
        platform: &Arc<FaasPlatform>,
        pool: &Executor,
    ) -> Result<ExecutionReport> {
        let mut report = ExecutionReport::default();
        for state in &self.states {
            match state {
                State::Task { function, payload, modeled } => {
                    let t0 = Instant::now();
                    let (result, attempts) =
                        invoke_with_retry(platform, function, payload, *modeled, None, self.retry);
                    report.measured_wall += t0.elapsed();
                    report.retries += attempts.saturating_sub(1) as usize;
                    let inv = result?;
                    report.invocations += 1;
                    if !inv.cold_start.is_zero() {
                        report.cold_starts += 1;
                    }
                    report.wall += inv.wall();
                    report.billed += inv.billed;
                    report.cost_usd += inv.cost_usd;
                    report.outputs.push(vec![inv.output]);
                }
                State::Map { function, items, modeled, max_concurrency } => {
                    platform.get(function)?; // fail fast before reserving envs
                    // first wave: every branch that may run before any
                    // other finishes needs its own environment
                    let first_wave = items.len().min(*max_concurrency);
                    let warm = platform.acquire_environments(function, first_wave);
                    // physical in-flight cap = the modeled Lambda
                    // concurrency, so measured_wall cannot show more
                    // parallelism than the platform would allow
                    let gate = Arc::new(Semaphore::new(*max_concurrency));
                    let t0 = Instant::now();
                    let handles: Vec<_> = items
                        .iter()
                        .enumerate()
                        .map(|(i, item)| {
                            let platform = platform.clone();
                            let function = function.clone();
                            let payload = item.clone();
                            let m = modeled.get(i).copied().flatten();
                            let cold = i >= warm && i < first_wave;
                            let retry = self.retry;
                            let gate = gate.clone();
                            pool.submit(move || {
                                let _slot = gate.acquire();
                                invoke_with_retry(
                                    &platform,
                                    &function,
                                    &payload,
                                    m,
                                    Some(cold),
                                    retry,
                                )
                            })
                        })
                        .collect();
                    let mut outs = Vec::with_capacity(items.len());
                    let mut walls = Vec::with_capacity(items.len());
                    let mut first_err = None;
                    for h in handles {
                        match h.join() {
                            Ok((Ok(inv), attempts)) => {
                                report.invocations += 1;
                                report.retries += attempts.saturating_sub(1) as usize;
                                if !inv.cold_start.is_zero() {
                                    report.cold_starts += 1;
                                }
                                walls.push(inv.wall());
                                report.billed += inv.billed;
                                report.cost_usd += inv.cost_usd;
                                outs.push(inv.output);
                            }
                            Ok((Err(e), attempts)) => {
                                report.retries += attempts.saturating_sub(1) as usize;
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                            Err(e) => {
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                        }
                    }
                    platform.release_environments(function, first_wave);
                    report.measured_wall += t0.elapsed();
                    if let Some(e) = first_err {
                        return Err(e);
                    }
                    report.wall += schedule_wall(&walls, *max_concurrency);
                    report.outputs.push(outs);
                }
            }
        }
        Ok(report)
    }
}

/// Invoke with Step-Functions retry semantics. Returns the final result
/// plus the number of attempts made (so callers record `attempts - 1`
/// retries — a first try is not a retry, even on exhaustion).
///
/// `prepared_cold` carries the state machine's wave decision: the first
/// attempt uses it, retry attempts always find the environment warm
/// (the cold init already happened).
pub(crate) fn invoke_with_retry(
    platform: &FaasPlatform,
    function: &str,
    payload: &Bytes,
    modeled: Option<Duration>,
    prepared_cold: Option<bool>,
    retry: RetryPolicy,
) -> (Result<Invocation>, u32) {
    let max = retry.max_attempts.max(1);
    let mut last_err = None;
    for attempt in 0..max {
        if attempt > 0 {
            let delay = retry.backoff_delay(attempt);
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
        }
        let result = match prepared_cold {
            None => platform.invoke(function, payload, modeled),
            Some(cold) => {
                platform.invoke_prepared(function, payload, modeled, cold && attempt == 0)
            }
        };
        match result {
            Ok(inv) => return (Ok(inv), attempt + 1),
            Err(e) => last_err = Some(e),
        }
    }
    (
        Err(last_err.unwrap_or_else(|| Error::Faas("retry exhausted".into()))),
        max,
    )
}

/// Greedy multi-worker makespan: dispatch durations in order onto
/// `concurrency` workers, return the final finish time.
pub fn schedule_wall(durations: &[Duration], concurrency: usize) -> Duration {
    let c = concurrency.max(1).min(durations.len().max(1));
    let mut workers = vec![Duration::ZERO; c];
    for &d in durations {
        // earliest-finishing worker takes the next item
        let (idx, _) = workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| **w)
            .unwrap();
        workers[idx] += d;
    }
    workers.into_iter().max().unwrap_or(Duration::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::lambda::{FunctionSpec, Handler};
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    fn echo() -> Handler {
        Arc::new(|b: &Bytes| Ok(b.clone()))
    }

    fn platform() -> Arc<FaasPlatform> {
        let p = Arc::new(FaasPlatform::new(Duration::from_millis(500)));
        p.register(FunctionSpec::new("grad", 1024, echo())).unwrap();
        p
    }

    fn secs(s: u64) -> Option<Duration> {
        Some(Duration::from_secs(s))
    }

    #[test]
    fn schedule_wall_unbounded_is_max() {
        let d: Vec<_> = [3u64, 1, 2].iter().map(|&s| Duration::from_secs(s)).collect();
        assert_eq!(schedule_wall(&d, 10), Duration::from_secs(3));
    }

    #[test]
    fn schedule_wall_serial_is_sum() {
        let d: Vec<_> = [3u64, 1, 2].iter().map(|&s| Duration::from_secs(s)).collect();
        assert_eq!(schedule_wall(&d, 1), Duration::from_secs(6));
    }

    #[test]
    fn schedule_wall_waves() {
        let d = vec![Duration::from_secs(2); 4];
        assert_eq!(schedule_wall(&d, 2), Duration::from_secs(4));
        assert_eq!(schedule_wall(&d, 3), Duration::from_secs(4)); // 2 then 1+1
        assert_eq!(schedule_wall(&d, 4), Duration::from_secs(2));
    }

    #[test]
    fn map_wall_is_parallel_billed_is_sum() {
        let p = platform();
        let items: Vec<Bytes> = (0..4).map(|_| Bytes::from_static(b"b")).collect();
        let modeled = vec![secs(10), secs(10), secs(10), secs(10)];
        let sm = StateMachine::parallel_batches("epoch", "grad", items, modeled, 64);
        let r = sm.execute(&p).unwrap();
        assert_eq!(r.invocations, 4);
        assert_eq!(r.billed, Duration::from_secs(40));
        // a fresh fan-out of 4 takes 4 cold starts (one env per branch)
        assert_eq!(r.cold_starts, 4);
        // wall: max(cold + 10s) — far below the serial 40s
        assert_eq!(r.wall, Duration::from_millis(10_500));
        // dispatch of no-op handlers is near-instant in real time
        assert!(r.measured_wall < Duration::from_secs(5));
    }

    #[test]
    fn second_fanout_reuses_warm_envs() {
        let p = platform();
        let items: Vec<Bytes> = (0..3).map(|_| Bytes::from_static(b"b")).collect();
        let sm = StateMachine::parallel_batches("e", "grad", items, vec![], 64);
        let r1 = sm.execute(&p).unwrap();
        assert_eq!(r1.cold_starts, 3);
        let r2 = sm.execute(&p).unwrap();
        assert_eq!(r2.cold_starts, 0, "second wave must be fully warm");
    }

    #[test]
    fn bounded_concurrency_bounds_cold_wave() {
        let p = platform();
        let items: Vec<Bytes> = (0..8).map(|_| Bytes::from_static(b"b")).collect();
        let sm = StateMachine::parallel_batches("e", "grad", items, vec![], 2);
        let r = sm.execute(&p).unwrap();
        // only 2 environments ever run concurrently; later branches reuse
        assert_eq!(r.cold_starts, 2);
        assert_eq!(r.invocations, 8);
    }

    #[test]
    fn sequential_tasks_accumulate_wall() {
        let p = platform();
        let sm = StateMachine::new("seq")
            .task("grad", Bytes::from_static(b"1"), secs(2))
            .task("grad", Bytes::from_static(b"2"), secs(3));
        let r = sm.execute(&p).unwrap();
        assert!(r.wall >= Duration::from_secs(5));
        assert_eq!(r.outputs.len(), 2);
    }

    #[test]
    fn retry_recovers_transient_failures() {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        let attempts = Arc::new(AtomicU32::new(0));
        let a2 = attempts.clone();
        let flaky: Handler = Arc::new(move |b: &Bytes| {
            if a2.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(Error::Faas("transient".into()))
            } else {
                Ok(b.clone())
            }
        });
        p.register(FunctionSpec::new("flaky", 512, flaky)).unwrap();
        let sm = StateMachine::new("r").task("flaky", Bytes::from_static(b"x"), None);
        let r = sm.execute(&p).unwrap();
        assert_eq!(r.retries, 2);
        assert_eq!(attempts.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn map_retry_success_counted_once() {
        // regression: a branch succeeding on its k-th attempt must add
        // exactly k-1 retries, not double-count across the report
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        let fails = Arc::new(AtomicU32::new(0));
        let f2 = fails.clone();
        let flaky: Handler = Arc::new(move |b: &Bytes| {
            if &b[..] == b"flaky" && f2.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(Error::Faas("transient".into()))
            } else {
                Ok(b.clone())
            }
        });
        p.register(FunctionSpec::new("g", 512, flaky)).unwrap();
        let items = vec![
            Bytes::from_static(b"ok1"),
            Bytes::from_static(b"flaky"),
            Bytes::from_static(b"ok2"),
        ];
        let sm = StateMachine::parallel_batches("e", "g", items, vec![], 64);
        let r = sm.execute(&p).unwrap();
        assert_eq!(r.invocations, 3);
        assert_eq!(r.retries, 2, "two failed attempts = two retries, counted once");
        assert_eq!(r.outputs[0].len(), 3);
    }

    #[test]
    fn retry_exhaustion_propagates() {
        let p = Arc::new(FaasPlatform::new(Duration::ZERO));
        let failing: Handler = Arc::new(|_| Err(Error::Faas("always".into())));
        p.register(FunctionSpec::new("bad", 512, failing)).unwrap();
        let sm = StateMachine::new("r")
            .with_retry(RetryPolicy { max_attempts: 2, ..RetryPolicy::default() })
            .task("bad", Bytes::new(), None);
        assert!(sm.execute(&p).is_err());
    }

    #[test]
    fn retry_exhaustion_counts_attempts_minus_one() {
        // regression: exhausting max_attempts is max_attempts - 1
        // retries (the first try is not a retry)
        let p = FaasPlatform::new(Duration::ZERO);
        let failing: Handler = Arc::new(|_| Err(Error::Faas("always".into())));
        p.register(FunctionSpec::new("bad", 512, failing)).unwrap();
        let (res, attempts) = invoke_with_retry(
            &p,
            "bad",
            &Bytes::new(),
            None,
            None,
            RetryPolicy { max_attempts: 3, ..RetryPolicy::default() },
        );
        assert!(res.is_err());
        assert_eq!(attempts, 3, "3 attempts made");
        assert_eq!(attempts - 1, 2, "recorded as 2 retries");
        assert_eq!(p.stats().errors, 3);
    }

    #[test]
    fn backoff_is_exponential_deterministic_and_bounded() {
        let p = RetryPolicy::configured(5, 100, 42);
        let d1 = p.backoff_delay(1);
        let d2 = p.backoff_delay(2);
        let d3 = p.backoff_delay(3);
        // exponential base, jitter bounded by half the base
        assert!(d1 >= Duration::from_millis(100) && d1 <= Duration::from_millis(150));
        assert!(d2 >= Duration::from_millis(200) && d2 <= Duration::from_millis(300));
        assert!(d3 >= Duration::from_millis(400) && d3 <= Duration::from_millis(600));
        // same seed, same delays
        assert_eq!(d2, RetryPolicy::configured(5, 100, 42).backoff_delay(2));
        // different seed, (almost surely) different jitter
        assert_ne!(d2, RetryPolicy::configured(5, 100, 43).backoff_delay(2));
        // no backoff configured = no sleep owed
        assert_eq!(RetryPolicy::default().backoff_delay(3), Duration::ZERO);
    }

    #[test]
    fn dynamic_generation_matches_batch_count() {
        let items: Vec<Bytes> = (0..30).map(|_| Bytes::new()).collect();
        let sm = StateMachine::parallel_batches("e", "grad", items, vec![], 10);
        assert_eq!(sm.num_states(), 1);
    }
}
