//! AWS-Step-Functions-like state machine (the paper's §IV-D.3 "Dynamic
//! State Machine for Parallel Batch Processing").
//!
//! The paper generates the state machine *dynamically from the batch
//! count*: a parallel Map over the peer's batches, each branch invoking
//! the gradient Lambda with its batch's S3 location. [`StateMachine`]
//! reproduces that: Task / Map / sequence states, bounded concurrency,
//! retry policy, and wall-clock aggregation.
//!
//! Wall time of a Map state is computed by a deterministic greedy
//! scheduler over the branch durations (`schedule_wall`): with enough
//! concurrency it is the max branch; with bounded concurrency, waves
//! form — exactly the behaviour that makes serverless fan-out beat the
//! sequential instance loop in fig 3.

use std::time::Duration;

use crate::util::Bytes;

use super::lambda::{FaasPlatform, Invocation};
use crate::error::{Error, Result};

/// Retry policy for transient task failures (Step Functions' `Retry`).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 3 }
    }
}

/// A state in the machine.
pub enum State {
    /// Invoke one function with a payload.
    Task { function: String, payload: Bytes, modeled: Option<Duration> },
    /// Parallel Map: invoke `function` once per item, at most
    /// `max_concurrency` in flight.
    Map {
        function: String,
        items: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    },
}

/// Execution report: outputs in state order, plus aggregate timing/cost.
#[derive(Debug, Default)]
pub struct ExecutionReport {
    pub outputs: Vec<Vec<Bytes>>,
    /// Modeled wall-clock (parallel branches overlap).
    pub wall: Duration,
    /// Sum of billed durations (what AWS charges for).
    pub billed: Duration,
    pub cost_usd: f64,
    pub invocations: usize,
    pub cold_starts: usize,
    pub retries: usize,
}

/// A dynamically-built state machine.
pub struct StateMachine {
    pub name: String,
    states: Vec<State>,
    retry: RetryPolicy,
}

impl StateMachine {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), states: Vec::new(), retry: RetryPolicy::default() }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn task(mut self, function: &str, payload: Bytes, modeled: Option<Duration>) -> Self {
        self.states.push(State::Task { function: function.into(), payload, modeled });
        self
    }

    pub fn map(
        mut self,
        function: &str,
        items: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    ) -> Self {
        assert!(modeled.is_empty() || modeled.len() == items.len());
        self.states.push(State::Map {
            function: function.into(),
            items,
            modeled,
            max_concurrency: max_concurrency.max(1),
        });
        self
    }

    /// The paper's generator: one Map branch per data batch.
    pub fn parallel_batches(
        name: impl Into<String>,
        function: &str,
        batch_payloads: Vec<Bytes>,
        modeled: Vec<Option<Duration>>,
        max_concurrency: usize,
    ) -> Self {
        Self::new(name).map(function, batch_payloads, modeled, max_concurrency)
    }

    pub fn num_states(&self) -> usize {
        self.states.len()
    }

    /// Execute against a platform. Handlers run inline (they are already
    /// fast or PJRT-bound); *modeled* parallelism is aggregated via
    /// [`schedule_wall`].
    pub fn execute(&self, platform: &FaasPlatform) -> Result<ExecutionReport> {
        let mut report = ExecutionReport::default();
        for state in &self.states {
            match state {
                State::Task { function, payload, modeled } => {
                    let inv = self.invoke_retry(platform, function, payload, *modeled, &mut report)?;
                    report.wall += inv.wall();
                    report.billed += inv.billed;
                    report.cost_usd += inv.cost_usd;
                    report.outputs.push(vec![inv.output]);
                }
                State::Map { function, items, modeled, max_concurrency } => {
                    let mut outs = Vec::with_capacity(items.len());
                    let mut walls = Vec::with_capacity(items.len());
                    for (i, item) in items.iter().enumerate() {
                        let m = modeled.get(i).copied().flatten();
                        let inv = self.invoke_retry(platform, function, item, m, &mut report)?;
                        walls.push(inv.wall());
                        report.billed += inv.billed;
                        report.cost_usd += inv.cost_usd;
                        outs.push(inv.output);
                    }
                    report.wall += schedule_wall(&walls, *max_concurrency);
                    report.outputs.push(outs);
                }
            }
        }
        Ok(report)
    }

    fn invoke_retry(
        &self,
        platform: &FaasPlatform,
        function: &str,
        payload: &Bytes,
        modeled: Option<Duration>,
        report: &mut ExecutionReport,
    ) -> Result<Invocation> {
        let mut last_err = None;
        for attempt in 0..self.retry.max_attempts.max(1) {
            match platform.invoke(function, payload, modeled) {
                Ok(inv) => {
                    report.invocations += 1;
                    if !inv.cold_start.is_zero() {
                        report.cold_starts += 1;
                    }
                    if attempt > 0 {
                        report.retries += attempt as usize;
                    }
                    return Ok(inv);
                }
                Err(e) => last_err = Some(e),
            }
        }
        report.retries += self.retry.max_attempts as usize;
        Err(last_err.unwrap_or_else(|| Error::Faas("retry exhausted".into())))
    }
}

/// Greedy multi-worker makespan: dispatch durations in order onto
/// `concurrency` workers, return the final finish time.
pub fn schedule_wall(durations: &[Duration], concurrency: usize) -> Duration {
    let c = concurrency.max(1).min(durations.len().max(1));
    let mut workers = vec![Duration::ZERO; c];
    for &d in durations {
        // earliest-finishing worker takes the next item
        let (idx, _) = workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| **w)
            .unwrap();
        workers[idx] += d;
    }
    workers.into_iter().max().unwrap_or(Duration::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::lambda::{FunctionSpec, Handler};
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    fn echo() -> Handler {
        Arc::new(|b: &Bytes| Ok(b.clone()))
    }

    fn platform() -> FaasPlatform {
        let p = FaasPlatform::new(Duration::from_millis(500));
        p.register(FunctionSpec::new("grad", 1024, echo())).unwrap();
        p
    }

    fn secs(s: u64) -> Option<Duration> {
        Some(Duration::from_secs(s))
    }

    #[test]
    fn schedule_wall_unbounded_is_max() {
        let d: Vec<_> = [3u64, 1, 2].iter().map(|&s| Duration::from_secs(s)).collect();
        assert_eq!(schedule_wall(&d, 10), Duration::from_secs(3));
    }

    #[test]
    fn schedule_wall_serial_is_sum() {
        let d: Vec<_> = [3u64, 1, 2].iter().map(|&s| Duration::from_secs(s)).collect();
        assert_eq!(schedule_wall(&d, 1), Duration::from_secs(6));
    }

    #[test]
    fn schedule_wall_waves() {
        let d = vec![Duration::from_secs(2); 4];
        assert_eq!(schedule_wall(&d, 2), Duration::from_secs(4));
        assert_eq!(schedule_wall(&d, 3), Duration::from_secs(4)); // 2 then 1+1
        assert_eq!(schedule_wall(&d, 4), Duration::from_secs(2));
    }

    #[test]
    fn map_wall_is_parallel_billed_is_sum() {
        let p = platform();
        let items: Vec<Bytes> = (0..4).map(|_| Bytes::from_static(b"b")).collect();
        let modeled = vec![secs(10), secs(10), secs(10), secs(10)];
        let sm = StateMachine::parallel_batches("epoch", "grad", items, modeled, 64);
        let r = sm.execute(&p).unwrap();
        assert_eq!(r.invocations, 4);
        assert_eq!(r.billed, Duration::from_secs(40));
        // wall: max(10s) + one cold start (first env) dominates waves;
        // every branch may cold-start since invocations are recorded
        // sequentially — wall must be far below the serial 40s.
        assert!(r.wall < Duration::from_secs(12), "wall {:?}", r.wall);
    }

    #[test]
    fn sequential_tasks_accumulate_wall() {
        let p = platform();
        let sm = StateMachine::new("seq")
            .task("grad", Bytes::from_static(b"1"), secs(2))
            .task("grad", Bytes::from_static(b"2"), secs(3));
        let r = sm.execute(&p).unwrap();
        assert!(r.wall >= Duration::from_secs(5));
        assert_eq!(r.outputs.len(), 2);
    }

    #[test]
    fn retry_recovers_transient_failures() {
        let p = FaasPlatform::new(Duration::ZERO);
        let attempts = Arc::new(AtomicU32::new(0));
        let a2 = attempts.clone();
        let flaky: Handler = Arc::new(move |b: &Bytes| {
            if a2.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(Error::Faas("transient".into()))
            } else {
                Ok(b.clone())
            }
        });
        p.register(FunctionSpec::new("flaky", 512, flaky)).unwrap();
        let sm = StateMachine::new("r").task("flaky", Bytes::from_static(b"x"), None);
        let r = sm.execute(&p).unwrap();
        assert_eq!(r.retries, 2);
        assert_eq!(attempts.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn retry_exhaustion_propagates() {
        let p = FaasPlatform::new(Duration::ZERO);
        let failing: Handler = Arc::new(|_| Err(Error::Faas("always".into())));
        p.register(FunctionSpec::new("bad", 512, failing)).unwrap();
        let sm = StateMachine::new("r")
            .with_retry(RetryPolicy { max_attempts: 2 })
            .task("bad", Bytes::new(), None);
        assert!(sm.execute(&p).is_err());
    }

    #[test]
    fn dynamic_generation_matches_batch_count() {
        let items: Vec<Bytes> = (0..30).map(|_| Bytes::new()).collect();
        let sm = StateMachine::parallel_batches("e", "grad", items, vec![], 10);
        assert_eq!(sm.num_states(), 1);
    }
}
