//! EC2 instance catalog (the paper's peer substrate).
//!
//! Real AWS us-east-1 on-demand specs/prices for the t2 family — the
//! paper's §IV-C picks t2.medium for SqueezeNet/MobileNet peers and
//! t2.large for VGG-11, and its cost tables use exactly these per-second
//! prices (t2.small $0.00000639/s, t2.large $0.00002578/s).

use crate::error::{Error, Result};

/// One EC2 instance type.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    pub name: &'static str,
    pub vcpus: u32,
    pub memory_gb: f64,
    /// On-demand USD per hour.
    pub price_per_hour: f64,
}

impl InstanceType {
    pub fn price_per_second(&self) -> f64 {
        self.price_per_hour / 3600.0
    }

    /// Relative CPU throughput vs t2.large (2 vCPU), the perfmodel's
    /// calibration reference.
    pub fn cpu_factor(&self) -> f64 {
        self.vcpus as f64 / 2.0
    }
}

/// The t2 family (AWS us-east-1 on-demand, as used by the paper).
pub const CATALOG: &[InstanceType] = &[
    InstanceType { name: "t2.nano", vcpus: 1, memory_gb: 0.5, price_per_hour: 0.0058 },
    InstanceType { name: "t2.micro", vcpus: 1, memory_gb: 1.0, price_per_hour: 0.0116 },
    InstanceType { name: "t2.small", vcpus: 1, memory_gb: 2.0, price_per_hour: 0.023 },
    InstanceType { name: "t2.medium", vcpus: 2, memory_gb: 4.0, price_per_hour: 0.0464 },
    InstanceType { name: "t2.large", vcpus: 2, memory_gb: 8.0, price_per_hour: 0.0928 },
    InstanceType { name: "t2.xlarge", vcpus: 4, memory_gb: 16.0, price_per_hour: 0.1856 },
    InstanceType { name: "t2.2xlarge", vcpus: 8, memory_gb: 32.0, price_per_hour: 0.3712 },
];

/// Look an instance type up by name.
pub fn instance(name: &str) -> Result<&'static InstanceType> {
    CATALOG
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| Error::Config(format!("unknown instance type {name:?}")))
}

/// The paper's §IV-C instance-selection procedure: walk the catalog from
/// the smallest type upward until one satisfies the model's memory need
/// (the paper discovered t2.medium / t2.large this way by crashing
/// smaller instances).
pub fn smallest_fitting(min_memory_gb: f64) -> &'static InstanceType {
    CATALOG
        .iter()
        .find(|t| t.memory_gb >= min_memory_gb)
        .unwrap_or(CATALOG.last().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_per_second_prices() {
        // Table III: t2.large $0.00002578/s; Table II: t2.small $0.00000639/s
        let small = instance("t2.small").unwrap();
        let large = instance("t2.large").unwrap();
        assert!((small.price_per_second() - 0.00000639).abs() < 5e-9);
        assert!((large.price_per_second() - 0.00002578).abs() < 5e-9);
    }

    #[test]
    fn unknown_instance_errors() {
        assert!(instance("m5.large").is_err());
    }

    #[test]
    fn smallest_fitting_walks_up() {
        assert_eq!(smallest_fitting(0.4).name, "t2.nano");
        assert_eq!(smallest_fitting(3.0).name, "t2.medium");
        assert_eq!(smallest_fitting(4.3).name, "t2.large"); // VGG-11's ~4.2 GB/batch
        assert_eq!(smallest_fitting(999.0).name, "t2.2xlarge");
    }

    #[test]
    fn cpu_factor_reference_is_t2_large() {
        assert_eq!(instance("t2.large").unwrap().cpu_factor(), 1.0);
        assert_eq!(instance("t2.small").unwrap().cpu_factor(), 0.5);
        assert_eq!(instance("t2.2xlarge").unwrap().cpu_factor(), 4.0);
    }

    #[test]
    fn catalog_sorted_by_memory() {
        for w in CATALOG.windows(2) {
            assert!(w[0].memory_gb <= w[1].memory_gb);
        }
    }
}
