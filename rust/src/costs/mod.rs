//! The paper's cost model — Eq. (1) and Eq. (2) of §V-B.2 — plus a
//! conventional GB-second accounting for comparison.
//!
//! The paper's equations (reproduced verbatim, including their unusual
//! dimensional structure — per-second rates multiplied, then scaled by
//! the computation time):
//!
//!   Cost/Peer_serverless     = [LambdaCost x NumBatches + EC2Cost] x T   (1)
//!   Cost/Peer_instance-based = EC2Cost x T                                (2)
//!
//! where `LambdaCost` and `EC2Cost` are USD/second rates and `T` is the
//! gradient-computation time in seconds. Plugging the paper's inputs
//! reproduces Table II/III's cost rows to <1 % (see tests), including
//! the headline "serverless costs up to 5.3-5.4x more" at B=1024.

use crate::cloud::InstanceType;
use crate::faas::pricing::{price_per_second, Arch};

/// Inputs for one cost evaluation.
#[derive(Debug, Clone, Copy)]
pub struct CostInputs {
    /// Gradient-computation time in seconds (per the relevant table).
    pub compute_time_s: f64,
    pub num_batches: usize,
    pub lambda_memory_mb: u32,
}

/// One cost line (USD).
#[derive(Debug, Clone, Copy)]
pub struct CostReport {
    pub ec2_rate_per_s: f64,
    pub lambda_rate_per_s: f64,
    pub cost_per_peer_usd: f64,
}

/// Eq. (1): serverless architecture (small host instance + lambdas).
pub fn serverless_cost_per_peer(
    host: &InstanceType,
    inputs: CostInputs,
) -> CostReport {
    let lambda_rate = price_per_second(inputs.lambda_memory_mb, Arch::Arm64);
    let ec2_rate = host.price_per_second();
    let cost =
        (lambda_rate * inputs.num_batches as f64 + ec2_rate) * inputs.compute_time_s;
    CostReport {
        ec2_rate_per_s: ec2_rate,
        lambda_rate_per_s: lambda_rate,
        cost_per_peer_usd: cost,
    }
}

/// Eq. (2): instance-based architecture.
pub fn instance_cost_per_peer(inst: &InstanceType, compute_time_s: f64) -> CostReport {
    let ec2_rate = inst.price_per_second();
    CostReport {
        ec2_rate_per_s: ec2_rate,
        lambda_rate_per_s: 0.0,
        cost_per_peer_usd: ec2_rate * compute_time_s,
    }
}

/// Conventional AWS billing for the same serverless workload (GB-seconds
/// actually consumed + host time) — reported alongside Eq. (1) so the
/// discussion section can contrast the paper's formula with real billing.
pub fn serverless_cost_actual_billing(
    host: &InstanceType,
    per_batch_s: f64,
    num_batches: usize,
    lambda_memory_mb: u32,
    host_wall_s: f64,
) -> f64 {
    let lambda = price_per_second(lambda_memory_mb, Arch::Arm64)
        * per_batch_s
        * num_batches as f64
        + num_batches as f64 * crate::faas::pricing::USD_PER_1M_REQUESTS / 1e6;
    lambda + host.price_per_second() * host_wall_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud;

    #[test]
    fn table2_serverless_costs() {
        // (batch, nbatches, mem MB, compute s, expected USD)
        let cases = [
            (1024usize, 15usize, 4400u32, 41.2f64, 0.03567f64),
            (512, 30, 2800, 28.1, 0.03069),
            (128, 118, 1800, 12.9, 0.03451),
            (64, 235, 1700, 10.5, 0.05435),
        ];
        let host = cloud::instance("t2.small").unwrap();
        for (b, n, mem, t, want) in cases {
            let got = serverless_cost_per_peer(
                host,
                CostInputs { compute_time_s: t, num_batches: n, lambda_memory_mb: mem },
            )
            .cost_per_peer_usd;
            // 5% tolerance: the paper's own B=128 row is ~3.5% off
            // from its stated rates (0.0000233*118+0.00000639)*12.9.
            assert!(
                (got - want).abs() / want < 0.05,
                "B={b}: got {got:.5}, paper {want:.5}"
            );
        }
    }

    #[test]
    fn table3_instance_costs() {
        let cases = [
            (1024usize, 258.0f64, 0.00665f64),
            (512, 278.4, 0.00717),
            (128, 330.4, 0.00851),
            (64, 394.8, 0.01017),
        ];
        let inst = cloud::instance("t2.large").unwrap();
        for (b, t, want) in cases {
            let got = instance_cost_per_peer(inst, t).cost_per_peer_usd;
            assert!(
                (got - want).abs() / want < 0.02,
                "B={b}: got {got:.5}, paper {want:.5}"
            );
        }
    }

    #[test]
    fn headline_cost_ratio_5_3x() {
        // B=1024: serverless ~5.34x the instance-based cost
        let host = cloud::instance("t2.small").unwrap();
        let inst = cloud::instance("t2.large").unwrap();
        let srv = serverless_cost_per_peer(
            host,
            CostInputs { compute_time_s: 41.2, num_batches: 15, lambda_memory_mb: 4400 },
        )
        .cost_per_peer_usd;
        let ins = instance_cost_per_peer(inst, 258.0).cost_per_peer_usd;
        let ratio = srv / ins;
        assert!((ratio - 5.34).abs() < 0.15, "ratio {ratio}");
    }

    #[test]
    fn actual_billing_is_positive_and_below_eq1_at_scale() {
        let host = cloud::instance("t2.small").unwrap();
        let actual = serverless_cost_actual_billing(host, 41.2, 15, 4400, 60.0);
        assert!(actual > 0.0);
        // Eq.(1) multiplies rate x batches x wall — actual GB-s billing
        // (each lambda billed its own runtime) lands lower here.
        let eq1 = serverless_cost_per_peer(
            host,
            CostInputs { compute_time_s: 41.2, num_batches: 15, lambda_memory_mb: 4400 },
        )
        .cost_per_peer_usd;
        assert!(actual < eq1 * 2.0);
    }
}
