//! `p2pless` — the leader CLI.
//!
//! Subcommands:
//!   train   run a P2P training cluster (real PJRT execution)
//!   exp     regenerate a paper table/figure (see DESIGN.md index)
//!   info    inspect the artifacts manifest + runtime
//!
//! Argument parsing is hand-rolled (the build is fully offline; no clap).

use std::process::ExitCode;
use std::sync::Arc;

use p2pless::config::{Backend, Compression, FailurePolicy, OffloadMode, SyncMode, TrainConfig};
use p2pless::coordinator::Cluster;
use p2pless::error::{Error, Result};
use p2pless::faas::pricing;
use p2pless::harness;
use p2pless::perfmodel;
use p2pless::runtime::{Engine, Manifest};

const USAGE: &str = "\
p2pless — serverless peer-to-peer distributed training (Barrak et al. 2023 reproduction)

USAGE:
    p2pless train [OPTIONS]          run a training cluster
    p2pless exp <ID|all> [OPTIONS]   regenerate a paper table/figure
    p2pless info [--artifacts DIR]   inspect artifacts + runtime

TRAIN OPTIONS:
    --config FILE            JSON config (overridden by the flags below)
    --model NAME             mini_squeezenet | mini_mobilenet | mini_vgg
    --dataset NAME           mnist | cifar
    --peers N                number of peers (default 4)
    --batch N                batch size (default 64; needs a matching artifact)
    --epochs N               epoch limit (default 4)
    --lr F                   learning rate (default 0.05)
    --train-samples N        synthetic training set size
    --val-samples N          validation set size
    --backend B              instance | serverless
    --sync M                 sync | async
    --compression C          none | qsgd:S | topk:FRAC
    --lambda-memory MB       lambda memory (0 = paper Table II rule)
    --lambda-concurrency N   per-peer in-flight branch cap: scheduler
                             admission limit (pipelined) / Map wave
                             size (staged); default 64
    --offload-mode M         staged | pipelined | cross-epoch (default
                             pipelined): staged uploads everything then
                             fans out; pipelined streams each batch
                             through the cluster scheduler as its upload
                             lands; cross-epoch additionally dispatches
                             epoch e+1 before epoch e's barrier/verdict
                             wait so the pool never drains at the epoch
                             boundary. Modeled walls are byte-identical
                             in all three modes
    --pipeline-depth N       cross-epoch in-flight epoch window
                             (default 2; 1 disables the pre-dispatch;
                             >2 is reserved for stale-tolerant modes)
    --sched-fair B           true | false (default true): round-robin
                             branch dispatch across peers vs the greedy
                             lowest-rank-first baseline
    --decode-cache N         decoded-object cache entries (params
                             decoded once per epoch instead of once per
                             branch; 0 disables, default 16)
    --sweep-scratch B        true | false (default true): reclaim each
                             epoch's store scratch (params, parked
                             gradients) by generation after the fan-out;
                             persistent batch objects always survive
    --wire-compression C     none | qsgd:S | topk:FRAC (default none):
                             serverless wire-plane codec — gradient
                             returns park encoded and params delta
                             frames use it as their inner codec; none
                             keeps the data plane byte-identical to the
                             uncompressed path
    --params-delta-every N   delta-encode params uploads against the
                             previous generation, resyncing with a full
                             object every N generations (default 0 =
                             off; needs --decode-cache > 0)
    --params-sharding S      off | N | layer (default off): split each
                             params upload into N shards (or one per
                             model layer) under an SPv1 manifest; only
                             shards whose contents changed are re-put,
                             the rest reuse the prior generation's
                             objects (needs --decode-cache > 0)
    --exec-threads N         FaaS worker-pool threads (0 = machine size);
                             physical fan-out concurrency only — the
                             modeled accounting does not move with N
    --exec-slots N           concurrent PJRT executions (0 = machine
                             size, 1 = serialized honest-timing mode)
    --exec-batch N|auto      fused-execution batch: up to N concurrent
                             gradient branches of the same executable +
                             params version coalesce into one engine
                             dispatch (default 1 = fusion off). Math and
                             modeled accounting are byte-identical at
                             any N; only the measured wall moves — it
                             shrinks when dispatch overhead dominates
                             (best with --exec-slots 1), but a fused
                             group runs on one slot, so wide-open slots
                             lose intra-group parallelism. With stacked
                             AOT artifacts (manifest v2) a full group
                             runs as ONE stacked XLA execution. "auto"
                             sizes the live target adaptively from
                             queue depth between 1 and a ceiling of
                             max(N, 8)
    --exec-batch-wait-us N   fused-group collect window in microseconds
                             (default 500): how long a group waits to
                             fill before dispatching partial
    --on-peer-failure P      abort | takeover | drop (default abort):
                             what survivors do when a peer dies mid-run
                             — abort the whole cluster (seed behavior),
                             take over its batch partition via its
                             epoch-persistent uploads, or drop it from
                             the fold
    --heartbeat-interval-ms N
                             per-peer liveness heartbeat period
                             (default 250)
    --peer-timeout-ms N      silence after which a peer is declared
                             dead (default 30000; must be >= the
                             heartbeat interval)
    --fold-quorum K          fold only the first K of N gradient
                             branches per peer-epoch, by branch index;
                             stragglers still execute and bill but are
                             excluded from the fold (default 0 = all)
    --fault-plan SPEC        deterministic fault injection: semicolon-
                             separated events such as kill:peer1@2 /
                             join:peer1@3 / delay:peer0.branch3@1:5ms /
                             dup:peer2.branch0@1 / storeput:peer0@2 /
                             storeget:peer1@2 / storecorrupt:peer1@2 /
                             storedelay:peer0@1:3ms / brokerdrop:peer1@2
                             / brokerdelay:peer0@1:2ms, or the seeded
                             form rate:kill=0.25,join=0.1,store=0.2,
                             seed=7 (empty = off; any plan arms the
                             membership plane)
    --lambda-retries N       invocation attempts per lambda branch
                             (default 3; 1 = fail fast)
    --retry-backoff-ms N     base of the exponential retry backoff
                             with seeded jitter (default 0 = immediate)
    --store-retries N        store/broker I/O attempts per op under
                             injected chaos (default 3; 1 = fail fast)
    --store-backoff-ms N     base of the store/broker retry backoff
                             (default 0 = immediate)
    --early-stop N           early-stopping patience (0 = off)
    --plateau N              ReduceLROnPlateau patience (0 = off)
    --seed N                 RNG seed
    --artifacts DIR          artifacts directory (default: artifacts)

EXP OPTIONS:
    --quick                  smaller real-exec runs
    --out DIR                results directory (default: results)

EXPERIMENT IDS: table1 fig3 table2 table3 fig4 fig5 fig6 headline all
";

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args {
        positional: Vec::new(),
        flags: std::collections::HashMap::new(),
        switches: std::collections::HashSet::new(),
    };
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            // switches without values
            if matches!(name, "quick" | "help") {
                args.switches.insert(name.to_string());
            } else {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?;
                args.flags.insert(name.to_string(), v.clone());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

fn parse_num<T: std::str::FromStr>(args: &Args, key: &str) -> Result<Option<T>> {
    match args.flags.get(key) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| Error::Config(format!("--{key}: bad value {v:?}"))),
    }
}

fn parse_bool(args: &Args, key: &str) -> Result<Option<bool>> {
    match args.flags.get(key).map(|s| s.as_str()) {
        None => Ok(None),
        Some("true" | "on" | "yes" | "1") => Ok(Some(true)),
        Some("false" | "off" | "no" | "0") => Ok(Some(false)),
        Some(v) => Err(Error::Config(format!("--{key}: bad boolean {v:?}"))),
    }
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => TrainConfig::from_json_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(v) = args.flags.get("model") {
        cfg.model = v.clone();
    }
    if let Some(v) = args.flags.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = parse_num(args, "peers")? {
        cfg.peers = v;
    }
    if let Some(v) = parse_num(args, "batch")? {
        cfg.batch_size = v;
    }
    if let Some(v) = parse_num(args, "epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = parse_num(args, "lr")? {
        cfg.lr = v;
    }
    if let Some(v) = parse_num(args, "train-samples")? {
        cfg.train_samples = v;
    }
    if let Some(v) = parse_num(args, "val-samples")? {
        cfg.val_samples = v;
    }
    if let Some(v) = args.flags.get("backend") {
        cfg.backend = Backend::parse(v)?;
    }
    if let Some(v) = args.flags.get("sync") {
        cfg.sync = SyncMode::parse(v)?;
    }
    if let Some(v) = args.flags.get("compression") {
        cfg.compression = Compression::parse(v)?;
    }
    if let Some(v) = parse_num(args, "lambda-memory")? {
        cfg.lambda_memory_mb = v;
    }
    if let Some(v) = parse_num(args, "lambda-concurrency")? {
        cfg.lambda_concurrency = v;
    }
    if let Some(v) = args.flags.get("offload-mode") {
        cfg.offload_mode = OffloadMode::parse(v)?;
    }
    if let Some(v) = parse_num(args, "pipeline-depth")? {
        cfg.pipeline_depth = v;
    }
    if let Some(v) = parse_bool(args, "sched-fair")? {
        cfg.sched_fair = v;
    }
    if let Some(v) = parse_num(args, "decode-cache")? {
        cfg.decode_cache = v;
    }
    if let Some(v) = parse_bool(args, "sweep-scratch")? {
        cfg.sweep_scratch = v;
    }
    if let Some(v) = args.flags.get("wire-compression") {
        cfg.wire_compression = Compression::parse(v)?;
    }
    if let Some(v) = parse_num(args, "params-delta-every")? {
        cfg.params_delta_every = v;
    }
    if let Some(v) = args.flags.get("params-sharding") {
        cfg.params_sharding = v.clone();
    }
    if let Some(v) = parse_num(args, "exec-threads")? {
        cfg.exec_threads = v;
    }
    if let Some(v) = parse_num(args, "exec-slots")? {
        cfg.exec_slots = v;
    }
    match args.flags.get("exec-batch").map(String::as_str) {
        // adaptive control plane: the numeric knob becomes a ceiling
        // (raised to at least 8 so the controller has room to ramp)
        Some("auto") => {
            cfg.exec_batch_auto = true;
            cfg.exec_batch = cfg.exec_batch.max(8);
        }
        Some(v) => {
            cfg.exec_batch = v.parse().map_err(|_| {
                Error::Config(format!("--exec-batch: bad value {v:?} (want a count or \"auto\")"))
            })?;
        }
        None => {}
    }
    if let Some(v) = parse_num(args, "exec-batch-wait-us")? {
        cfg.exec_batch_wait_us = v;
    }
    if let Some(v) = args.flags.get("on-peer-failure") {
        cfg.on_peer_failure = FailurePolicy::parse(v)?;
    }
    if let Some(v) = parse_num(args, "heartbeat-interval-ms")? {
        cfg.heartbeat_interval_ms = v;
    }
    if let Some(v) = parse_num(args, "peer-timeout-ms")? {
        cfg.peer_timeout_ms = v;
    }
    if let Some(v) = parse_num(args, "fold-quorum")? {
        cfg.fold_quorum = v;
    }
    if let Some(v) = args.flags.get("fault-plan") {
        cfg.fault_plan = v.clone();
    }
    if let Some(v) = parse_num(args, "lambda-retries")? {
        cfg.lambda_retries = v;
    }
    if let Some(v) = parse_num(args, "retry-backoff-ms")? {
        cfg.retry_backoff_ms = v;
    }
    if let Some(v) = parse_num(args, "store-retries")? {
        cfg.store_retries = v;
    }
    if let Some(v) = parse_num(args, "store-backoff-ms")? {
        cfg.store_backoff_ms = v;
    }
    if let Some(v) = parse_num(args, "early-stop")? {
        cfg.early_stop_patience = v;
    }
    if let Some(v) = parse_num(args, "plateau")? {
        cfg.plateau_patience = v;
    }
    if let Some(v) = parse_num(args, "seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.flags.get("artifacts") {
        cfg.artifacts_dir = v.clone();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    println!(
        "training {} on {}: peers={} batch={} epochs={} backend={} sync={} compression={}",
        cfg.model,
        cfg.dataset,
        cfg.peers,
        cfg.batch_size,
        cfg.epochs,
        cfg.backend.name(),
        cfg.sync.name(),
        cfg.compression.to_spec(),
    );
    let report = Cluster::new(cfg)?.run()?;
    println!("\nepoch  val_loss  val_acc");
    for (e, loss, acc) in &report.val_curve {
        println!("{e:>5}  {loss:>8.4}  {acc:>7.3}");
    }
    println!("\nper-stage (all peers):");
    for (stage, s) in &report.stages {
        if s.count > 0 {
            println!(
                "  {:<22} n={:<4} total {:>10.3?}  mean {:>10.3?}  cpu {:>5.1}%  rss {:>5.0} MB",
                stage.to_string(),
                s.count,
                s.total_wall,
                s.mean_wall(),
                s.mean_cpu_pct,
                s.peak_rss_bytes as f64 / 1e6,
            );
        }
    }
    println!(
        "\nbroker: {} msgs / {} bytes; lambda: {} invocations / ${:.5} / {} cold starts",
        report.broker_msgs,
        report.broker_bytes,
        report.lambda_invocations,
        report.lambda_cost_usd,
        report.lambda_cold_starts
    );
    if report.lambda_invocations > 0 {
        println!(
            "lambda fan-out measured wall (worker pool): {:?}",
            report.lambda_measured_wall
        );
        let s = &report.sched;
        println!(
            "scheduler ({} dispatch, {} mode): {} branches, peak queue {}, peak in-flight {}; \
             pool {} threads (peak busy {})",
            if report.config.sched_fair { "round-robin" } else { "greedy" },
            report.config.offload_mode.name(),
            s.submitted,
            s.peak_queued,
            s.peak_in_flight,
            s.exec_threads,
            s.exec_peak_busy,
        );
        for &(rank, served) in &s.per_peer_served {
            println!("  peer {rank}: {served} branches served");
        }
        let c = |name| report.counter(name).unwrap_or(0);
        println!(
            "store: {} puts ({} deduped) / {} gets / {} bytes in; decode cache: \
             {} hits / {} misses; packed literals: {} hits / {} misses; \
             {} objects left",
            c("store.puts"),
            c("store.dedup_hits"),
            c("store.gets"),
            c("store.bytes_in"),
            c("store.decode_hits"),
            c("store.decode_misses"),
            c("store.pack_hits"),
            c("store.pack_misses"),
            report.store_objects,
        );
        if c("faas.retries") > 0 {
            println!(
                "lambda retries: {} extra attempts ({} max per branch, backoff {} ms)",
                c("faas.retries"),
                report.config.lambda_retries,
                report.config.retry_backoff_ms,
            );
        }
        if report.config.wire_compression != Compression::None
            || report.config.params_delta_every > 0
        {
            let raw = c("wire.bytes_raw");
            let wire = c("wire.bytes_wire");
            let pct = if raw > 0 { wire as f64 * 100.0 / raw as f64 } else { 0.0 };
            // bytes-on-wire feeds the modeled transfer terms: per-epoch
            // park time at the modeled store bandwidth, and the S3
            // request + cross-region rate card for the whole run
            println!(
                "wire plane ({}, params delta every {}): {} raw -> {} wire bytes \
                 ({pct:.1}%), {} delta resyncs; encode {:.1} ms / decode {:.1} ms; \
                 modeled park {:?} / transfer ${:.6}",
                report.config.wire_compression.to_spec(),
                report.config.params_delta_every,
                raw,
                wire,
                c("wire.delta_resyncs"),
                c("wire.encode_us") as f64 / 1e3,
                c("wire.decode_us") as f64 / 1e3,
                perfmodel::store_put_time(wire as usize),
                pricing::transfer_cost(wire, c("store.puts"), c("store.gets")),
            );
        }
        if report.config.params_sharding != "off" {
            let total = c("shard.total");
            let reused = c("shard.reused");
            let pct = if total > 0 {
                reused as f64 * 100.0 / total as f64
            } else {
                0.0
            };
            println!(
                "shard plane ({}): {} shard uploads -> {} changed / {} reused \
                 ({pct:.1}%), {} raw bytes kept off the wire",
                report.config.params_sharding,
                total,
                c("shard.changed"),
                reused,
                c("shard.bytes_saved"),
            );
        }
        if report.config.exec_batch > 1 {
            println!(
                "fused exec (batch {}{}): {} fused dispatches / {} branches fused / \
                 {}% mean fill",
                report.config.exec_batch,
                if report.config.exec_batch_auto { " auto" } else { "" },
                c("engine.batched_execs"),
                c("engine.fused_branches"),
                c("engine.batch_fill"),
            );
            println!(
                "stacked exec: {} stacked XLA executions / {} pad lanes wasted / \
                 {} lane promotions",
                c("engine.stacked_execs"),
                c("engine.pad_waste"),
                c("sched.lane_promotions"),
            );
        }
        if report.config.offload_mode == OffloadMode::CrossEpoch {
            println!(
                "cross-epoch: {} epochs pre-dispatched, {:.1} ms total overlap window, \
                 peak {} generations in flight, {} stale publishes suppressed",
                c("offload.predispatched_epochs"),
                c("offload.overlap_wall_us") as f64 / 1e3,
                c("sched.peak_inflight_generations"),
                c("broker.stale_drops"),
            );
        }
    }
    let c = |name| report.counter(name).unwrap_or(0);
    let armed = report.config.on_peer_failure != FailurePolicy::Abort
        || !report.config.fault_plan.is_empty();
    if armed {
        println!(
            "membership ({} policy): {} heartbeats, {} deaths, {} barrier proxies, \
             {} takeover epochs, {} gradients dropped, {} orphan objects swept",
            report.config.on_peer_failure.name(),
            c("membership.heartbeats"),
            c("membership.deaths"),
            c("membership.barrier_proxies"),
            c("membership.takeover_epochs"),
            c("membership.dropped_grads"),
            c("membership.orphans_swept"),
        );
        if c("membership.joins") > 0 {
            println!("elastic joins: {} admitted mid-run", c("membership.joins"));
        }
        if c("store.retries") + c("store.corrupt_refetches") + c("broker.retries") > 0 {
            println!(
                "io chaos: {} store retries, {} corrupt re-fetches, {} broker republishes",
                c("store.retries"),
                c("store.corrupt_refetches"),
                c("broker.retries"),
            );
        }
    }
    if report.config.fold_quorum > 0 {
        println!(
            "fold quorum {}: {} straggler branches excluded from the fold",
            c("fold.quorum"),
            c("fold.stragglers"),
        );
    }
    if !report.config.fault_plan.is_empty() {
        println!(
            "fault plan \"{}\": {} kills / {} joins / {} delays / {} dups / \
             {} store faults / {} broker faults fired",
            report.config.fault_plan,
            c("fault.kills_fired"),
            c("fault.joins_fired"),
            c("fault.delays_fired"),
            c("fault.dups_fired"),
            c("fault.store_faults_fired"),
            c("fault.broker_faults_fired"),
        );
    }
    println!("wall: {:?}", report.wall);
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| Error::Config("exp needs an id (or `all`)".into()))?;
    let quick = args.switches.contains("quick");
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "results".to_string());
    if id == "all" {
        harness::run_all(quick, &out)
    } else {
        harness::run(id, quick, &out, None)
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::new()?);
    println!("platform: {}", engine.platform());
    println!("artifacts dir: {dir}");
    println!("qsgd kernel: n={} s={}", manifest.qsgd.n, manifest.qsgd.s);
    println!("\nmodels:");
    for (key, e) in &manifest.models {
        println!(
            "  {key}: {} params, input {:?}, grad batches {:?}, eval batches {:?}",
            e.param_count,
            e.input,
            e.grad.keys().collect::<Vec<_>>(),
            e.eval.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if args.positional.is_empty() || args.switches.contains("help") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let result = match args.positional[0].as_str() {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "info" => cmd_info(&args),
        other => Err(Error::Config(format!("unknown subcommand {other:?}"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
