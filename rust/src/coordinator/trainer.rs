//! Cluster assembly: build the substrates, partition the data, spawn
//! one peer thread per rank, collect the training report.
//!
//! This is the top-level entry the CLI / examples / harness use for
//! *real* (PJRT-executing) runs. Cloud-scale *modeled* runs live in
//! `harness` and drive `perfmodel` + `faas` directly.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::gradient::GradientWire;
use super::membership::Membership;
use super::peer::{control_queue, GradBackend, Peer, PeerReport, Verdict};
use super::serverless::ServerlessOffload;
use super::sync::EpochBarrier;
use crate::broker::{Broker, FaultPlan, Message, QueueMode, DEFAULT_MESSAGE_CAP};
use crate::compress::{codec_for, WirePlane};
use crate::config::{Backend, FailurePolicy, TrainConfig};
use crate::data::{Dataset, DatasetKind, SyntheticDataset};
use crate::error::{Error, Result};
use crate::faas::{BranchScheduler, Executor, FaasPlatform, RetryPolicy, SchedulerStats};
use crate::harness::faults::FaultPlanSpec;
use crate::metrics::{MetricsRegistry, Stage, StageSummary};
use crate::perfmodel;
use crate::runtime::{Engine, ModelRuntime};
use crate::store::{
    peer_bucket, shard, DecodedCache, ObjectRef, ObjectStore, GEN_PERSISTENT, PARAMS_BUCKET,
};
use crate::util::{Bytes, Json};

/// Everything a finished run reports.
#[derive(Debug)]
pub struct TrainReport {
    pub config: TrainConfig,
    pub peers: Vec<PeerReport>,
    /// (epoch, val_loss, val_acc) from the leader's detector.
    pub val_curve: Vec<(u64, f32, f32)>,
    /// Per-stage aggregates across all peers (Table I shape).
    pub stages: Vec<(Stage, StageSummary)>,
    pub wall: Duration,
    /// Broker stats: (messages, bytes).
    pub broker_msgs: u64,
    pub broker_bytes: u64,
    /// Faas stats if the serverless backend ran.
    pub lambda_invocations: u64,
    pub lambda_cost_usd: f64,
    pub lambda_cold_starts: u64,
    /// Real wall time of the serverless fan-outs, summed over peers
    /// (the measured counterpart of the modeled Map-state wall).
    pub lambda_measured_wall: Duration,
    /// Objects still live in the store at the end of the run — the
    /// per-epoch sweep must keep this at zero for serverless runs.
    pub store_objects: usize,
    /// Cluster branch-scheduler utilization (queue depth, fairness,
    /// per-peer branches served). All zeros for instance-backend runs.
    pub sched: SchedulerStats,
    /// Named utilization counters from the metrics registry
    /// (`sched.*`, `exec.*`).
    pub counters: Vec<(String, u64)>,
}

impl TrainReport {
    pub fn epochs_run(&self) -> usize {
        self.peers.iter().map(|p| p.epochs_run).max().unwrap_or(0)
    }

    /// Look up a named utilization counter (`sched.*`, `exec.*`,
    /// `store.*`, `engine.*`, `wire.*`).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    pub fn final_val_loss(&self) -> Option<f32> {
        self.val_curve.last().map(|&(_, l, _)| l)
    }

    pub fn final_val_acc(&self) -> Option<f32> {
        self.val_curve.last().map(|&(_, _, a)| a)
    }

    pub fn mean_train_loss_last_epoch(&self) -> Option<f32> {
        let losses: Vec<f32> = self
            .peers
            .iter()
            .filter_map(|p| p.train_loss.last().copied())
            .collect();
        if losses.is_empty() {
            None
        } else {
            Some(losses.iter().sum::<f32>() / losses.len() as f32)
        }
    }
}

/// The cluster: owns substrates, spawns peers.
pub struct Cluster {
    config: TrainConfig,
    engine: Arc<Engine>,
    faults: FaultPlan,
}

impl Cluster {
    pub fn new(config: TrainConfig) -> Result<Self> {
        config.validate()?;
        let engine = Arc::new(Engine::with_exec_batching(
            config.exec_slots,
            config.exec_batch,
            Duration::from_micros(config.exec_batch_wait_us),
        )?);
        Ok(Self { config, engine, faults: FaultPlan::default() })
    }

    /// Reuse an existing engine (avoids re-creating the PJRT client).
    /// The engine's execution-slot bound, fused-batch size and collect
    /// window are fixed at construction, so a config that demands a
    /// different `exec_slots`, `exec_batch`, or (with fusion on) a
    /// different `exec_batch_wait_us` is an error — not a silently
    /// ignored knob.
    pub fn with_engine(config: TrainConfig, engine: Arc<Engine>) -> Result<Self> {
        config.validate()?;
        if config.exec_slots != 0 && config.exec_slots != engine.exec_slots() {
            return Err(Error::Config(format!(
                "config wants exec_slots={} but the provided engine was built with {}",
                config.exec_slots,
                engine.exec_slots()
            )));
        }
        if config.exec_batch != engine.exec_batch() {
            return Err(Error::Config(format!(
                "config wants exec_batch={} but the provided engine was built with {}",
                config.exec_batch,
                engine.exec_batch()
            )));
        }
        // the collect window is equally engine-fixed, but only matters
        // once fusion is on — a mismatched window on a non-fusing
        // engine has no observable effect
        if config.exec_batch > 1
            && Duration::from_micros(config.exec_batch_wait_us) != engine.exec_batch_wait()
        {
            return Err(Error::Config(format!(
                "config wants exec_batch_wait_us={} but the provided engine was built \
                 with {} us",
                config.exec_batch_wait_us,
                engine.exec_batch_wait().as_micros()
            )));
        }
        Ok(Self { config, engine, faults: FaultPlan::default() })
    }

    /// Inject broker faults (drop/delay) for resilience experiments.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    pub fn engine(&self) -> Arc<Engine> {
        self.engine.clone()
    }

    /// Build substrates, run all peers to completion.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        let kind = DatasetKind::parse(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset {:?}", cfg.dataset)))?;

        // ---- substrates ------------------------------------------------
        let broker = Arc::new(Broker::new(DEFAULT_MESSAGE_CAP, self.faults));
        let store = Arc::new(ObjectStore::new());
        let platform = Arc::new(FaasPlatform::default());
        // one worker pool shared by every peer's fan-outs, fronted by
        // the cluster-wide admission scheduler (round-robin across
        // peers, per-peer in-flight caps)
        let executor = Arc::new(Executor::new(cfg.exec_threads));
        let scheduler = BranchScheduler::new(executor.clone(), cfg.sched_fair);
        // with execution fusion on, release a peer's same-generation
        // branches in bursts so they meet in the engine batcher
        if cfg.exec_batch_auto {
            // adaptive control plane: the controller resizes both the
            // scheduler's coalesce burst and the engine's effective
            // fused-group target from live queue depth, between 1 and
            // the --exec-batch ceiling
            let engine = self.engine.clone();
            scheduler.enable_autotune(
                cfg.exec_batch,
                Box::new(move |n| engine.set_exec_batch_effective(n)),
            );
        } else {
            scheduler.set_coalesce(cfg.exec_batch);
            self.engine.set_exec_batch_effective(cfg.exec_batch);
        }
        // shared across every peer's handlers: the params object each
        // epoch's branches read is decoded once, not once per branch
        let decode_cache = Arc::new(DecodedCache::new(cfg.decode_cache));
        // the serverless wire plane: cluster-shared codec knobs and
        // wire.* byte/time counters for the store-mediated params
        // uploads and gradient returns (fully off by default)
        let wire_plane = Arc::new(WirePlane::new(
            cfg.wire_compression,
            cfg.params_delta_every,
            cfg.seed,
        ));
        let metrics = Arc::new(MetricsRegistry::new());
        let runtime = Arc::new(ModelRuntime::load(
            self.engine.clone(),
            &cfg.artifacts_dir,
            &cfg.model_key(),
        )?);
        // the sharded-params plane: resolved against this model's packed
        // size (and, in layer mode, the AOT manifest's per-layer
        // params_spec) after the runtime loads; off by default, which
        // keeps the monolithic params object byte-identical
        let shard_plane = {
            let spec = shard::ShardSpec::parse(&cfg.params_sharding)?;
            let layer_sizes: Vec<usize> =
                runtime.entry.params_spec.iter().map(|&(_, n)| n).collect();
            Arc::new(shard::ShardPlane::new(
                spec,
                runtime.entry.param_count,
                &layer_sizes,
            )?)
        };

        // ---- data -------------------------------------------------------
        let train = SyntheticDataset::new(kind, cfg.seed).generate(cfg.train_samples);
        // validation shares the training prototypes (same classes) but
        // draws independent noise — otherwise "generalization" would be
        // measured against a different task.
        let val = Arc::new(
            SyntheticDataset::new(kind, cfg.seed ^ 0x76616c)
                .with_prototype_seed(cfg.seed)
                .generate(cfg.val_samples),
        );
        let partitions = train.partition(cfg.peers)?;

        // ---- membership + fault plan --------------------------------------
        // the injected-fault plan (kills / joins / branch delays /
        // duplicate deliveries / store + broker I/O faults) is resolved
        // once for the whole cluster — before the barrier, because
        // scheduled growth joins widen it
        let fault_plan = {
            let spec = FaultPlanSpec::parse(&cfg.fault_plan)?;
            if spec.is_empty() {
                None
            } else {
                Some(Arc::new(spec.resolve(cfg.peers, cfg.epochs)?))
            }
        };
        // the membership plane arms only when something can actually die
        // survivably: a non-abort policy, or an active fault plan. An
        // unarmed table publishes no heartbeats and reaps nothing, so
        // default runs keep their exact broker/message trace.
        let armed = cfg.on_peer_failure != FailurePolicy::Abort || fault_plan.is_some();
        let membership = Arc::new(Membership::new(
            broker.clone(),
            cfg.peers,
            cfg.on_peer_failure,
            Duration::from_millis(cfg.heartbeat_interval_ms),
            Duration::from_millis(cfg.peer_timeout_ms),
            armed,
        )?);
        // scheduled joins widen the membership table (and, for growth
        // ranks, the epoch barrier) up front — admission itself stays an
        // epoch-boundary event driven by the leader
        let joins: Vec<(usize, u64)> = fault_plan
            .as_ref()
            .map(|p| p.join_events())
            .unwrap_or_default();
        membership.set_join_schedule(&joins)?;

        // ---- queues + barrier -------------------------------------------
        // gradient queues for every rank the cluster can ever hold, so
        // consumers never race a growth joiner's queue into existence
        for rank in 0..membership.max_width() {
            broker.declare(&Broker::gradient_queue(rank), QueueMode::LatestOnly)?;
        }
        broker.declare(&control_queue(), QueueMode::Fifo)?;
        if !joins.is_empty() {
            broker.declare(&Broker::join_queue(), QueueMode::Fifo)?;
        }
        let barrier = Arc::new(EpochBarrier::with_growth(
            &broker,
            cfg.peers,
            membership.growth_epochs(),
        )?);

        // store/broker chaos: injected I/O faults route every put/get
        // and publish through the deterministic hooks, retried under
        // the shared `--store-retries`/`--store-backoff-ms` policy.
        // Plans without I/O faults leave both planes untouched.
        if let Some(plan) = &fault_plan {
            if plan.has_io_faults() {
                let io_retry =
                    RetryPolicy::configured(cfg.store_retries, cfg.store_backoff_ms, cfg.seed);
                store.arm_chaos(plan.clone(), io_retry);
                broker.arm_chaos(plan.clone(), io_retry);
            }
        }
        // branch retry policy: seeded per-attempt jitter on top of the
        // exponential backoff, shared by every peer's fan-outs
        let retry = RetryPolicy::configured(cfg.lambda_retries, cfg.retry_backoff_ms, cfg.seed);

        // ---- spawn peers --------------------------------------------------
        // engine fusion counters are engine-lifetime monotonic and the
        // engine may be shared across runs: report this run's delta
        let (batched0, fused0) = self.engine.batch_stats();
        let (stacked0, pad0) = self.engine.stacked_stats();
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(cfg.peers);
        let mut partitions = partitions.into_iter();
        for rank in 0..cfg.peers {
            let partition = partitions.next().unwrap();
            let codec = Arc::from(codec_for(cfg.compression, cfg.seed ^ rank as u64));
            let wire = GradientWire::new(codec, store.clone(), DEFAULT_MESSAGE_CAP);
            let backend = match cfg.backend {
                Backend::Instance => GradBackend::Local { pallas: true },
                Backend::Serverless => {
                    let mem = if cfg.lambda_memory_mb > 0 {
                        cfg.lambda_memory_mb
                    } else {
                        // Table II sizing rule for the paper counterpart
                        perfmodel::PaperModel::from_key(&cfg.model_key())
                            .map(|m| {
                                perfmodel::lambda_memory_for(
                                    perfmodel::paper_model(m),
                                    cfg.batch_size,
                                )
                            })
                            .unwrap_or(1769)
                    };
                    let mut offload = ServerlessOffload::new(
                        platform.clone(),
                        store.clone(),
                        runtime.clone(),
                        scheduler.clone(),
                        decode_cache.clone(),
                        wire_plane.clone(),
                        shard_plane.clone(),
                        rank,
                        mem,
                        cfg.lambda_concurrency,
                        cfg.offload_mode,
                        cfg.sweep_scratch,
                        cfg.pipeline_depth,
                    )?;
                    offload.set_retry(retry);
                    offload.set_fold_quorum(cfg.fold_quorum);
                    if let Some(plan) = &fault_plan {
                        offload.set_faults(plan.clone());
                    }
                    GradBackend::Serverless(offload)
                }
            };
            let mut peer = Peer::new(
                rank,
                cfg.clone(),
                partition,
                val.clone(),
                runtime.clone(),
                broker.clone(),
                wire,
                backend,
                barrier.clone(),
                metrics.clone(),
            )?;
            peer.set_membership(membership.clone());
            peer.set_store_plane(store.clone(), decode_cache.clone());
            if let Some(plan) = &fault_plan {
                peer.set_faults(plan.clone());
            }
            // under a survivable policy (takeover/drop) a failed peer is
            // declared dead *from its own thread* — survivors route
            // around it immediately, the heartbeat timeout only has to
            // catch hangs — and its scheduler lane is evicted so queued
            // branches stop competing for pool slots. Otherwise keep the
            // historical fail-fast: abort the broker so peers parked on
            // gradient waits or the epoch barrier wake with
            // Error::Aborted instead of hanging.
            let broker = broker.clone();
            let thread_membership = membership.clone();
            let thread_scheduler = scheduler.clone();
            let survivable = armed && cfg.on_peer_failure != FailurePolicy::Abort;
            handles.push(std::thread::spawn(move || {
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || peer.run(),
                ));
                match outcome {
                    Ok(result) => {
                        match &result {
                            Err(e) if !matches!(e, Error::Aborted(_)) => {
                                if survivable {
                                    thread_membership
                                        .declare_dead(rank, &format!("peer {rank} failed: {e}"));
                                    thread_scheduler.evict_peer(rank);
                                } else {
                                    broker.abort(&format!("peer {rank} failed: {e}"));
                                }
                            }
                            Err(_) => {}
                            Ok(_) => thread_membership.mark_done(rank),
                        }
                        result
                    }
                    Err(_) => {
                        if survivable {
                            thread_membership.declare_dead(rank, &format!("peer {rank} panicked"));
                            thread_scheduler.evict_peer(rank);
                        } else {
                            broker.abort(&format!("peer {rank} panicked"));
                        }
                        Err(Error::Broker(format!("peer {rank} thread panicked")))
                    }
                }
            }));
        }

        // ---- spawn joiners ------------------------------------------------
        // one thread per scheduled join, up front: it announces its
        // rank on the join queue, parks on its admit queue until a
        // leader admits (or declines) it at the epoch boundary, decodes
        // the leader's warm-start params through the shared cache, and
        // enters the epoch loop mid-run. The backend is built only
        // after admission, so a declined join leaves no scheduler lane
        // or registered function behind.
        let mut join_handles = Vec::with_capacity(joins.len());
        for &(jrank, jepoch) in &joins {
            let cfg = cfg.clone();
            let val = val.clone();
            let runtime = runtime.clone();
            let broker2 = broker.clone();
            let store2 = store.clone();
            let platform2 = platform.clone();
            let scheduler2 = scheduler.clone();
            let decode_cache2 = decode_cache.clone();
            let wire_plane2 = wire_plane.clone();
            let shard_plane2 = shard_plane.clone();
            let metrics2 = metrics.clone();
            let membership2 = membership.clone();
            let fault_plan2 = fault_plan.clone();
            let barrier2 = barrier.clone();
            let survivable = armed && cfg.on_peer_failure != FailurePolicy::Abort;
            join_handles.push((
                jrank,
                std::thread::spawn(move || {
                    let run = || -> Result<Option<PeerReport>> {
                        broker2.publish(
                            &Broker::join_queue(),
                            Message::new(jrank, jepoch, Bytes::new()),
                        )?;
                        let admit_q = broker2
                            .declare(&Broker::join_admit_queue(jrank), QueueMode::Fifo)?;
                        while !admit_q.await_version_timeout(1, membership2.wait_slice())? {}
                        let msg = admit_q.snapshot().into_iter().next().ok_or_else(|| {
                            Error::Broker(format!("joiner {jrank}: empty admit queue"))
                        })?;
                        let j = Json::parse(
                            std::str::from_utf8(&msg.payload)
                                .map_err(|e| Error::Broker(e.to_string()))?,
                        )?;
                        if !j.req("admit")?.as_bool().unwrap_or(false) {
                            return Ok(None);
                        }
                        let start = j.req("start")?.as_u64().ok_or_else(|| {
                            Error::Broker(format!("joiner {jrank}: admit without start epoch"))
                        })?;
                        let warm_ref = ObjectRef {
                            bucket: j.req("bucket")?.as_str().unwrap_or_default().to_string(),
                            key: j.req("key")?.as_str().unwrap_or_default().to_string(),
                            size: j.req("size")?.as_u64().unwrap_or(0) as usize,
                        };
                        // warm-start: decode through the shared cache
                        // (the chaos-gated get verifies the content
                        // hash), then drop the entry and the object
                        let warm = {
                            let decoded = decode_cache2.get_or_decode(&warm_ref, &store2)?;
                            let v = decoded.as_ref().clone();
                            decode_cache2.invalidate(&warm_ref);
                            store2.delete(&warm_ref.bucket, &warm_ref.key)?;
                            v
                        };
                        let codec = Arc::from(codec_for(cfg.compression, cfg.seed ^ jrank as u64));
                        let wire = GradientWire::new(codec, store2.clone(), DEFAULT_MESSAGE_CAP);
                        let backend = match cfg.backend {
                            Backend::Instance => GradBackend::Local { pallas: true },
                            Backend::Serverless => {
                                let mem = if cfg.lambda_memory_mb > 0 {
                                    cfg.lambda_memory_mb
                                } else {
                                    perfmodel::PaperModel::from_key(&cfg.model_key())
                                        .map(|m| {
                                            perfmodel::lambda_memory_for(
                                                perfmodel::paper_model(m),
                                                cfg.batch_size,
                                            )
                                        })
                                        .unwrap_or(1769)
                                };
                                let mut offload = ServerlessOffload::new(
                                    platform2.clone(),
                                    store2.clone(),
                                    runtime.clone(),
                                    scheduler2.clone(),
                                    decode_cache2.clone(),
                                    wire_plane2.clone(),
                                    shard_plane2.clone(),
                                    jrank,
                                    mem,
                                    cfg.lambda_concurrency,
                                    cfg.offload_mode,
                                    cfg.sweep_scratch,
                                    cfg.pipeline_depth,
                                )?;
                                offload.set_retry(retry);
                                offload.set_fold_quorum(cfg.fold_quorum);
                                if let Some(plan) = &fault_plan2 {
                                    offload.set_faults(plan.clone());
                                }
                                GradBackend::Serverless(offload)
                            }
                        };
                        // a revival's scheduler lane was evicted when
                        // the rank died; growth lanes were just created
                        scheduler2.readmit_peer(jrank);
                        // placeholder partition — run_joined absorbs the
                        // handle the admission registered for this rank
                        let placeholder = Dataset {
                            x: Vec::new(),
                            y: Vec::new(),
                            h: val.h,
                            w: val.w,
                            c: val.c,
                            nclass: val.nclass,
                        };
                        let mut peer = Peer::new(
                            jrank,
                            cfg.clone(),
                            placeholder,
                            val.clone(),
                            runtime.clone(),
                            broker2.clone(),
                            wire,
                            backend,
                            barrier2.clone(),
                            metrics2.clone(),
                        )?;
                        peer.set_membership(membership2.clone());
                        peer.set_store_plane(store2.clone(), decode_cache2.clone());
                        if let Some(plan) = &fault_plan2 {
                            peer.set_faults(plan.clone());
                        }
                        peer.run_joined(start, warm).map(Some)
                    };
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run));
                    match outcome {
                        Ok(result) => {
                            match &result {
                                Err(e) if !matches!(e, Error::Aborted(_)) => {
                                    if survivable {
                                        membership2.declare_dead(
                                            jrank,
                                            &format!("joiner {jrank} failed: {e}"),
                                        );
                                        scheduler2.evict_peer(jrank);
                                    } else {
                                        broker2.abort(&format!("joiner {jrank} failed: {e}"));
                                    }
                                }
                                Err(_) => {}
                                Ok(Some(_)) => membership2.mark_done(jrank),
                                Ok(None) => {}
                            }
                            result
                        }
                        Err(_) => {
                            if survivable {
                                membership2
                                    .declare_dead(jrank, &format!("joiner {jrank} panicked"));
                                scheduler2.evict_peer(jrank);
                            } else {
                                broker2.abort(&format!("joiner {jrank} panicked"));
                            }
                            Err(Error::Broker(format!("joiner {jrank} thread panicked")))
                        }
                    }
                }),
            ));
        }

        let mut peers = Vec::with_capacity(cfg.peers);
        // join everyone (threads exit promptly after an abort), then
        // surface the root cause — not the secondary Aborted errors
        let mut failure: Option<Error> = None;
        let mut record = |failure: &mut Option<Error>, e: Error| {
            // a real error supersedes a secondary Aborted; first wins
            // otherwise
            let supersedes = match (failure.as_ref(), &e) {
                (None, _) => true,
                (Some(Error::Aborted(_)), Error::Aborted(_)) => false,
                (Some(Error::Aborted(_)), _) => true,
                _ => false,
            };
            if supersedes {
                *failure = Some(e);
            }
        };
        let survivable = armed && cfg.on_peer_failure != FailurePolicy::Abort;
        for (rank, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(p)) => peers.push(p),
                // under a survivable policy a declared-dead peer's error
                // is a recorded death, not a run failure — the survivors
                // carried the epoch to completion around it
                Ok(Err(_)) if survivable && !membership.is_alive(rank) => {}
                Ok(Err(e)) => record(&mut failure, e),
                // unreachable in practice: the spawn wrapper catches
                // peer panics and returns them as Ok(Err(..))
                Err(_) => record(
                    &mut failure,
                    Error::Broker("peer thread panicked".into()),
                ),
            }
        }
        // release any joiner whose admission boundary never came (early
        // stop, abort, or a failed run): publish a decline so its
        // thread stops parking, then join them all
        for &(jrank, _) in &joins {
            if membership.awaiting_join(jrank, u64::MAX) {
                if let Ok(q) = broker.declare(&Broker::join_admit_queue(jrank), QueueMode::Fifo) {
                    let mut j = Json::obj();
                    j.set("admit", false);
                    let _ = q.publish(Message::new(
                        0,
                        0,
                        Bytes::from(j.to_string().into_bytes()),
                    ));
                }
            }
        }
        for (jrank, h) in join_handles {
            match h.join() {
                Ok(Ok(Some(p))) => peers.push(p),
                // declined: the join never landed, nothing to report
                Ok(Ok(None)) => {}
                Ok(Err(_)) if survivable && !membership.is_alive(jrank) => {}
                Ok(Err(e)) => record(&mut failure, e),
                Err(_) => record(
                    &mut failure,
                    Error::Broker("joiner thread panicked".into()),
                ),
            }
        }
        if let Some(e) = failure {
            return Err(e);
        }
        if peers.is_empty() {
            let dead: Vec<String> = membership
                .dead_peers()
                .into_iter()
                .map(|(r, why)| format!("peer {r}: {why}"))
                .collect();
            return Err(Error::Runtime(format!(
                "no peer survived the run [{}]",
                dead.join("; ")
            )));
        }
        let wall = t0.elapsed();

        // ---- collect the leader's verdict history ------------------------
        // the control queue is FIFO, so the full per-epoch curve survives
        let mut val_curve = Vec::new();
        if let Ok(ctl) = broker.get(&control_queue()) {
            for m in ctl.snapshot() {
                if let Ok(v) = Verdict::from_message(&m) {
                    val_curve.push((v.epoch, v.val_loss, v.val_acc));
                }
            }
        }

        let (broker_msgs, broker_bytes) = broker.stats();
        let fstats = platform.stats();
        let lambda_measured_wall = peers.iter().map(|p| p.lambda_measured_wall).sum();

        // ---- store teardown ----------------------------------------------
        // training is over: drop the epoch-persistent batch objects so
        // `store_objects` measures per-epoch sweep hygiene only — any
        // scratch generation a sweep missed stays visible
        for rank in 0..membership.max_width() {
            store.sweep_generation(&peer_bucket(rank), GEN_PERSISTENT);
        }
        // elastic runs stage warm-start params in the persistent
        // generation of the shared params bucket; an admitted joiner
        // deletes its copy after decoding, this catches declined or
        // interrupted admissions
        if !joins.is_empty() {
            store.sweep_generation(PARAMS_BUCKET, GEN_PERSISTENT);
        }
        // dead peers never ran their own teardown to the end of the run:
        // straggling branches on their evicted lanes (and takeover
        // fan-outs through their handlers) may have parked scratch after
        // the per-epoch sweeps. Sweep every generation of every dead
        // bucket so `store_objects` stays an invariant, and count what
        // was actually reclaimed.
        let mut orphans_swept = 0usize;
        for (rank, _) in membership.dead_peers() {
            for e in 1..=cfg.epochs as u64 {
                orphans_swept += store.sweep_generation(&peer_bucket(rank), e);
            }
        }

        // ---- scheduler / executor utilization ----------------------------
        let sched = scheduler.stats();
        metrics.set_counter("sched.branches_submitted", sched.submitted);
        metrics.set_counter("sched.branches_completed", sched.completed);
        metrics.set_counter("sched.peak_queue_depth", sched.peak_queued as u64);
        metrics.set_counter("sched.peak_in_flight", sched.peak_in_flight as u64);
        metrics.set_counter("sched.lane_promotions", sched.lane_promotions);
        metrics.set_counter(
            "sched.peak_inflight_generations",
            sched.peak_inflight_generations as u64,
        );
        metrics.set_counter("exec.threads", executor.threads() as u64);
        metrics.set_counter("exec.peak_busy", executor.peak_busy() as u64);
        for &(rank, served) in &sched.per_peer_served {
            metrics.set_counter(&format!("sched.peer{rank}.served"), served);
        }
        let (store_puts, store_gets, store_bytes) = store.stats();
        metrics.set_counter("store.puts", store_puts);
        metrics.set_counter("store.gets", store_gets);
        metrics.set_counter("store.bytes_in", store_bytes);
        metrics.set_counter("store.dedup_hits", store.dedup_hits());
        metrics.set_counter("store.decode_hits", decode_cache.hits());
        metrics.set_counter("store.decode_misses", decode_cache.misses());
        metrics.set_counter("store.pack_hits", decode_cache.pack_hits());
        metrics.set_counter("store.pack_misses", decode_cache.pack_misses());
        // wire plane: raw vs on-wire bytes, codec time, chain resyncs
        // (all zero with the plane off — pinned by the invariance test)
        metrics.set_counter("wire.bytes_raw", wire_plane.bytes_raw());
        metrics.set_counter("wire.bytes_wire", wire_plane.bytes_wire());
        metrics.set_counter("wire.encode_us", wire_plane.encode_us());
        metrics.set_counter("wire.decode_us", wire_plane.decode_us());
        metrics.set_counter("wire.delta_resyncs", wire_plane.delta_resyncs());
        // sharded-params plane: shard uploads attempted, actually changed
        // (re-encoded + re-put), reused from the prior generation, and the
        // raw bytes those reuses kept off the wire (all zero when off)
        metrics.set_counter("shard.total", shard_plane.total());
        metrics.set_counter("shard.changed", shard_plane.changed());
        metrics.set_counter("shard.reused", shard_plane.reused());
        metrics.set_counter("shard.bytes_saved", shard_plane.bytes_saved());
        // execution fusion: fused dispatches, branches that rode them,
        // and the mean group fill as a percentage of --exec-batch
        let (batched, fused) = self.engine.batch_stats();
        let (batched, fused) = (batched - batched0, fused - fused0);
        metrics.set_counter("engine.batched_execs", batched);
        metrics.set_counter("engine.fused_branches", fused);
        let fill = if batched > 0 {
            fused * 100 / (batched * self.engine.exec_batch() as u64)
        } else {
            0
        };
        metrics.set_counter("engine.batch_fill", fill);
        // stacked execution: groups that completed as ONE stacked XLA
        // execution, and the padding lanes those stacks wasted
        let (stacked, pad) = self.engine.stacked_stats();
        metrics.set_counter("engine.stacked_execs", stacked - stacked0);
        metrics.set_counter("engine.pad_waste", pad - pad0);
        // cross-epoch overlap accounting: how many epoch fan-outs were
        // pre-dispatched ahead of the boundary, and for how long they
        // executed before collection began
        let predispatched: usize = peers.iter().map(|p| p.predispatched_epochs).sum();
        let overlap: Duration = peers.iter().map(|p| p.overlap_wall).sum();
        metrics.set_counter("offload.predispatched_epochs", predispatched as u64);
        metrics.set_counter("offload.overlap_wall_us", overlap.as_micros() as u64);
        metrics.set_counter("broker.stale_drops", broker.stale_drops());
        // elastic-membership plane: liveness traffic, deaths, and how the
        // cluster routed around them
        metrics.set_counter("membership.heartbeats", membership.heartbeats());
        metrics.set_counter("membership.deaths", membership.deaths());
        metrics.set_counter("membership.barrier_proxies", membership.barrier_proxies());
        metrics.set_counter("membership.takeover_epochs", membership.takeover_epochs());
        metrics.set_counter("membership.dropped_grads", membership.dropped_grads());
        metrics.set_counter("membership.orphans_swept", orphans_swept as u64);
        metrics.set_counter("membership.joins", membership.joins());
        // chaos-hardened I/O planes: injected-fault retries and the
        // hash-verified re-fetches that caught corrupted reads (all
        // zero when no I/O faults are armed)
        metrics.set_counter("store.retries", store.chaos_retries());
        metrics.set_counter("store.corrupt_refetches", store.corrupt_refetches());
        metrics.set_counter("broker.retries", broker.chaos_retries());
        // k-of-n partial folds and the configured Lambda retry policy
        metrics.set_counter("fold.quorum", cfg.fold_quorum as u64);
        let stragglers: usize = peers.iter().map(|p| p.fold_stragglers).sum();
        metrics.set_counter("fold.stragglers", stragglers as u64);
        let retries: usize = peers.iter().map(|p| p.lambda_retries).sum();
        metrics.set_counter("faas.retries", retries as u64);
        metrics.set_counter("sched.lane_evictions", sched.lane_evictions);
        // fault-injection accounting (all zero without --fault-plan)
        if let Some(plan) = &fault_plan {
            metrics.set_counter("fault.kills_fired", plan.kills_fired());
            metrics.set_counter("fault.delays_fired", plan.delays_fired());
            metrics.set_counter("fault.dups_fired", plan.dups_fired());
            metrics.set_counter("fault.joins_fired", plan.joins_fired());
            metrics.set_counter("fault.store_faults_fired", plan.store_faults_fired());
            metrics.set_counter("fault.broker_faults_fired", plan.broker_faults_fired());
        }

        Ok(TrainReport {
            config: cfg.clone(),
            peers,
            val_curve,
            stages: metrics.all(),
            wall,
            broker_msgs,
            broker_bytes,
            lambda_invocations: fstats.invocations,
            lambda_cost_usd: platform.total_cost_usd(),
            lambda_cold_starts: fstats.cold_starts,
            lambda_measured_wall,
            store_objects: store.total_objects(),
            sched,
            counters: metrics.counters(),
        })
    }
}
