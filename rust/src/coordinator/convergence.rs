//! Convergence detection (§III-B.7): Early Stopping + ReduceLROnPlateau,
//! driven by validation loss each epoch.

/// Early stopping: stop when the monitored loss has not improved by at
/// least `min_delta` for `patience` consecutive epochs.
#[derive(Debug, Clone)]
pub struct EarlyStopping {
    patience: usize,
    min_delta: f32,
    best: f32,
    stale: usize,
    stopped: bool,
}

impl EarlyStopping {
    pub fn new(patience: usize, min_delta: f32) -> Self {
        Self {
            patience,
            min_delta,
            best: f32::INFINITY,
            stale: 0,
            stopped: false,
        }
    }

    /// Disabled detector (patience 0): never stops.
    pub fn disabled() -> Self {
        Self::new(0, 0.0)
    }

    /// Record an epoch's validation loss; returns true if training
    /// should stop now.
    pub fn observe(&mut self, val_loss: f32) -> bool {
        if self.patience == 0 {
            return false;
        }
        if val_loss < self.best - self.min_delta {
            self.best = val_loss;
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.patience {
                self.stopped = true;
            }
        }
        self.stopped
    }

    pub fn stopped(&self) -> bool {
        self.stopped
    }

    pub fn best(&self) -> f32 {
        self.best
    }
}

/// ReduceLROnPlateau: multiply the lr by `factor` when the loss has
/// plateaued for `patience` epochs; never below `min_lr`.
#[derive(Debug, Clone)]
pub struct ReduceLROnPlateau {
    patience: usize,
    factor: f32,
    min_lr: f32,
    best: f32,
    stale: usize,
    lr: f32,
    reductions: usize,
}

impl ReduceLROnPlateau {
    pub fn new(initial_lr: f32, patience: usize, factor: f32, min_lr: f32) -> Self {
        assert!(factor > 0.0 && factor < 1.0, "factor must be in (0,1)");
        Self {
            patience,
            factor,
            min_lr,
            best: f32::INFINITY,
            stale: 0,
            lr: initial_lr,
            reductions: 0,
        }
    }

    /// Disabled scheduler: lr never changes.
    pub fn disabled(initial_lr: f32) -> Self {
        Self {
            patience: 0,
            factor: 0.5,
            min_lr: 0.0,
            best: f32::INFINITY,
            stale: 0,
            lr: initial_lr,
            reductions: 0,
        }
    }

    /// Record an epoch's validation loss; returns the lr to use next.
    pub fn observe(&mut self, val_loss: f32) -> f32 {
        if self.patience == 0 {
            return self.lr;
        }
        if val_loss < self.best - 1e-6 {
            self.best = val_loss;
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.patience {
                self.lr = (self.lr * self.factor).max(self.min_lr);
                self.reductions += 1;
                self.stale = 0;
            }
        }
        self.lr
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn reductions(&self) -> usize {
        self.reductions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_stop_after_patience() {
        let mut es = EarlyStopping::new(2, 0.0);
        assert!(!es.observe(1.0));
        assert!(!es.observe(0.9)); // improved
        assert!(!es.observe(0.95)); // stale 1
        assert!(es.observe(0.94)); // stale 2 -> stop
        assert!(es.stopped());
        assert_eq!(es.best(), 0.9);
    }

    #[test]
    fn early_stop_min_delta() {
        let mut es = EarlyStopping::new(1, 0.1);
        assert!(!es.observe(1.0));
        // 0.95 is an improvement but below min_delta -> counts stale
        assert!(es.observe(0.95));
    }

    #[test]
    fn early_stop_disabled_never_stops() {
        let mut es = EarlyStopping::disabled();
        for _ in 0..100 {
            assert!(!es.observe(5.0));
        }
    }

    #[test]
    fn plateau_halves_lr() {
        let mut sch = ReduceLROnPlateau::new(0.1, 2, 0.5, 0.001);
        assert_eq!(sch.observe(1.0), 0.1);
        assert_eq!(sch.observe(1.0), 0.1); // stale 1
        let lr = sch.observe(1.0); // stale 2 -> reduce
        assert!((lr - 0.05).abs() < 1e-7);
        assert_eq!(sch.reductions(), 1);
    }

    #[test]
    fn plateau_respects_min_lr() {
        let mut sch = ReduceLROnPlateau::new(0.1, 1, 0.1, 0.05);
        sch.observe(1.0);
        sch.observe(1.0); // reduce -> clamped at 0.05
        assert!((sch.lr() - 0.05).abs() < 1e-7);
        sch.observe(1.0);
        assert!((sch.lr() - 0.05).abs() < 1e-7);
    }

    #[test]
    fn plateau_resets_on_improvement() {
        let mut sch = ReduceLROnPlateau::new(0.1, 2, 0.5, 0.0);
        sch.observe(1.0);
        sch.observe(1.0); // stale 1
        sch.observe(0.5); // improvement resets
        sch.observe(0.6); // stale 1
        assert_eq!(sch.reductions(), 0);
        assert!((sch.lr() - 0.1).abs() < 1e-7);
    }
}
