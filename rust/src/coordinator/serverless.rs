//! The serverless gradient-offload path — the paper's core contribution
//! (§III-C, §IV-D): per-batch gradient computation fanned out to Lambda
//! functions through a dynamically-generated Step Functions Map state.
//!
//! Faithful to the paper's dataflow:
//! 1. the peer uploads its pre-processed, **pre-batched** data partition
//!    to S3 *once, before training* ([`ServerlessOffload::upload_batches`]);
//!    every epoch re-reads the same batch objects, so a steady-state
//!    epoch uploads exactly one object — the current params;
//! 2. a state machine is generated *from the batch count* — one Map
//!    branch per batch;
//! 3. each Lambda pulls its batch + params from S3 (the params decode is
//!    memoized in a [`DecodedCache`], so N branches decode once), computes
//!    the gradient with the AOT PJRT executable (the same artifact the
//!    instance path runs), parks the gradient in S3 and returns its
//!    UUID + loss;
//! 4. the peer collects and averages the per-batch gradients.
//!
//! Per-epoch scratch (the params object, the parked gradients) is tagged
//! with the epoch's **generation** and reclaimed by a generation-scoped
//! sweep after the fan-out — success or failure — while the persistent
//! batch objects survive for the next epoch. The generation rides inside
//! every branch payload, doubling as the param-version tag cross-epoch
//! pipelining will key on.
//!
//! Two dispatch modes ([`OffloadMode`]):
//!
//! - **staged** — build every branch payload, execute the Map state,
//!   then collect (the PR-1 shape; the modeled wall's reference
//!   implementation);
//! - **pipelined** — each batch's branch is submitted through the
//!   cluster-wide [`BranchScheduler`] as soon as it is built, and
//!   gradients stream into the accumulator (in branch order, so the
//!   math is bit-identical) while later branches dispatch. The *modeled*
//!   wall/billed/cost are byte-identical to the staged path; only the
//!   *measured* wall shrinks with the overlap.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::gradient::GradAccumulator;
use crate::config::OffloadMode;
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::faas::{
    BranchScheduler, FaasPlatform, FunctionSpec, Handler, PipelinedMap, RetryPolicy,
    StateMachine,
};
use crate::runtime::ModelRuntime;
use crate::store::{DecodedCache, ObjectRef, ObjectStore};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::{Bytes, Json};

/// Binary batch object stored in S3: `[u32 b][u32 elems][x f32s][y i32s]`.
pub fn pack_batch(batch: &Batch, elems: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + batch.x.len() * 4 + batch.y.len() * 4);
    out.extend_from_slice(&(batch.size as u32).to_le_bytes());
    out.extend_from_slice(&(elems as u32).to_le_bytes());
    out.extend_from_slice(&f32s_to_bytes(&batch.x));
    for &y in &batch.y {
        out.extend_from_slice(&y.to_le_bytes());
    }
    out
}

/// Inverse of [`pack_batch`].
pub fn unpack_batch(data: &[u8]) -> Result<Batch> {
    if data.len() < 8 {
        return Err(Error::Faas("truncated batch object".into()));
    }
    let b = u32::from_le_bytes(data[0..4].try_into().unwrap()) as usize;
    let elems = u32::from_le_bytes(data[4..8].try_into().unwrap()) as usize;
    let xbytes = b * elems * 4;
    let need = 8 + xbytes + b * 4;
    if data.len() != need {
        return Err(Error::Faas(format!(
            "batch object: expected {need} bytes, got {}",
            data.len()
        )));
    }
    let x = bytes_to_f32s(&data[8..8 + xbytes]);
    let y = data[8 + xbytes..]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Batch { x, y, size: b })
}

fn ref_to_json(r: &ObjectRef) -> Json {
    let mut j = Json::obj();
    j.set("bucket", r.bucket.as_str())
        .set("key", r.key.as_str())
        .set("size", r.size);
    j
}

fn ref_from_json(j: &Json) -> Result<ObjectRef> {
    Ok(ObjectRef {
        bucket: j
            .req("bucket")?
            .as_str()
            .ok_or_else(|| Error::Faas("bucket".into()))?
            .to_string(),
        key: j
            .req("key")?
            .as_str()
            .ok_or_else(|| Error::Faas("key".into()))?
            .to_string(),
        size: j.req("size")?.as_usize().unwrap_or(0),
    })
}

/// Build one branch request: cached batch ref + this epoch's params ref
/// + the generation tag the handler scopes its scratch writes to.
fn branch_payload(params_ref: &ObjectRef, batch_ref: &ObjectRef, generation: u64) -> Bytes {
    let mut req = Json::obj();
    req.set("params", ref_to_json(params_ref))
        .set("batch", ref_to_json(batch_ref))
        .set("gen", generation);
    Bytes::from(req.to_string().into_bytes())
}

/// Parse one gradient-Lambda response: `{"loss": <f64>, "grad": <ref>}`.
/// A non-numeric loss is a handler bug and is surfaced as an error —
/// folding `NaN` into the epoch mean would silently poison every
/// downstream convergence decision.
fn parse_branch_response(out: &[u8]) -> Result<(f64, ObjectRef)> {
    let resp =
        Json::parse(std::str::from_utf8(out).map_err(|e| Error::Faas(e.to_string()))?)?;
    let loss = resp
        .req("loss")?
        .as_f64()
        .ok_or_else(|| Error::Faas("handler response: \"loss\" is not a number".into()))?;
    let grad_ref = ref_from_json(resp.req("grad")?)?;
    Ok((loss, grad_ref))
}

/// The serverless offload engine bound to one peer.
pub struct ServerlessOffload {
    platform: Arc<FaasPlatform>,
    store: Arc<ObjectStore>,
    runtime: Arc<ModelRuntime>,
    scheduler: Arc<BranchScheduler>,
    decode_cache: Arc<DecodedCache>,
    function: String,
    bucket: String,
    peer: usize,
    concurrency: usize,
    mode: OffloadMode,
    sweep_scratch: bool,
    /// Epoch-persistent batch objects, uploaded once by
    /// [`Self::upload_batches`] and referenced by every epoch's branch
    /// payloads thereafter.
    batch_refs: Mutex<Vec<ObjectRef>>,
}

/// Result of one serverless epoch fan-out.
#[derive(Debug)]
pub struct OffloadResult {
    /// Mean loss across batches.
    pub loss: f32,
    /// Average of the per-batch gradients.
    pub grads: Vec<f32>,
    /// Modeled wall time of the fan-out (parallel branches overlap
    /// under the deterministic greedy schedule).
    pub wall: Duration,
    /// Measured wall time: the Map dispatch alone in staged mode, the
    /// whole submit/invoke/collect pipeline in pipelined mode.
    pub measured_wall: Duration,
    /// Billed lambda-seconds.
    pub billed: Duration,
    pub cost_usd: f64,
    pub invocations: usize,
    pub cold_starts: usize,
}

impl ServerlessOffload {
    /// Register the gradient Lambda for `peer_rank` and return the
    /// offloader. `memory_mb` sizes the function (paper Table II rule);
    /// `concurrency` becomes the peer's admission cap on the cluster
    /// scheduler (and the Map concurrency in staged mode);
    /// `decode_cache` memoizes the params decode across branches;
    /// `sweep_scratch = false` keeps per-epoch scratch alive (debugging
    /// aid — the store then grows with the epoch count).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        platform: Arc<FaasPlatform>,
        store: Arc<ObjectStore>,
        runtime: Arc<ModelRuntime>,
        scheduler: Arc<BranchScheduler>,
        decode_cache: Arc<DecodedCache>,
        peer_rank: usize,
        memory_mb: u32,
        concurrency: usize,
        mode: OffloadMode,
        sweep_scratch: bool,
    ) -> Result<Self> {
        let function = format!("grad-{}-peer{}", runtime.entry.key, peer_rank);
        let bucket = crate::store::peer_bucket(peer_rank);
        store.create_bucket(&bucket);
        scheduler.register_peer(peer_rank, concurrency);

        // The Lambda handler: parse refs, pull params (via the decoded
        // cache) + batch from S3, run the AOT grad executable, park the
        // gradient in S3 under the request's generation tag.
        let h_store = store.clone();
        let h_runtime = runtime.clone();
        let h_bucket = bucket.clone();
        let h_cache = decode_cache.clone();
        let handler: Handler = Arc::new(move |payload: &Bytes| {
            let req = Json::parse(
                std::str::from_utf8(payload).map_err(|e| Error::Faas(e.to_string()))?,
            )?;
            let params_ref = ref_from_json(req.req("params")?)?;
            let batch_ref = ref_from_json(req.req("batch")?)?;
            let generation = req
                .req("gen")?
                .as_u64()
                .ok_or_else(|| Error::Faas("branch request: \"gen\" is not a number".into()))?;
            let params = h_cache.get_or_decode(&params_ref, &h_store)?;
            let batch = unpack_batch(&h_store.get_ref(&batch_ref)?)?;
            let out = h_runtime.grad(batch.size, &params, &batch.x, &batch.y, true)?;
            // a real Lambda has its own environment: the time this
            // branch queued for an engine slot is a simulation artifact
            // and must not be billed (the handler's own work — S3 I/O,
            // decode, execution — stays billed)
            crate::faas::report_unbilled(out.queue_wait);
            let grad_ref = h_store.put_new_gen(
                &h_bucket,
                Bytes::from(f32s_to_bytes(&out.grads)),
                generation,
            )?;
            let mut resp = Json::obj();
            resp.set("loss", out.loss as f64)
                .set("grad", ref_to_json(&grad_ref));
            Ok(Bytes::from(resp.to_string().into_bytes()))
        });
        platform.register(FunctionSpec::new(&function, memory_mb, handler))?;
        Ok(Self {
            platform,
            store,
            runtime,
            scheduler,
            decode_cache,
            function,
            bucket,
            peer: peer_rank,
            concurrency,
            mode,
            sweep_scratch,
            batch_refs: Mutex::new(Vec::new()),
        })
    }

    pub fn function_name(&self) -> &str {
        &self.function
    }

    pub fn mode(&self) -> OffloadMode {
        self.mode
    }

    /// Batch objects currently uploaded (0 before [`Self::upload_batches`]).
    pub fn num_batches(&self) -> usize {
        self.batch_refs.lock().unwrap().len()
    }

    /// Pack and upload the peer's pre-batched partition *once*, before
    /// training (paper §III-B). The refs persist across epochs; a
    /// steady-state epoch then uploads only the params object. Calling
    /// this twice is a contract violation, not an idempotent refresh —
    /// the batch objects are immutable for the life of the run.
    pub fn upload_batches(&self, batches: &[Batch]) -> Result<usize> {
        if batches.is_empty() {
            return Err(Error::Faas("no batches to offload".into()));
        }
        let elems = {
            let (h, w, c) = self.runtime.input_shape();
            h * w * c
        };
        let mut refs = self.batch_refs.lock().unwrap();
        if !refs.is_empty() {
            return Err(Error::Faas(format!(
                "peer {}: batch objects already uploaded ({})",
                self.peer,
                refs.len()
            )));
        }
        for batch in batches {
            refs.push(
                self.store
                    .put_new(&self.bucket, Bytes::from(pack_batch(batch, elems)))?,
            );
        }
        Ok(refs.len())
    }

    /// Run one epoch's batches through the dynamically-generated state
    /// machine and average the gradients. Uploads exactly one object —
    /// the params, tagged with this epoch's generation — and sweeps that
    /// generation (params + parked gradients) on every exit path, so the
    /// store stays bounded while the batch objects persist.
    pub fn compute_epoch(&self, epoch: usize, params: &[f32]) -> Result<OffloadResult> {
        let batch_refs = self.batch_refs.lock().unwrap().clone();
        if batch_refs.is_empty() {
            return Err(Error::Faas(
                "no batch objects uploaded — call upload_batches first".into(),
            ));
        }
        // the epoch number is the generation (== the param version the
        // branch payloads advertise); GEN_PERSISTENT is u64::MAX so any
        // realistic epoch index is a valid scratch generation
        let generation = epoch as u64;
        let params_ref = self.store.put_new_gen(
            &self.bucket,
            Bytes::from(f32s_to_bytes(params)),
            generation,
        )?;
        let outcome = match self.mode {
            OffloadMode::Staged => {
                self.fan_out_epoch_staged(epoch, &params_ref, &batch_refs, generation)
            }
            OffloadMode::Pipelined => {
                self.fan_out_epoch_pipelined(&params_ref, &batch_refs, generation)
            }
        };
        if self.sweep_scratch {
            self.store.sweep_generation(&self.bucket, generation);
        }
        // the params key is never read again (next epoch gets a fresh
        // key), so its cache entry is dead weight either way
        self.decode_cache.invalidate(&params_ref);
        outcome
    }

    /// Parse a branch response and fold it into the running epoch state.
    fn fold_branch(
        &self,
        out: &[u8],
        acc: &mut GradAccumulator,
        loss_sum: &mut f64,
    ) -> Result<()> {
        let (loss, grad_ref) = parse_branch_response(out)?;
        *loss_sum += loss;
        acc.add(&bytes_to_f32s(&self.store.get_ref(&grad_ref)?))
    }

    /// Staged: build every payload, fan out, collect. Scratch objects
    /// are swept by the caller ([`Self::compute_epoch`]) on every exit
    /// path.
    fn fan_out_epoch_staged(
        &self,
        epoch: usize,
        params_ref: &ObjectRef,
        batch_refs: &[ObjectRef],
        generation: u64,
    ) -> Result<OffloadResult> {
        let items: Vec<Bytes> = batch_refs
            .iter()
            .map(|r| branch_payload(params_ref, r, generation))
            .collect();
        // dynamic state machine: one branch per batch, dispatched
        // across the shared worker pool
        let sm = StateMachine::parallel_batches(
            format!("{}-epoch{epoch}", self.function),
            &self.function,
            items,
            vec![],
            self.concurrency,
        );
        let report = sm.execute_with(&self.platform, self.scheduler.executor())?;
        // collect + average (streaming: one running sum instead of
        // materializing every per-batch gradient)
        let outputs = report
            .outputs
            .first()
            .ok_or_else(|| Error::Faas("state machine produced no outputs".into()))?;
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        for out in outputs {
            self.fold_branch(out, &mut acc, &mut loss_sum)?;
        }
        let avg = acc.mean()?;
        Ok(OffloadResult {
            loss: (loss_sum / outputs.len() as f64) as f32,
            grads: avg,
            wall: report.wall,
            measured_wall: report.measured_wall,
            billed: report.billed,
            cost_usd: report.cost_usd,
            invocations: report.invocations,
            cold_starts: report.cold_starts,
        })
    }

    /// Pipelined: every branch is admitted to the cluster scheduler as
    /// soon as its payload is built, and landed gradients fold into the
    /// accumulator (in branch order — bit-identical math) while later
    /// branches are still dispatching. Modeled accounting is
    /// byte-identical to the staged path; the measured wall shows the
    /// real submit/invoke/collect overlap.
    fn fan_out_epoch_pipelined(
        &self,
        params_ref: &ObjectRef,
        batch_refs: &[ObjectRef],
        generation: u64,
    ) -> Result<OffloadResult> {
        let mut pipe = PipelinedMap::new(
            self.scheduler.clone(),
            self.platform.clone(),
            self.peer,
            &self.function,
            batch_refs.len(),
            self.concurrency,
            RetryPolicy::default(),
        )?;
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        for batch_ref in batch_refs {
            pipe.submit(branch_payload(params_ref, batch_ref, generation), None);
            // drain whatever already landed: collection overlaps dispatch
            while let Some((_, out)) = pipe.poll_output() {
                self.fold_branch(&out, &mut acc, &mut loss_sum)?;
            }
        }
        while let Some((_, out)) = pipe.next_output() {
            self.fold_branch(&out, &mut acc, &mut loss_sum)?;
        }
        let report = pipe.finish()?;
        Ok(OffloadResult {
            loss: (loss_sum / batch_refs.len() as f64) as f32,
            grads: acc.mean()?,
            wall: report.wall,
            measured_wall: report.measured_wall,
            billed: report.billed,
            cost_usd: report.cost_usd,
            invocations: report.invocations,
            cold_starts: report.cold_starts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;

    #[test]
    fn batch_pack_roundtrip() {
        let b = Batch { x: vec![0.5, -1.0, 2.0, 0.0], y: vec![3, 7], size: 2 };
        let packed = pack_batch(&b, 2);
        let back = unpack_batch(&packed).unwrap();
        assert_eq!(back.x, b.x);
        assert_eq!(back.y, b.y);
        assert_eq!(back.size, 2);
    }

    #[test]
    fn unpack_rejects_truncated() {
        let b = Batch { x: vec![1.0; 4], y: vec![0, 1], size: 2 };
        let mut packed = pack_batch(&b, 2);
        packed.pop();
        assert!(unpack_batch(&packed).is_err());
        assert!(unpack_batch(&[1, 2, 3]).is_err());
    }

    #[test]
    fn ref_json_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k-1".into(), size: 42 };
        let back = ref_from_json(&ref_to_json(&r)).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn branch_payload_carries_generation() {
        let p = ObjectRef { bucket: "b".into(), key: "params".into(), size: 8 };
        let b = ObjectRef { bucket: "b".into(), key: "batch".into(), size: 16 };
        let payload = branch_payload(&p, &b, 7);
        let req = Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
        assert_eq!(req.req("gen").unwrap().as_u64(), Some(7));
        assert_eq!(ref_from_json(req.req("params").unwrap()).unwrap(), p);
        assert_eq!(ref_from_json(req.req("batch").unwrap()).unwrap(), b);
    }

    #[test]
    fn branch_response_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 8 };
        let mut resp = Json::obj();
        resp.set("loss", 0.25).set("grad", ref_to_json(&r));
        let (loss, gref) =
            parse_branch_response(resp.to_string().as_bytes()).unwrap();
        assert_eq!(loss, 0.25);
        assert_eq!(gref, r);
    }

    #[test]
    fn non_numeric_loss_is_an_error_not_nan() {
        // regression: a handler echoing a malformed loss used to fold
        // f64::NAN into the epoch mean and silently poison it
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 8 };
        let mut resp = Json::obj();
        resp.set("loss", "oops").set("grad", ref_to_json(&r));
        let err = parse_branch_response(resp.to_string().as_bytes()).unwrap_err();
        assert!(
            err.to_string().contains("loss"),
            "error must name the bad field: {err}"
        );
        // a missing loss is equally fatal
        let mut resp = Json::obj();
        resp.set("grad", ref_to_json(&r));
        assert!(parse_branch_response(resp.to_string().as_bytes()).is_err());
    }

    // Full offload integration (real PJRT) lives in rust/tests/.
}
