//! The serverless gradient-offload path — the paper's core contribution
//! (§III-C, §IV-D): per-batch gradient computation fanned out to Lambda
//! functions through a dynamically-generated Step Functions Map state.
//!
//! Faithful to the paper's dataflow:
//! 1. the peer uploads its pre-processed, **pre-batched** data partition
//!    to S3 *once, before training* ([`ServerlessOffload::upload_batches`]);
//!    every epoch re-reads the same batch objects, so a steady-state
//!    epoch uploads exactly one object — the current params. That upload
//!    is content-deduplicated through the shared [`PARAMS_BUCKET`]:
//!    synchronous peers produce identical params bytes every epoch, so
//!    the *cluster* stores one params object per epoch and each peer
//!    merely holds a reference (released when its generation retires);
//! 2. a state machine is generated *from the batch count* — one Map
//!    branch per batch;
//! 3. each Lambda pulls its batch + params from S3 (the params decode is
//!    memoized in a [`DecodedCache`], so N branches decode once — and the
//!    batch object's input literals are checked out of the cache's packed
//!    sidecar, so they are packed once per object, not once per branch),
//!    computes the gradient with the AOT PJRT executable (the same
//!    artifact the instance path runs) — routed through the engine's
//!    execution batcher, so concurrent branches of the same params
//!    version fuse into one engine dispatch — parks the gradient in S3
//!    and returns its UUID + loss;
//! 4. the peer collects and averages the per-batch gradients.
//!
//! Per-epoch scratch is tagged with the epoch's **generation**: the
//! peer's parked gradients are reclaimed by a generation-scoped sweep
//! right after the fan-out — success or failure — while the persistent
//! batch objects survive for the next epoch. The *shared* params
//! reference is released **one epoch late** (or at teardown): another
//! peer may still be uploading the identical bytes for the same epoch,
//! and the epoch barrier guarantees every peer has uploaded v(e) before
//! anyone computes e+1, so the lag is exactly what keeps the refcounted
//! dedupe — and its counters — deterministic. The generation rides
//! inside every branch payload, doubling as the param-version tag
//! cross-epoch pipelining keys on.
//!
//! Three dispatch modes ([`OffloadMode`]):
//!
//! - **staged** — build every branch payload, execute the Map state,
//!   then collect (the PR-1 shape; the modeled wall's reference
//!   implementation);
//! - **pipelined** — each batch's branch is submitted through the
//!   cluster-wide [`BranchScheduler`] as soon as it is built, and
//!   gradients stream into the accumulator (in branch order, so the
//!   math is bit-identical) while later branches dispatch. The *modeled*
//!   wall/billed/cost are byte-identical to the staged path; only the
//!   *measured* wall shrinks with the overlap.
//! - **cross-epoch** — pipelined, plus the epoch boundary itself is
//!   overlapped: the fan-out is split into
//!   [`ServerlessOffload::dispatch_epoch`] (upload params v(e),
//!   generation-tag and submit every branch) and
//!   [`ServerlessOffload::collect_epoch`] (fold the oldest in-flight
//!   epoch, in branch order). The peer dispatches epoch e+1 right after
//!   its model update — *before* the convergence eval, the barrier wait
//!   and the verdict read — so the pool keeps executing e+1 branches
//!   while inter-peer coordination for epoch e completes. Folds are
//!   keyed by the generation tag and can never mix param versions; the
//!   scratch sweep **lags one live generation** (gen e is reclaimed
//!   when e+2 dispatches, at the latest at run teardown) so a
//!   stale-tolerant tail branch of epoch e can always re-read params
//!   v(e); the live params versions are pinned in the [`DecodedCache`].
//!   Modeled wall/billed/cost remain byte-identical to staged at any
//!   `pipeline_depth`; only the measured wall shrinks.
//!
//! Orthogonal to the dispatch mode, the **wire plane**
//! ([`crate::compress::WirePlane`]) compresses what actually crosses
//! the store: `--params-delta-every N` frames params uploads as deltas
//! against the previous generation (resident under the lagged sweep),
//! and `--wire-compression` quantizes the parked gradient returns,
//! decoded right before the fold. With both knobs off every store byte
//! — payloads, objects, counters — is identical to the uncompressed
//! plane; see `docs/ARCHITECTURE.md` ("the wire plane").
//!
//! Generation lifecycle in cross-epoch mode (one peer, depth 2):
//!
//! ```text
//!   dispatch(e)          collect(e)      dispatch(e+1)      dispatch(e+2)
//!   ──────────▶ in-flight ─────────▶ retired(lagged) ─────────▶ swept
//!   put params v(e)      fold all       params v(e) kept       drain barrier,
//!   pin cache entry      branches in    + pinned while         sweep gen e,
//!   submit N branches    gen order      e+1 runs (lag=1)       drop entry+pin
//! ```
//!
//! Driving a cross-epoch cluster (needs the AOT artifacts on disk):
//!
//! ```no_run
//! use p2pless::config::{Backend, OffloadMode, TrainConfig};
//! use p2pless::coordinator::Cluster;
//!
//! # fn main() -> p2pless::Result<()> {
//! let cfg = TrainConfig {
//!     peers: 2,
//!     backend: Backend::Serverless,
//!     offload_mode: OffloadMode::CrossEpoch,
//!     pipeline_depth: 2,
//!     ..Default::default()
//! };
//! let report = Cluster::new(cfg)?.run()?;
//! println!(
//!     "epochs pre-dispatched ahead of the boundary: {:?}",
//!     report.counter("offload.predispatched_epochs"),
//! );
//! # Ok(())
//! # }
//! ```

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::gradient::GradAccumulator;
use crate::compress::{ParamsChain, WirePlane};
use crate::config::OffloadMode;
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::faas::{
    BranchScheduler, FaasPlatform, FunctionSpec, Handler, PipelinedMap, RetryPolicy,
    StateMachine,
};
use crate::harness::faults::FaultPlan as InjectedFaults;
use crate::runtime::{ModelRuntime, PackedBatch};
use crate::store::shard::{
    self, ShardManifest, ShardPlane, ShardState, SHARD_KIND_RAW, SHARD_KIND_WIRE,
};
use crate::store::{DecodedCache, ObjectRef, ObjectStore, PARAMS_BUCKET};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::{Bytes, Json};

/// Binary batch object stored in S3: `[u32 b][u32 elems][x f32s][y i32s]`.
pub fn pack_batch(batch: &Batch, elems: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + batch.x.len() * 4 + batch.y.len() * 4);
    out.extend_from_slice(&(batch.size as u32).to_le_bytes());
    out.extend_from_slice(&(elems as u32).to_le_bytes());
    out.extend_from_slice(&f32s_to_bytes(&batch.x));
    for &y in &batch.y {
        out.extend_from_slice(&y.to_le_bytes());
    }
    out
}

/// Inverse of [`pack_batch`].
pub fn unpack_batch(data: &[u8]) -> Result<Batch> {
    if data.len() < 8 {
        return Err(Error::Faas("truncated batch object".into()));
    }
    let b = u32::from_le_bytes(data[0..4].try_into().unwrap()) as usize;
    let elems = u32::from_le_bytes(data[4..8].try_into().unwrap()) as usize;
    let xbytes = b * elems * 4;
    let need = 8 + xbytes + b * 4;
    if data.len() != need {
        return Err(Error::Faas(format!(
            "batch object: expected {need} bytes, got {}",
            data.len()
        )));
    }
    let x = bytes_to_f32s(&data[8..8 + xbytes]);
    let y = data[8 + xbytes..]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Batch { x, y, size: b })
}

fn ref_to_json(r: &ObjectRef) -> Json {
    let mut j = Json::obj();
    j.set("bucket", r.bucket.as_str())
        .set("key", r.key.as_str())
        .set("size", r.size);
    j
}

fn ref_from_json(j: &Json) -> Result<ObjectRef> {
    Ok(ObjectRef {
        bucket: j
            .req("bucket")?
            .as_str()
            .ok_or_else(|| Error::Faas("bucket".into()))?
            .to_string(),
        key: j
            .req("key")?
            .as_str()
            .ok_or_else(|| Error::Faas("key".into()))?
            .to_string(),
        size: j.req("size")?.as_usize().unwrap_or(0),
    })
}

/// Build one branch request: cached batch ref + this epoch's params ref
/// + the generation tag the handler scopes its scratch writes to.
/// `branch` (the batch index) rides along **only** when the wire
/// plane's gradient path is on — it seeds the per-branch quantizer —
/// so the `--wire-compression none` payload stays byte-identical to
/// the uncompressed plane.
fn branch_payload(
    params_ref: &ObjectRef,
    batch_ref: &ObjectRef,
    generation: u64,
    branch: Option<u64>,
) -> Bytes {
    let mut req = Json::obj();
    req.set("params", ref_to_json(params_ref))
        .set("batch", ref_to_json(batch_ref))
        .set("gen", generation);
    if let Some(idx) = branch {
        req.set("idx", idx);
    }
    Bytes::from(req.to_string().into_bytes())
}

/// Parse one gradient-Lambda response: `{"loss": <f64>, "grad": <ref>}`.
/// A non-numeric loss is a handler bug and is surfaced as an error —
/// folding `NaN` into the epoch mean would silently poison every
/// downstream convergence decision.
fn parse_branch_response(out: &[u8]) -> Result<(f64, ObjectRef)> {
    let resp =
        Json::parse(std::str::from_utf8(out).map_err(|e| Error::Faas(e.to_string()))?)?;
    let loss = resp
        .req("loss")?
        .as_f64()
        .ok_or_else(|| Error::Faas("handler response: \"loss\" is not a number".into()))?;
    let grad_ref = ref_from_json(resp.req("grad")?)?;
    Ok((loss, grad_ref))
}

/// Shared slot for the injected fault plan: the Lambda handler is
/// registered at construction but the plan arrives later (via
/// [`ServerlessOffload::set_faults`]), so the handler reads it through
/// this slot. `None` (the default) injects nothing.
type FaultSlot = Arc<Mutex<Option<Arc<InjectedFaults>>>>;

/// Every store reference one peer holds for one generation's params:
/// the **primary** object the branch payloads name (the `SPv1` manifest
/// with sharding on, the params object itself otherwise) plus the
/// per-shard objects the manifest resolves to — freshly stored *or*
/// retained from a prior generation. The whole handle lives and dies as
/// one unit through the lagged-release lifecycle, which is exactly what
/// keeps a reused shard's object alive while any manifest naming it is
/// still in its sweep window.
struct ParamsHandle {
    primary: ObjectRef,
    shards: Vec<ObjectRef>,
}

impl ParamsHandle {
    /// The monolithic plane's handle: one object, no shards.
    fn monolithic(primary: ObjectRef) -> Self {
        Self { primary, shards: Vec::new() }
    }
}

/// Resolve a sharded params upload on the handler side: parse the
/// `SPv1` manifest, decode every shard through the shared cache — each
/// *changed* shard decodes exactly once cluster-wide, reused shards are
/// already resident under their own refs — verify each shard's content
/// hash, and memoize the assembled vector under the manifest's own ref
/// so sibling branches of the same generation reassemble nothing.
fn resolve_sharded_params(
    wire: &WirePlane,
    manifest_ref: &ObjectRef,
    cache: &DecodedCache,
    store: &ObjectStore,
) -> Result<Arc<Vec<f32>>> {
    cache.get_or_decode_with(manifest_ref, store, &|bytes| {
        let manifest = ShardManifest::from_wire(bytes)?;
        let expected = if wire.params_on() { SHARD_KIND_WIRE } else { SHARD_KIND_RAW };
        let mut out = Vec::with_capacity(manifest.total_elems);
        for entry in &manifest.shards {
            if entry.kind != expected {
                return Err(Error::Store(format!(
                    "shard {}: manifest kind {} does not match the wire \
                     plane's expected kind {expected}",
                    entry.id, entry.kind
                )));
            }
            // per-shard cache keys are the shard objects themselves, so
            // this recursion memoizes independently of the assembled
            // manifest entry; decode_params handles both the framed and
            // the raw layout, matching the uniform manifest kind
            let decoded = wire.decode_params(&entry.object, cache, store)?;
            shard::verify_shard(entry, &decoded)?;
            out.extend_from_slice(&decoded);
        }
        Ok(out)
    })
}

/// One dispatched-but-not-yet-collected epoch (cross-epoch mode).
struct InflightEpoch {
    epoch: usize,
    generation: u64,
    params: ParamsHandle,
    pipe: PipelinedMap,
    batches: usize,
    dispatched_at: Instant,
}

/// The serverless offload engine bound to one peer.
pub struct ServerlessOffload {
    platform: Arc<FaasPlatform>,
    store: Arc<ObjectStore>,
    runtime: Arc<ModelRuntime>,
    scheduler: Arc<BranchScheduler>,
    decode_cache: Arc<DecodedCache>,
    /// Cluster-shared wire-plane knobs + `wire.*` counters. With both
    /// paths off ([`WirePlane::off`]) every store byte is identical to
    /// the uncompressed plane.
    wire: Arc<WirePlane>,
    /// This peer's generation-keyed params delta chain (wire plane's
    /// params path; idle when `params_delta_every == 0` or when the
    /// shard plane supersedes it with per-shard chains).
    chain: ParamsChain,
    /// Cluster-shared shard-plane layout + `shard.*` counters
    /// ([`ShardPlane::off`] reproduces the monolithic params plane byte
    /// for byte).
    shard: Arc<ShardPlane>,
    /// This peer's per-shard upload history: content hashes for change
    /// detection, prior objects for cross-generation reuse.
    shard_state: ShardState,
    /// Per-shard delta chains (wire params path × shard plane): shard i
    /// delta-encodes against its own previous frame, and a reused shard
    /// re-keys its chain instead of breaking it.
    shard_chains: Vec<ParamsChain>,
    function: String,
    bucket: String,
    peer: usize,
    concurrency: usize,
    mode: OffloadMode,
    sweep_scratch: bool,
    /// Cross-epoch window: max epochs in flight at once (>= 1).
    pipeline_depth: usize,
    /// Retry policy for every branch invocation (`--lambda-retries` /
    /// `--retry-backoff-ms`); defaults to the historical hardcoded
    /// policy (3 attempts, no backoff).
    retry: RetryPolicy,
    /// k-of-n partial folds (`--fold-quorum`): only the first k
    /// branches (by index) fold into the gradient/wall; the rest are
    /// stragglers — executed and billed. 0 (default) folds everything.
    fold_quorum: usize,
    /// Injected fault plan shared with the Lambda handler (delays fire
    /// inside the handler; duplicates add shadow invocations).
    faults: FaultSlot,
    /// Epoch-persistent batch objects, uploaded once by
    /// [`Self::upload_batches`] and referenced by every epoch's branch
    /// payloads thereafter.
    batch_refs: Mutex<Vec<ObjectRef>>,
    /// Cross-epoch mode: dispatched epochs, oldest first.
    inflight: Mutex<VecDeque<InflightEpoch>>,
    /// Cross-epoch mode: collected generations whose scratch sweep is
    /// lagged (the newest entry stays alive while the next epoch runs).
    retired: Mutex<VecDeque<(u64, ParamsHandle)>>,
    /// Staged/pipelined modes: the previous epoch's params reference,
    /// released one epoch late. A fast peer finishing its fan-out must
    /// not drive the shared deduplicated params object's refcount to
    /// zero while a slower peer's *same-epoch* upload is still on its
    /// way — deferring the release past the epoch barrier makes the
    /// dedup/decode counters exact instead of timing-dependent. The
    /// parked generation's drain and gradient sweep already happened
    /// when its epoch completed; only the params release remains.
    /// Drained by the next epoch's fan-out or [`Self::finish_run`].
    /// Tagged with its generation so a takeover can locate the still-
    /// resident params object for the epoch being recovered.
    pending_release: Mutex<Option<(u64, ParamsHandle)>>,
}

/// Result of one serverless epoch fan-out.
#[derive(Debug)]
pub struct OffloadResult {
    /// Mean loss across batches.
    pub loss: f32,
    /// Average of the per-batch gradients.
    pub grads: Vec<f32>,
    /// Modeled wall time of the fan-out (parallel branches overlap
    /// under the deterministic greedy schedule).
    pub wall: Duration,
    /// Measured wall time: the Map dispatch alone in staged mode, the
    /// whole submit/invoke/collect pipeline in pipelined mode.
    pub measured_wall: Duration,
    /// Billed lambda-seconds.
    pub billed: Duration,
    pub cost_usd: f64,
    pub invocations: usize,
    pub cold_starts: usize,
    /// Extra invocation attempts beyond the first, across all branches
    /// (the configured Lambda retry policy at work).
    pub retries: usize,
    /// Branches that executed (and billed) but were excluded from the
    /// fold by the k-of-n quorum.
    pub stragglers: usize,
    /// Cross-epoch mode: how long this epoch had been dispatched before
    /// collection began — the overlap window the pre-dispatch bought
    /// (zero in staged/pipelined modes and for non-pre-dispatched
    /// epochs).
    pub overlap: Duration,
}

impl ServerlessOffload {
    /// Register the gradient Lambda for `peer_rank` and return the
    /// offloader. `memory_mb` sizes the function (paper Table II rule);
    /// `concurrency` becomes the peer's admission cap on the cluster
    /// scheduler (and the Map concurrency in staged mode);
    /// `decode_cache` memoizes the params decode across branches;
    /// `wire` carries the cluster-shared wire-plane knobs/counters
    /// ([`WirePlane::off`] reproduces the uncompressed plane byte for
    /// byte); `shard` carries the cluster-shared shard-plane layout and
    /// `shard.*` counters ([`ShardPlane::off`] reproduces the
    /// monolithic params plane byte for byte);
    /// `sweep_scratch = false` keeps per-epoch scratch alive
    /// (debugging aid — the store then grows with the epoch count);
    /// `pipeline_depth` bounds the cross-epoch in-flight window
    /// (ignored by staged/pipelined modes; clamped to >= 1).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        platform: Arc<FaasPlatform>,
        store: Arc<ObjectStore>,
        runtime: Arc<ModelRuntime>,
        scheduler: Arc<BranchScheduler>,
        decode_cache: Arc<DecodedCache>,
        wire: Arc<WirePlane>,
        shard_plane: Arc<ShardPlane>,
        peer_rank: usize,
        memory_mb: u32,
        concurrency: usize,
        mode: OffloadMode,
        sweep_scratch: bool,
        pipeline_depth: usize,
    ) -> Result<Self> {
        let function = format!("grad-{}-peer{}", runtime.entry.key, peer_rank);
        let bucket = crate::store::peer_bucket(peer_rank);
        store.create_bucket(&bucket);
        store.create_bucket(PARAMS_BUCKET);
        scheduler.register_peer(peer_rank, concurrency);

        // The Lambda handler: parse refs, pull params (via the decoded
        // cache) + batch from S3, run the AOT grad executable — through
        // the engine's execution batcher, tagged with the request's
        // params version so concurrent same-version branches fuse into
        // one engine dispatch — and park the gradient in S3 under the
        // request's generation tag.
        let faults: FaultSlot = Arc::new(Mutex::new(None));
        let h_store = store.clone();
        let h_runtime = runtime.clone();
        let h_bucket = bucket.clone();
        let h_cache = decode_cache.clone();
        let h_wire = wire.clone();
        let h_shard = shard_plane.clone();
        let h_faults = faults.clone();
        let h_peer = peer_rank;
        let handler: Handler = Arc::new(move |payload: &Bytes| {
            let req = Json::parse(
                std::str::from_utf8(payload).map_err(|e| Error::Faas(e.to_string()))?,
            )?;
            let params_ref = ref_from_json(req.req("params")?)?;
            let batch_ref = ref_from_json(req.req("batch")?)?;
            let generation = req
                .req("gen")?
                .as_u64()
                .ok_or_else(|| Error::Faas("branch request: \"gen\" is not a number".into()))?;
            // scope this handler's store I/O to (owning rank, epoch) so
            // scheduled store faults land inside the Lambda exactly as
            // they would on the peer loop's own thread; a takeover
            // fan-out runs the *dead* rank's handler, so its scheduled
            // faults follow the partition, not the successor
            let _fault_scope = crate::harness::faults::FaultScope::enter(h_peer, generation);
            // injected branch delay (fault harness): the branch index
            // rides in the payload whenever any delay/dup targets this
            // peer, so the lookup is exact. Measured time only — the
            // modeled plane (wall/billed/cost) never sees the sleep.
            if let Some(plan) = h_faults.lock().unwrap().clone() {
                if let Some(idx) = req.req("idx").ok().and_then(|j| j.as_u64()) {
                    if let Some(us) = plan.branch_delay_us(h_peer, generation, idx as usize) {
                        std::thread::sleep(Duration::from_micros(us));
                    }
                }
            }
            // with the shard plane on the primary ref is always an SPv1
            // manifest, resolved shard by shard through the shared
            // cache; otherwise a framed params decode when the wire
            // plane's params path is on, the plain cached decode when
            // not — every path memoized per version in the shared cache
            let params = if h_shard.on() {
                resolve_sharded_params(&h_wire, &params_ref, &h_cache, &h_store)?
            } else {
                h_wire.decode_params(&params_ref, &h_cache, &h_store)?
            };
            // cached-literal fast path: the batch object is immutable
            // and read by exactly one branch per epoch, so its input
            // literals are packed once per object and checked out /
            // back in around the execution — a miss (first epoch, or a
            // rare cross-epoch overlap on the same branch index) pays
            // the full unpack + pack
            let packed = match h_cache.take_packed::<PackedBatch>(&batch_ref) {
                Some(p) => *p,
                None => {
                    let batch = unpack_batch(&h_store.get_ref(&batch_ref)?)?;
                    h_runtime.pack_batch_literals(&batch)?
                }
            };
            let (out, packed) =
                h_runtime.grad_packed(&params, packed, true, Some(generation))?;
            h_cache.put_packed(&batch_ref, Box::new(packed));
            // a real Lambda has its own environment: the time this
            // branch queued for an engine slot — and, fused, the batch
            // collect window plus the other members' turns — is a
            // simulation artifact and must not be billed (the handler's
            // own work — S3 I/O, decode, its own execution — stays
            // billed)
            crate::faas::report_unbilled(out.queue_wait);
            // park the gradient encoded when the wire plane's gradient
            // path is on; the branch index seeds the per-branch
            // quantizer stream and rides in the payload only then
            let park = if h_wire.grads_on() {
                let branch = req.req("idx")?.as_u64().ok_or_else(|| {
                    Error::Faas("branch request: \"idx\" is not a number".into())
                })?;
                h_wire.encode_grads(&out.grads, generation, h_peer, branch)?
            } else {
                Bytes::from(f32s_to_bytes(&out.grads))
            };
            let grad_ref = h_store.put_new_gen(&h_bucket, park, generation)?;
            let mut resp = Json::obj();
            resp.set("loss", out.loss as f64)
                .set("grad", ref_to_json(&grad_ref));
            Ok(Bytes::from(resp.to_string().into_bytes()))
        });
        platform.register(FunctionSpec::new(&function, memory_mb, handler))?;
        let shard_state = ShardState::new(shard_plane.shard_count());
        let shard_chains =
            (0..shard_plane.shard_count()).map(|_| ParamsChain::new()).collect();
        Ok(Self {
            platform,
            store,
            runtime,
            scheduler,
            decode_cache,
            wire,
            chain: ParamsChain::new(),
            shard: shard_plane,
            shard_state,
            shard_chains,
            function,
            bucket,
            peer: peer_rank,
            concurrency,
            mode,
            sweep_scratch,
            pipeline_depth: pipeline_depth.max(1),
            retry: RetryPolicy::default(),
            fold_quorum: 0,
            faults,
            batch_refs: Mutex::new(Vec::new()),
            inflight: Mutex::new(VecDeque::new()),
            retired: Mutex::new(VecDeque::new()),
            pending_release: Mutex::new(None),
        })
    }

    pub fn function_name(&self) -> &str {
        &self.function
    }

    pub fn mode(&self) -> OffloadMode {
        self.mode
    }

    /// Cross-epoch in-flight window (meaningful in cross-epoch mode).
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Replace the branch retry policy (default: 3 attempts, no
    /// backoff — the historical hardcoded policy).
    pub fn set_retry(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    /// Set the k-of-n fold quorum (`--fold-quorum`); 0 folds all
    /// branches. A `k >= n` quorum degenerates to folding everything.
    pub fn set_fold_quorum(&mut self, k: usize) {
        self.fold_quorum = k;
    }

    pub fn fold_quorum(&self) -> usize {
        self.fold_quorum
    }

    /// Arm the fault-injection plan: branch delays fire inside the
    /// Lambda handler, duplicates add shadow deliveries of targeted
    /// branches.
    pub fn set_faults(&self, plan: Arc<InjectedFaults>) {
        *self.faults.lock().unwrap() = Some(plan);
    }

    fn injected_faults(&self) -> Option<Arc<InjectedFaults>> {
        self.faults.lock().unwrap().clone()
    }

    /// Should the branch index ride in the payload? Needed by the
    /// wire plane's per-branch quantizer and by targeted branch
    /// faults; otherwise omitted so default payloads stay
    /// byte-identical to the pre-membership plane.
    fn idx_tag(&self, idx: usize) -> Option<u64> {
        self.idx_tag_for(self.peer, idx)
    }

    /// [`Self::idx_tag`] on behalf of an arbitrary rank — a takeover
    /// fan-out tags branches exactly as the dead peer would have, so
    /// its handler sees the same payloads.
    fn idx_tag_for(&self, rank: usize, idx: usize) -> Option<u64> {
        let faulted = self
            .injected_faults()
            .map(|f| f.targets_branches(rank))
            .unwrap_or(false);
        (self.wire.grads_on() || faulted).then_some(idx as u64)
    }

    /// The quorum effective for a fan-out of `n` branches (0 = all).
    fn effective_quorum(&self, n: usize) -> usize {
        if self.fold_quorum == 0 || self.fold_quorum >= n {
            0
        } else {
            self.fold_quorum
        }
    }

    /// Branches actually folded out of a fan-out of `n`.
    fn folded_count(&self, n: usize) -> usize {
        match self.effective_quorum(n) {
            0 => n,
            k => k,
        }
    }

    /// Inject duplicate deliveries: every branch the fault plan marks
    /// as duplicated gets a *shadow* invocation on this peer's lane —
    /// same payload, same generation tag (so drain barriers cover it),
    /// result discarded. The real branch's landing is the only one
    /// folded, which is exactly the idempotence the at-least-once
    /// delivery claim needs; the shadow's parked gradient lands in the
    /// same generation scratch and is swept with it.
    fn inject_duplicates(
        &self,
        params_ref: &ObjectRef,
        batch_refs: &[ObjectRef],
        generation: u64,
    ) {
        let Some(plan) = self.injected_faults() else {
            return;
        };
        for (idx, batch_ref) in batch_refs.iter().enumerate() {
            if !plan.duplicate(self.peer, generation, idx) {
                continue;
            }
            let payload = branch_payload(params_ref, batch_ref, generation, self.idx_tag(idx));
            let platform = self.platform.clone();
            let function = self.function.clone();
            self.scheduler
                .submit_detached_tagged(self.peer, Some(generation), move || {
                    let _ = platform.invoke(&function, &payload, None);
                });
        }
    }

    /// Snapshot of the uploaded batch refs (this peer's partition).
    /// The membership table registers these so a survivor can
    /// re-dispatch them on takeover — the objects are epoch-persistent,
    /// so a takeover re-dispatches branches, it never re-uploads data.
    pub fn batch_refs(&self) -> Vec<ObjectRef> {
        self.batch_refs.lock().unwrap().clone()
    }

    /// The shared object store this offload reads/writes (the elastic
    /// trainer threads the same handle into every peer's store plane).
    pub fn store(&self) -> &Arc<ObjectStore> {
        &self.store
    }

    /// The shared decode cache (joiner warm-starts decode through it).
    pub fn decode_cache(&self) -> &Arc<DecodedCache> {
        &self.decode_cache
    }

    /// Still-resident params object for `generation`, if any: the
    /// staged/pipelined one-epoch-late release slot, then cross-epoch's
    /// lagged-retire queue, then the in-flight window. A takeover for
    /// epoch `e` runs strictly before this peer computes `e + 1`, so a
    /// miss means the recovery window already aged out.
    fn current_params_ref(&self, generation: u64) -> Option<ObjectRef> {
        if let Some((g, h)) = self.pending_release.lock().unwrap().as_ref() {
            if *g == generation {
                return Some(h.primary.clone());
            }
        }
        if let Some((_, h)) = self
            .retired
            .lock()
            .unwrap()
            .iter()
            .find(|(g, _)| *g == generation)
        {
            return Some(h.primary.clone());
        }
        self.inflight
            .lock()
            .unwrap()
            .iter()
            .find(|ep| ep.generation == generation)
            .map(|ep| ep.params.primary.clone())
    }

    /// Recompute a *dead* peer's epoch-`epoch` fold on this peer's lane
    /// (partition takeover, `--on-peer-failure takeover`). Nothing is
    /// re-uploaded: the dead peer's batch objects are epoch-persistent
    /// and the params object v(`epoch`) is this peer's still-resident
    /// reference (re-uploading would double-commit the params delta
    /// chain). The fan-out invokes the *dead peer's* registered Lambda
    /// — its handler seeds the wire plane's per-branch quantizer with
    /// the dead rank — so the folded gradient is byte-identical to the
    /// one the dead peer would have produced: same branch order, same
    /// f64 accumulation, same quorum.
    pub fn compute_takeover(
        &self,
        epoch: usize,
        dead_rank: usize,
        batch_refs: &[ObjectRef],
    ) -> Result<OffloadResult> {
        if batch_refs.is_empty() {
            return Err(Error::Faas(format!(
                "peer {}: takeover of peer {dead_rank}'s empty partition",
                self.peer
            )));
        }
        let generation = epoch as u64;
        let params_ref = self.current_params_ref(generation).ok_or_else(|| {
            Error::Faas(format!(
                "peer {}: params v{generation} already released — \
                 takeover window for peer {dead_rank} missed",
                self.peer
            ))
        })?;
        let dead_function =
            format!("grad-{}-peer{}", self.runtime.entry.key, dead_rank);
        let mut pipe = PipelinedMap::new(
            self.scheduler.clone(),
            self.platform.clone(),
            self.peer,
            &dead_function,
            batch_refs.len(),
            self.concurrency,
            self.retry,
        )?
        .with_generation(generation)
        .with_quorum(self.effective_quorum(batch_refs.len()));
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        let mut fold_err: Option<Error> = None;
        for (idx, batch_ref) in batch_refs.iter().enumerate() {
            pipe.submit(
                branch_payload(
                    &params_ref,
                    batch_ref,
                    generation,
                    self.idx_tag_for(dead_rank, idx),
                ),
                None,
            );
        }
        while let Some((_, out)) = pipe.next_output() {
            if let Err(e) = self.fold_branch(&out, &mut acc, &mut loss_sum) {
                fold_err = Some(e);
                break;
            }
        }
        let finish = pipe.finish();
        // the takeover's parked gradients land in the *dead peer's*
        // scratch bucket under the recovered generation (its handler
        // parked them). Drain both lanes — the takeover branches on
        // this peer's, any straggling pre-death branches on the dead
        // peer's evicted lane — then sweep that generation; the
        // trainer's final orphan sweep catches anything parked later.
        self.scheduler.await_generation_drained(self.peer, generation);
        self.scheduler.await_generation_drained(dead_rank, generation);
        if self.sweep_scratch {
            self.store
                .sweep_generation(&crate::store::peer_bucket(dead_rank), generation);
        }
        let report = match (fold_err, finish) {
            (Some(e), _) | (None, Err(e)) => return Err(e),
            (None, Ok(r)) => r,
        };
        Ok(OffloadResult {
            loss: (loss_sum / self.folded_count(batch_refs.len()) as f64) as f32,
            grads: acc.mean()?,
            wall: report.wall,
            measured_wall: report.measured_wall,
            billed: report.billed,
            cost_usd: report.cost_usd,
            invocations: report.invocations,
            cold_starts: report.cold_starts,
            retries: report.retries,
            stragglers: report.stragglers,
            overlap: Duration::ZERO,
        })
    }

    /// Epochs dispatched but not yet collected (cross-epoch mode).
    pub fn inflight_epochs(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// Batch objects currently uploaded (0 before [`Self::upload_batches`]).
    pub fn num_batches(&self) -> usize {
        self.batch_refs.lock().unwrap().len()
    }

    /// Pack and upload the peer's pre-batched partition *once*, before
    /// training (paper §III-B). The refs persist across epochs; a
    /// steady-state epoch then uploads only the params object. Calling
    /// this twice is a contract violation, not an idempotent refresh —
    /// the batch objects are immutable for the life of the run.
    pub fn upload_batches(&self, batches: &[Batch]) -> Result<usize> {
        if batches.is_empty() {
            return Err(Error::Faas("no batches to offload".into()));
        }
        let elems = {
            let (h, w, c) = self.runtime.input_shape();
            h * w * c
        };
        let mut refs = self.batch_refs.lock().unwrap();
        if !refs.is_empty() {
            return Err(Error::Faas(format!(
                "peer {}: batch objects already uploaded ({})",
                self.peer,
                refs.len()
            )));
        }
        for batch in batches {
            refs.push(
                self.store
                    .put_new(&self.bucket, Bytes::from(pack_batch(batch, elems)))?,
            );
        }
        Ok(refs.len())
    }

    /// Install already-uploaded batch refs as this peer's partition —
    /// the joiner's path: a revived rank absorbs its orphaned
    /// epoch-persistent objects, a growth joiner receives the split-off
    /// half of a donor's. Nothing is uploaded; the objects already
    /// exist. Refuses to clobber an uploaded partition.
    pub fn adopt_batch_refs(&self, adopted: Vec<ObjectRef>) -> Result<usize> {
        if adopted.is_empty() {
            return Err(Error::Faas("no batch refs to adopt".into()));
        }
        let mut refs = self.batch_refs.lock().unwrap();
        if !refs.is_empty() {
            return Err(Error::Faas(format!(
                "peer {}: batch objects already uploaded ({})",
                self.peer,
                refs.len()
            )));
        }
        *refs = adopted;
        Ok(refs.len())
    }

    /// Replace this peer's active partition refs — the growth-join
    /// donor's shed path: the donor keeps computing its half, the
    /// joiner adopted the rest. Applied at an epoch boundary, never
    /// mid-fan-out (the epoch snapshot is taken under the same lock).
    pub fn set_active_refs(&self, new_refs: Vec<ObjectRef>) {
        *self.batch_refs.lock().unwrap() = new_refs;
    }

    /// Upload params v(`generation`) through the wire plane: a delta (or
    /// full) frame when the params path is on, raw f32 bytes otherwise —
    /// both content-deduplicated through the shared bucket (frame bytes
    /// are rank-independent, so synchronous peers still store one object
    /// per epoch). On the framed path the chain is committed to this
    /// upload so the next generation deltas against it. With the shard
    /// plane on, the same machinery runs per shard and the handle's
    /// primary is the `SPv1` manifest instead.
    fn upload_params(&self, params: &[f32], generation: u64) -> Result<ParamsHandle> {
        if self.shard.on() {
            return self.upload_params_sharded(params, generation);
        }
        if !self.wire.params_on() {
            return Ok(ParamsHandle::monolithic(self.store.put_dedup(
                PARAMS_BUCKET,
                Bytes::from(f32s_to_bytes(params)),
                generation,
            )?));
        }
        let (frame, reconstructed) =
            self.wire.encode_params(params, generation, &self.chain, &self.store)?;
        let params_ref = self.store.put_dedup(PARAMS_BUCKET, frame, generation)?;
        self.chain.commit(generation, params_ref.clone(), reconstructed);
        Ok(ParamsHandle::monolithic(params_ref))
    }

    /// Sharded upload: only the shards whose content hash changed since
    /// this peer's previous upload are encoded (each through its own
    /// per-shard delta chain when the wire params path is on) and
    /// stored; unchanged shards re-reference the prior generation's
    /// objects via [`crate::store::ObjectStore::retain`]. The `SPv1`
    /// manifest the branch payloads name is itself `put_dedup`'d — its
    /// bytes are rank-independent, so synchronous peers still store one
    /// manifest (and one object per changed shard) per epoch.
    fn upload_params_sharded(
        &self,
        params: &[f32],
        generation: u64,
    ) -> Result<ParamsHandle> {
        let kind = if self.wire.params_on() { SHARD_KIND_WIRE } else { SHARD_KIND_RAW };
        let up = shard::upload_sharded(
            &self.shard,
            &self.shard_state,
            &self.store,
            PARAMS_BUCKET,
            params,
            generation,
            kind,
            |i, slice| {
                if self.wire.params_on() {
                    let (frame, reconstructed) = self.wire.encode_params(
                        slice,
                        generation,
                        &self.shard_chains[i],
                        &self.store,
                    )?;
                    let r = self.store.put_dedup(PARAMS_BUCKET, frame, generation)?;
                    self.shard_chains[i].commit(generation, r.clone(), reconstructed.clone());
                    Ok((r, reconstructed))
                } else {
                    let r = self.store.put_dedup(
                        PARAMS_BUCKET,
                        Bytes::from(f32s_to_bytes(slice)),
                        generation,
                    )?;
                    Ok((r, slice.to_vec()))
                }
            },
        )?;
        // reused shards shipped no frame: advance their delta chains to
        // this generation so the next real change delta-encodes against
        // the reused object instead of forcing a full resync
        if self.wire.params_on() {
            for (i, reused) in up.reused.iter().enumerate() {
                if *reused {
                    self.shard_chains[i].rekey(generation);
                }
            }
        }
        Ok(ParamsHandle { primary: up.manifest, shards: up.shards })
    }

    /// Pin a generation's live decoded views: the primary (manifest or
    /// monolithic object) and every shard. Tail branches must find each
    /// of them memoized for the generation's whole life, whatever the
    /// cache pressure from other peers' insertions.
    fn pin_params(&self, handle: &ParamsHandle) {
        self.decode_cache.pin(&handle.primary);
        for r in &handle.shards {
            self.decode_cache.pin(r);
        }
    }

    /// Run one epoch's batches through the dynamically-generated state
    /// machine and average the gradients. Uploads exactly one object —
    /// the params, tagged with this epoch's generation. Staged and
    /// pipelined modes sweep that generation (params + parked gradients)
    /// on every exit path; cross-epoch mode delegates to
    /// [`Self::dispatch_epoch`] + [`Self::collect_epoch`], whose sweep
    /// lags one live generation (reclaimed on a later dispatch or at
    /// [`Self::finish_run`]). Either way the store stays bounded while
    /// the batch objects persist.
    pub fn compute_epoch(&self, epoch: usize, params: &[f32]) -> Result<OffloadResult> {
        if self.mode == OffloadMode::CrossEpoch {
            // the non-pre-dispatched path (first epoch, or depth 1):
            // dispatch and collect back to back. Collection yields the
            // *oldest* in-flight epoch — if a caller interleaved a bare
            // dispatch_epoch, returning its fold labeled as `epoch`
            // would silently mix param versions, so refuse instead.
            self.dispatch_epoch(epoch, params)?;
            let (collected, result) = self.collect_epoch()?;
            if collected != epoch {
                return Err(Error::Faas(format!(
                    "peer {}: collected epoch {collected} while expecting {epoch} — \
                     generation-keyed fold refused",
                    self.peer
                )));
            }
            return Ok(result);
        }
        let batch_refs = self.batch_refs.lock().unwrap().clone();
        if batch_refs.is_empty() {
            return Err(Error::Faas(
                "no batch objects uploaded — call upload_batches first".into(),
            ));
        }
        // the epoch number is the generation (== the param version the
        // branch payloads advertise); GEN_PERSISTENT is u64::MAX so any
        // realistic epoch index is a valid scratch generation. The
        // upload is content-deduplicated through the shared params
        // bucket: in synchronous mode every peer's params bytes are
        // identical, so the cluster stores one object per epoch and
        // each peer holds a reference
        let generation = epoch as u64;
        let handle = self.upload_params(params, generation)?;
        // the live params version must survive cache pressure for the
        // whole fan-out, whatever the mode — without the pin, a small
        // shared cache lets another peer's params insertion evict this
        // epoch's entry mid-fan-out and break the one-decode-per-epoch
        // invariant
        self.pin_params(&handle);
        let outcome = match self.mode {
            OffloadMode::Staged => {
                self.fan_out_epoch_staged(epoch, &handle.primary, &batch_refs, generation)
            }
            OffloadMode::Pipelined | OffloadMode::CrossEpoch => {
                self.fan_out_epoch_pipelined(&handle.primary, &batch_refs, generation)
            }
        };
        // this peer's own scratch (parked gradients) is reclaimed
        // immediately on every exit path; the *shared* params reference
        // is parked and released one epoch late — other peers may still
        // be uploading the identical bytes for this very epoch, and a
        // premature refs-to-zero would force them to re-store and
        // re-decode (the epoch barrier guarantees every peer has
        // uploaded v(e) before anyone computes e+1)
        self.scheduler.await_generation_drained(self.peer, generation);
        if self.sweep_scratch {
            self.store.sweep_generation(&self.bucket, generation);
        }
        let lagged = self
            .pending_release
            .lock()
            .unwrap()
            .replace((generation, handle));
        if let Some((_, lagged_handle)) = lagged {
            self.release_params(&lagged_handle);
        }
        outcome
    }

    /// Cross-epoch mode: upload params v(`epoch`), pin their decoded
    /// view, tag and submit every branch through the cluster scheduler,
    /// and return immediately — the fan-out executes while the caller
    /// does inter-epoch coordination (convergence eval, barrier,
    /// verdict). Also reclaims lagged scratch: every retired generation
    /// except the most recent one is swept here, which is exactly the
    /// "sweep lags one live generation" contract.
    pub fn dispatch_epoch(&self, epoch: usize, params: &[f32]) -> Result<()> {
        if self.mode != OffloadMode::CrossEpoch {
            return Err(Error::Faas(format!(
                "dispatch_epoch requires cross-epoch offload mode (peer {} is {})",
                self.peer,
                self.mode.name()
            )));
        }
        let batch_refs = self.batch_refs.lock().unwrap().clone();
        if batch_refs.is_empty() {
            return Err(Error::Faas(
                "no batch objects uploaded — call upload_batches first".into(),
            ));
        }
        {
            let inflight = self.inflight.lock().unwrap();
            if inflight.len() >= self.pipeline_depth {
                return Err(Error::Faas(format!(
                    "peer {}: pipeline window full ({} epochs in flight, depth {})",
                    self.peer,
                    inflight.len(),
                    self.pipeline_depth
                )));
            }
        }
        self.sweep_lagged();
        let generation = epoch as u64;
        // build the fan-out *before* uploading the params: if the
        // constructor fails (unknown function), nothing has been
        // uploaded or pinned yet, so the generation cannot leak past
        // the sweep
        let mut pipe = PipelinedMap::new(
            self.scheduler.clone(),
            self.platform.clone(),
            self.peer,
            &self.function,
            batch_refs.len(),
            self.concurrency,
            self.retry,
        )?
        .with_generation(generation)
        .with_quorum(self.effective_quorum(batch_refs.len()));
        let handle = self.upload_params(params, generation)?;
        // the live params version must survive cache pressure until its
        // generation retires — tail branches re-reading an evicted entry
        // would still be *correct* (the lagged sweep keeps the object),
        // but the exactly-one-decode-per-epoch invariant would not hold
        self.pin_params(&handle);
        // duplicated deliveries race the real fan-out on the shared pool
        self.inject_duplicates(&handle.primary, &batch_refs, generation);
        for (idx, batch_ref) in batch_refs.iter().enumerate() {
            pipe.submit(
                branch_payload(&handle.primary, batch_ref, generation, self.idx_tag(idx)),
                None,
            );
        }
        self.inflight.lock().unwrap().push_back(InflightEpoch {
            epoch,
            generation,
            params: handle,
            pipe,
            batches: batch_refs.len(),
            dispatched_at: Instant::now(),
        });
        Ok(())
    }

    /// Cross-epoch mode: fold the *oldest* in-flight epoch — in branch
    /// order, so the f64 gradient/loss folds are byte-identical to the
    /// staged path — and retire its generation into the lagged-sweep
    /// queue. Returns the collected epoch number with the result, so
    /// callers can account for completions that arrive out of epoch
    /// order once deeper windows (stale-tolerant modes) land.
    pub fn collect_epoch(&self) -> Result<(usize, OffloadResult)> {
        let ep = self
            .inflight
            .lock()
            .unwrap()
            .pop_front()
            .ok_or_else(|| {
                Error::Faas(format!("peer {}: no epoch in flight to collect", self.peer))
            })?;
        let InflightEpoch { epoch, generation, params, mut pipe, batches, dispatched_at } =
            ep;
        let overlap = dispatched_at.elapsed();
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        let mut fold_err: Option<Error> = None;
        while let Some((_, out)) = pipe.next_output() {
            if let Err(e) = self.fold_branch(&out, &mut acc, &mut loss_sum) {
                fold_err = Some(e);
                break;
            }
        }
        // finish() waits for any branches the fold loop did not consume
        // (error path), so a sweep below cannot race a live handler
        let finish = pipe.finish();
        let report = match (fold_err, finish) {
            (Some(e), _) | (None, Err(e)) => {
                // failed epochs are retired immediately — there is no
                // later dispatch to lag behind
                self.retire_generation(generation, &params);
                return Err(e);
            }
            (None, Ok(r)) => r,
        };
        // the generation stays pinned through its lag window: a
        // stale-tolerant tail branch must find params v(e) both in the
        // store *and* still memoized while epoch e+1 runs
        self.retired.lock().unwrap().push_back((generation, params));
        Ok((
            epoch,
            OffloadResult {
                loss: (loss_sum / self.folded_count(batches) as f64) as f32,
                grads: acc.mean()?,
                wall: report.wall,
                measured_wall: report.measured_wall,
                billed: report.billed,
                cost_usd: report.cost_usd,
                invocations: report.invocations,
                cold_starts: report.cold_starts,
                retries: report.retries,
                stragglers: report.stragglers,
                overlap,
            },
        ))
    }

    /// Retire one generation: wait out any straggler branches (drain
    /// barrier — a collected generation has none today, but a
    /// stale-tolerant mode may retire one with stragglers, and a sweep
    /// must never run under a live branch), reclaim its store scratch
    /// (honoring `sweep_scratch`) — the per-peer parked gradients by
    /// generation sweep, this peer's reference on the shared params
    /// object by refcounted release (the object goes when the *last*
    /// peer retires the generation) — and drop this peer's claim on the
    /// params cache entry, which also clears its pin.
    fn retire_generation(&self, generation: u64, params: &ParamsHandle) {
        self.scheduler.await_generation_drained(self.peer, generation);
        if self.sweep_scratch {
            self.store.sweep_generation(&self.bucket, generation);
        }
        self.release_params(params);
    }

    /// Drop this peer's claims on a generation's shared params: the
    /// store references (honoring `sweep_scratch` — an object goes when
    /// the *last* holder releases, so a shard still referenced by a
    /// newer generation's manifest survives on that handle's retained
    /// ref) and the decode-cache pins/entries (per-holder, same
    /// survival rule). Used alone by the one-epoch-late
    /// staged/pipelined path, whose generation was already drained and
    /// swept when its epoch completed.
    fn release_params(&self, params: &ParamsHandle) {
        if self.sweep_scratch {
            self.store.release(&params.primary);
            for r in &params.shards {
                self.store.release(r);
            }
        }
        self.decode_cache.invalidate(&params.primary);
        for r in &params.shards {
            self.decode_cache.invalidate(r);
        }
    }

    /// Sweep every retired generation except the newest (the lag).
    fn sweep_lagged(&self) {
        let mut retired = self.retired.lock().unwrap();
        while retired.len() > 1 {
            let (generation, params) = retired.pop_front().unwrap();
            self.retire_generation(generation, &params);
        }
    }

    /// Offload teardown: drain any still-in-flight epochs (their
    /// branches are allowed to finish, their results are discarded) and
    /// retire every remaining generation — cross-epoch's lagged sweeps
    /// and staged/pipelined's one-epoch-late params release alike.
    /// Called by the peer when the training loop exits, whatever the
    /// mode — on success and on failure; idempotent.
    pub fn finish_run(&self) {
        loop {
            let ep = self.inflight.lock().unwrap().pop_front();
            let Some(ep) = ep else { break };
            let InflightEpoch { generation, params, mut pipe, .. } = ep;
            while pipe.next_output().is_some() {}
            let _ = pipe.finish();
            self.retire_generation(generation, &params);
        }
        {
            let mut retired = self.retired.lock().unwrap();
            while let Some((generation, params)) = retired.pop_front() {
                self.retire_generation(generation, &params);
            }
        }
        let pending = self.pending_release.lock().unwrap().take();
        if let Some((_, params)) = pending {
            self.release_params(&params);
        }
    }

    /// Parse a branch response and fold it into the running epoch state,
    /// decoding the parked gradient through the wire plane when its
    /// gradient path is on.
    fn fold_branch(
        &self,
        out: &[u8],
        acc: &mut GradAccumulator,
        loss_sum: &mut f64,
    ) -> Result<()> {
        let (loss, grad_ref) = parse_branch_response(out)?;
        *loss_sum += loss;
        let park = self.store.get_ref(&grad_ref)?;
        if self.wire.grads_on() {
            acc.add(&self.wire.decode_grads(&park)?)
        } else {
            acc.add(&bytes_to_f32s(&park))
        }
    }

    /// Staged: build every payload, fan out, collect. Scratch objects
    /// are swept by the caller ([`Self::compute_epoch`]) on every exit
    /// path.
    fn fan_out_epoch_staged(
        &self,
        epoch: usize,
        params_ref: &ObjectRef,
        batch_refs: &[ObjectRef],
        generation: u64,
    ) -> Result<OffloadResult> {
        let items: Vec<Bytes> = batch_refs
            .iter()
            .enumerate()
            .map(|(idx, r)| branch_payload(params_ref, r, generation, self.idx_tag(idx)))
            .collect();
        // duplicated deliveries race the real fan-out on the shared pool
        self.inject_duplicates(params_ref, batch_refs, generation);
        // dynamic state machine: one branch per batch, dispatched
        // across the shared worker pool
        let sm = StateMachine::parallel_batches(
            format!("{}-epoch{epoch}", self.function),
            &self.function,
            items,
            vec![],
            self.concurrency,
        )
        .with_retry(self.retry);
        let report = sm.execute_with(&self.platform, self.scheduler.executor())?;
        // collect + average (streaming: one running sum instead of
        // materializing every per-batch gradient). Under a fold quorum
        // only the first k outputs fold; the staged wall stays the full
        // wave (every branch still ran in it) — the quorum's wall
        // truncation is a property of the streaming collectors.
        let outputs = report
            .outputs
            .first()
            .ok_or_else(|| Error::Faas("state machine produced no outputs".into()))?;
        let folded = self.folded_count(outputs.len());
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        for out in outputs.iter().take(folded) {
            self.fold_branch(out, &mut acc, &mut loss_sum)?;
        }
        let avg = acc.mean()?;
        Ok(OffloadResult {
            loss: (loss_sum / folded as f64) as f32,
            grads: avg,
            wall: report.wall,
            measured_wall: report.measured_wall,
            billed: report.billed,
            cost_usd: report.cost_usd,
            invocations: report.invocations,
            cold_starts: report.cold_starts,
            retries: report.retries,
            stragglers: outputs.len() - folded,
            overlap: Duration::ZERO,
        })
    }

    /// Pipelined: every branch is admitted to the cluster scheduler as
    /// soon as its payload is built, and landed gradients fold into the
    /// accumulator (in branch order — bit-identical math) while later
    /// branches are still dispatching. Modeled accounting is
    /// byte-identical to the staged path; the measured wall shows the
    /// real submit/invoke/collect overlap.
    fn fan_out_epoch_pipelined(
        &self,
        params_ref: &ObjectRef,
        batch_refs: &[ObjectRef],
        generation: u64,
    ) -> Result<OffloadResult> {
        let mut pipe = PipelinedMap::new(
            self.scheduler.clone(),
            self.platform.clone(),
            self.peer,
            &self.function,
            batch_refs.len(),
            self.concurrency,
            self.retry,
        )?
        .with_generation(generation)
        .with_quorum(self.effective_quorum(batch_refs.len()));
        // duplicated deliveries race the real fan-out on the shared pool
        self.inject_duplicates(params_ref, batch_refs, generation);
        let mut acc = GradAccumulator::new();
        let mut loss_sum = 0f64;
        for (idx, batch_ref) in batch_refs.iter().enumerate() {
            pipe.submit(
                branch_payload(params_ref, batch_ref, generation, self.idx_tag(idx)),
                None,
            );
            // drain whatever already landed: collection overlaps dispatch
            while let Some((_, out)) = pipe.poll_output() {
                self.fold_branch(&out, &mut acc, &mut loss_sum)?;
            }
        }
        while let Some((_, out)) = pipe.next_output() {
            self.fold_branch(&out, &mut acc, &mut loss_sum)?;
        }
        let report = pipe.finish()?;
        Ok(OffloadResult {
            loss: (loss_sum / self.folded_count(batch_refs.len()) as f64) as f32,
            grads: acc.mean()?,
            wall: report.wall,
            measured_wall: report.measured_wall,
            billed: report.billed,
            cost_usd: report.cost_usd,
            invocations: report.invocations,
            cold_starts: report.cold_starts,
            retries: report.retries,
            stragglers: report.stragglers,
            overlap: Duration::ZERO,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;

    #[test]
    fn batch_pack_roundtrip() {
        let b = Batch { x: vec![0.5, -1.0, 2.0, 0.0], y: vec![3, 7], size: 2 };
        let packed = pack_batch(&b, 2);
        let back = unpack_batch(&packed).unwrap();
        assert_eq!(back.x, b.x);
        assert_eq!(back.y, b.y);
        assert_eq!(back.size, 2);
    }

    #[test]
    fn unpack_rejects_truncated() {
        let b = Batch { x: vec![1.0; 4], y: vec![0, 1], size: 2 };
        let mut packed = pack_batch(&b, 2);
        packed.pop();
        assert!(unpack_batch(&packed).is_err());
        assert!(unpack_batch(&[1, 2, 3]).is_err());
    }

    #[test]
    fn ref_json_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k-1".into(), size: 42 };
        let back = ref_from_json(&ref_to_json(&r)).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn branch_payload_carries_generation() {
        let p = ObjectRef { bucket: "b".into(), key: "params".into(), size: 8 };
        let b = ObjectRef { bucket: "b".into(), key: "batch".into(), size: 16 };
        let payload = branch_payload(&p, &b, 7, None);
        let req = Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
        assert_eq!(req.req("gen").unwrap().as_u64(), Some(7));
        assert_eq!(ref_from_json(req.req("params").unwrap()).unwrap(), p);
        assert_eq!(ref_from_json(req.req("batch").unwrap()).unwrap(), b);
    }

    #[test]
    fn branch_index_rides_only_on_the_compressed_plane() {
        // the `none` payload must stay byte-identical to the pre-wire
        // plane: no "idx" field at all
        let p = ObjectRef { bucket: "b".into(), key: "params".into(), size: 8 };
        let b = ObjectRef { bucket: "b".into(), key: "batch".into(), size: 16 };
        let plain = branch_payload(&p, &b, 7, None);
        let req = Json::parse(std::str::from_utf8(&plain).unwrap()).unwrap();
        assert!(req.req("idx").is_err(), "uncompressed payload grew an idx field");
        let tagged = branch_payload(&p, &b, 7, Some(3));
        let req = Json::parse(std::str::from_utf8(&tagged).unwrap()).unwrap();
        assert_eq!(req.req("idx").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn branch_response_roundtrip() {
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 8 };
        let mut resp = Json::obj();
        resp.set("loss", 0.25).set("grad", ref_to_json(&r));
        let (loss, gref) =
            parse_branch_response(resp.to_string().as_bytes()).unwrap();
        assert_eq!(loss, 0.25);
        assert_eq!(gref, r);
    }

    #[test]
    fn non_numeric_loss_is_an_error_not_nan() {
        // regression: a handler echoing a malformed loss used to fold
        // f64::NAN into the epoch mean and silently poison it
        let r = ObjectRef { bucket: "b".into(), key: "k".into(), size: 8 };
        let mut resp = Json::obj();
        resp.set("loss", "oops").set("grad", ref_to_json(&r));
        let err = parse_branch_response(resp.to_string().as_bytes()).unwrap_err();
        assert!(
            err.to_string().contains("loss"),
            "error must name the bad field: {err}"
        );
        // a missing loss is equally fatal
        let mut resp = Json::obj();
        resp.set("grad", ref_to_json(&r));
        assert!(parse_branch_response(resp.to_string().as_bytes()).is_err());
    }

    #[test]
    fn sharded_manifest_resolves_once_per_shard_through_the_cache() {
        use crate::store::shard::{ShardPlane, ShardSpec};
        let store = Arc::new(ObjectStore::new());
        store.create_bucket(PARAMS_BUCKET);
        let cache = DecodedCache::new(8);
        let wire = WirePlane::off();
        let plane = ShardPlane::new(ShardSpec::Count(3), 10, &[]).unwrap();
        let state = ShardState::new(plane.shard_count());
        let params: Vec<f32> = (0..10).map(|i| i as f32 * 0.5 - 2.0).collect();
        let up = shard::upload_sharded(
            &plane,
            &state,
            &store,
            PARAMS_BUCKET,
            &params,
            1,
            SHARD_KIND_RAW,
            |_, slice| {
                let r =
                    store.put_dedup(PARAMS_BUCKET, Bytes::from(f32s_to_bytes(slice)), 1)?;
                Ok((r, slice.to_vec()))
            },
        )
        .unwrap();
        let v = resolve_sharded_params(&wire, &up.manifest, &cache, &store).unwrap();
        assert_eq!(*v, params);
        assert_eq!(cache.misses(), 4, "manifest + 3 shards, each decoded once");
        // a sibling branch of the same generation reassembles nothing
        let v2 = resolve_sharded_params(&wire, &up.manifest, &cache, &store).unwrap();
        assert_eq!(*v2, params);
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.hits(), 1);
        // a tampered per-shard hash is rejected actionably, never folded
        let mut m = ShardManifest::from_wire(&store.get_ref(&up.manifest).unwrap()).unwrap();
        m.shards[1].hash ^= 1;
        let bad = store
            .put_dedup(PARAMS_BUCKET, Bytes::from(m.to_wire()), 1)
            .unwrap();
        let cold = DecodedCache::new(8);
        let err = resolve_sharded_params(&wire, &bad, &cold, &store).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
        // a manifest whose kind disagrees with the wire plane's config
        // is a plane mismatch, not a decode attempt
        let mut m = ShardManifest::from_wire(&store.get_ref(&up.manifest).unwrap()).unwrap();
        for e in &mut m.shards {
            e.kind = SHARD_KIND_WIRE;
        }
        let mismatched = store
            .put_dedup(PARAMS_BUCKET, Bytes::from(m.to_wire()), 1)
            .unwrap();
        let cold = DecodedCache::new(8);
        let err = resolve_sharded_params(&wire, &mismatched, &cold, &store).unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");
    }

    // Full offload integration (real PJRT) lives in rust/tests/.
}
