//! The peer actor: one thread running Algorithm 1 over its partition.
//!
//! Per epoch, a peer:
//! 1. computes per-batch gradients (sequentially on its "instance", or
//!    fanned out to Lambda via [`ServerlessOffload`]) and averages them;
//! 2. publishes the averaged gradient to its dedicated queue;
//! 3. consumes every other peer's gradient (blocking on the epoch in
//!    synchronous mode; taking whatever is freshest in async mode);
//! 4. averages the gradient dictionary and applies the SGD update;
//! 5. (leader) runs convergence detection on the validation set and
//!    broadcasts the verdict + scheduled lr on the control queue;
//! 6. (synchronous) waits at the RabbitMQ epoch barrier.
//!
//! Every stage is timed into the shared [`MetricsRegistry`] under its
//! Table-I stage name.
//!
//! **Cross-epoch offload mode** reorders the serverless boundary: right
//! after step 4 produces params v(e+1), the peer *dispatches* epoch
//! e+1's fan-out (params upload + branch submission, generation-tagged)
//! and only then runs steps 5–6 — so the convergence eval, the barrier
//! wait and the verdict read all overlap epoch e+1's execution on the
//! pool, and step 1 of the next iteration merely collects. The
//! pre-dispatch is gated off when early stopping is enabled: a verdict
//! that can say "stop" would make the speculative epoch's invocations
//! and cost diverge from the staged reference.

use std::sync::Arc;
use std::time::Duration;

use super::convergence::{EarlyStopping, ReduceLROnPlateau};
use super::gradient::{GradAccumulator, GradientDict, GradientWire};
use super::membership::{JoinKind, Membership, PartitionHandle};
use super::serverless::ServerlessOffload;
use super::sync::EpochBarrier;
use crate::broker::{Broker, Message, QueueMode};
use crate::config::{FailurePolicy, OffloadMode, SyncMode, TrainConfig};
use crate::data::{Batcher, Dataset};
use crate::error::{Error, Result};
use crate::harness::faults::{FaultPlan, FaultScope};
use crate::metrics::{MetricsRegistry, Stage, StageTimer};
use crate::runtime::ModelRuntime;
use crate::store::{DecodedCache, ObjectStore, GEN_PERSISTENT, PARAMS_BUCKET};
use crate::util::bytes::f32s_to_bytes;
use crate::util::{Bytes, Json};

/// Name of the control queue the leader broadcasts verdicts on.
pub fn control_queue() -> String {
    "ctl.convergence".to_string()
}

/// Leader verdict for one epoch.
#[derive(Debug, Clone, Copy)]
pub struct Verdict {
    pub epoch: u64,
    pub stop: bool,
    pub lr: f32,
    pub val_loss: f32,
    pub val_acc: f32,
}

impl Verdict {
    pub fn to_payload(&self) -> Bytes {
        let mut j = Json::obj();
        j.set("stop", self.stop)
            .set("lr", self.lr as f64)
            .set("val_loss", self.val_loss as f64)
            .set("val_acc", self.val_acc as f64);
        Bytes::from(j.to_string().into_bytes())
    }

    pub fn from_message(m: &Message) -> Result<Self> {
        let j = Json::parse(
            std::str::from_utf8(&m.payload).map_err(|e| Error::Broker(e.to_string()))?,
        )?;
        Ok(Self {
            epoch: m.epoch,
            stop: j.req("stop")?.as_bool().unwrap_or(false),
            lr: j.req("lr")?.as_f64().unwrap_or(0.0) as f32,
            val_loss: j.req("val_loss")?.as_f64().unwrap_or(f64::NAN) as f32,
            val_acc: j.req("val_acc")?.as_f64().unwrap_or(f64::NAN) as f32,
        })
    }
}

/// How a peer computes its per-batch gradients.
pub enum GradBackend {
    /// Sequential loop on the peer's own instance (PJRT local).
    Local { pallas: bool },
    /// The paper's serverless fan-out.
    Serverless(ServerlessOffload),
}

/// Per-peer outcome.
#[derive(Debug, Clone)]
pub struct PeerReport {
    pub rank: usize,
    pub epochs_run: usize,
    /// Mean training loss per epoch.
    pub train_loss: Vec<f32>,
    /// Gradient wire bytes sent per epoch.
    pub sent_bytes: Vec<usize>,
    /// Serverless cost accrued by this peer (USD), if offloading.
    pub lambda_cost_usd: f64,
    pub lambda_invocations: usize,
    /// Real wall time of this peer's fan-outs across the worker pool
    /// (vs the modeled wall the paper tables use).
    pub lambda_measured_wall: std::time::Duration,
    /// Cross-epoch mode: epochs whose fan-out was dispatched before the
    /// previous epoch's convergence eval / barrier / verdict wait.
    pub predispatched_epochs: usize,
    /// Cross-epoch mode: summed overlap windows — how long pre-dispatched
    /// epochs ran on the pool before their collection began.
    pub overlap_wall: std::time::Duration,
    /// Invocation attempts beyond the first across this peer's fan-outs
    /// (the configured `--lambda-retries` policy at work).
    pub lambda_retries: usize,
    /// Branches executed and billed but excluded from the fold by the
    /// `--fold-quorum` k-of-n partial fold.
    pub fold_stragglers: usize,
    /// FNV-1a fingerprint of this peer's final packed params — the
    /// bit-exactness handle the cross-plane invariance tests compare
    /// without shipping the full vector around.
    pub params_fnv: u64,
}

/// One peer of the cluster.
pub struct Peer {
    pub rank: usize,
    config: TrainConfig,
    partition: Dataset,
    val: Arc<Dataset>,
    runtime: Arc<ModelRuntime>,
    broker: Arc<Broker>,
    wire: GradientWire,
    backend: GradBackend,
    barrier: Arc<EpochBarrier>,
    metrics: Arc<MetricsRegistry>,
    params: Vec<f32>,
    /// Cluster liveness table; `None` (or unarmed) reproduces the
    /// fixed-membership trainer byte for byte.
    membership: Option<Arc<Membership>>,
    /// Deterministic fault-injection plan (`--fault-plan`).
    faults: Option<Arc<FaultPlan>>,
    /// Shared store plane for elastic-join warm-starts: the admitting
    /// leader uploads its params here, the joiner decodes them through
    /// the cache. `None` outside elastic runs.
    store: Option<Arc<ObjectStore>>,
    decode_cache: Option<Arc<DecodedCache>>,
}

impl Peer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        config: TrainConfig,
        partition: Dataset,
        val: Arc<Dataset>,
        runtime: Arc<ModelRuntime>,
        broker: Arc<Broker>,
        wire: GradientWire,
        backend: GradBackend,
        barrier: Arc<EpochBarrier>,
        metrics: Arc<MetricsRegistry>,
    ) -> Result<Self> {
        // dedicated queue per peer (Algorithm 1 init)
        broker.declare(&Broker::gradient_queue(rank), QueueMode::LatestOnly)?;
        broker.declare(&control_queue(), QueueMode::Fifo)?;
        let params = runtime.init_params()?;
        Ok(Self {
            rank,
            config,
            partition,
            val,
            runtime,
            broker,
            wire,
            backend,
            barrier,
            metrics,
            params,
            membership: None,
            faults: None,
            store: None,
            decode_cache: None,
        })
    }

    /// Attach the cluster's shared membership table (the trainer wires
    /// every peer to the same one).
    pub fn set_membership(&mut self, membership: Arc<Membership>) {
        self.membership = Some(membership);
    }

    /// Arm the fault-injection plan for this peer's thread (kill
    /// checks; the offload backend holds its own handle for branch
    /// delays/dups).
    pub fn set_faults(&mut self, faults: Arc<FaultPlan>) {
        self.faults = Some(faults);
    }

    /// Attach the shared store plane (elastic runs only): the leader
    /// uses it to stage warm-start params for admitted joiners.
    pub fn set_store_plane(&mut self, store: Arc<ObjectStore>, cache: Arc<DecodedCache>) {
        self.store = Some(store);
        self.decode_cache = Some(cache);
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// The armed membership table, if any — unarmed tables are treated
    /// as absent so every waiting loop keeps its historical untimed
    /// form (and its exact message/counter trace).
    fn armed_membership(&self) -> Option<&Arc<Membership>> {
        self.membership.as_ref().filter(|m| m.armed())
    }

    fn no_batch_error(&self) -> Error {
        Error::Data(format!(
            "peer {}: partition of {} samples yields no batch of {}",
            self.rank,
            self.partition.len(),
            self.config.batch_size
        ))
    }

    /// Run Algorithm 1. Returns the per-peer report.
    pub fn run(&mut self) -> Result<PeerReport> {
        self.run_epochs(1, None)
    }

    /// Run as a mid-run joiner admitted at `start_epoch`: install the
    /// admitting leader's warm-start params, absorb the partition the
    /// membership table registered for this rank (the orphaned refs on
    /// a revival, the split-off half on a growth join), replay past
    /// verdicts into the local convergence state, and enter the epoch
    /// loop at `start_epoch`.
    pub fn run_joined(&mut self, start_epoch: u64, warm_params: Vec<f32>) -> Result<PeerReport> {
        if start_epoch < 2 {
            return Err(Error::Runtime(format!(
                "peer {}: join start epoch must be >= 2, got {start_epoch}",
                self.rank
            )));
        }
        self.run_epochs(start_epoch, Some(warm_params))
    }

    fn run_epochs(
        &mut self,
        start_epoch: u64,
        warm_params: Option<Vec<f32>>,
    ) -> Result<PeerReport> {
        let batcher = Batcher::new(self.config.batch_size, self.config.seed ^ self.rank as u64);
        let mut early = if self.config.early_stop_patience > 0 {
            EarlyStopping::new(self.config.early_stop_patience, 1e-4)
        } else {
            EarlyStopping::disabled()
        };
        let mut plateau = if self.config.plateau_patience > 0 {
            ReduceLROnPlateau::new(self.config.lr, self.config.plateau_patience, 0.5, 1e-5)
        } else {
            ReduceLROnPlateau::disabled(self.config.lr)
        };
        let mut lr = self.config.lr;
        let mut report = PeerReport {
            rank: self.rank,
            epochs_run: 0,
            train_loss: Vec::new(),
            sent_bytes: Vec::new(),
            lambda_cost_usd: 0.0,
            lambda_invocations: 0,
            lambda_measured_wall: std::time::Duration::ZERO,
            predispatched_epochs: 0,
            overlap_wall: std::time::Duration::ZERO,
            lambda_retries: 0,
            fold_stragglers: 0,
            params_fnv: 0,
        };

        // a joiner starts from the admitting leader's post-update
        // params instead of the deterministic init
        if let Some(p) = warm_params {
            self.params = p;
        }

        // heartbeat pump: beats until dropped — which happens on every
        // exit path of this function, so this peer's beats stop exactly
        // when its thread does and survivors' reap timers start counting
        let _pump = self
            .armed_membership()
            .map(|m| m.clone().start_pump(self.rank));

        if start_epoch == 1 {
            // Serverless fidelity (paper §III-B): the partition is batched
            // once and uploaded to the peer's bucket *before* training;
            // every epoch re-reads the same batch objects, so steady-state
            // epochs upload only the params. The instance path keeps
            // Algorithm 1's per-epoch reshuffle (batch membership there is
            // ephemeral — nothing is uploaded).
            if let GradBackend::Serverless(offload) = &self.backend {
                let batches = batcher.epoch_batches(&self.partition, 0);
                if batches.is_empty() {
                    return Err(self.no_batch_error());
                }
                offload.upload_batches(&batches)?;
            }

            // register what a takeover successor would need to recompute
            // this peer's partition: the epoch-persistent batch refs
            // (serverless) or the raw partition (instance)
            if let Some(m) = self.armed_membership() {
                let handle = match &self.backend {
                    GradBackend::Serverless(offload) => {
                        PartitionHandle::Refs(offload.batch_refs())
                    }
                    GradBackend::Local { .. } => {
                        PartitionHandle::Data(Box::new(self.partition.clone()))
                    }
                };
                m.register_partition(self.rank, handle);
            }
        } else {
            // joiner: absorb the partition the admission registered for
            // this rank — nothing is re-uploaded, a revival re-dispatches
            // the orphaned epoch-persistent refs and a growth join works
            // the donor's split-off half in place
            self.adopt_join_partition()?;

            if self.config.sync == SyncMode::Synchronous {
                // replay the leader's past verdicts (the control queue is
                // a never-drained Fifo) so this rank's early-stop /
                // plateau / lr state matches what every survivor
                // accumulated — a later leader fallback onto this rank
                // must continue the same history
                let ctl = self.broker.get(&control_queue())?;
                for e in 1..start_epoch {
                    if let Some(msg) = ctl.await_epoch_timeout(e, Duration::ZERO)? {
                        let v = Verdict::from_message(&msg)?;
                        early.observe(v.val_loss);
                        plateau.observe(v.val_loss);
                        lr = if v.lr > 0.0 { v.lr } else { lr };
                    }
                }
                // wait (without arriving — this rank's arrivals only
                // count from start_epoch on) until the admitting epoch's
                // barrier fills, so the first compute can't outrun
                // survivors still folding epoch start_epoch-1
                if let Some(m) = self.armed_membership() {
                    let m = m.clone();
                    while !self.barrier.wait_timeout(start_epoch - 1, m.wait_slice())? {
                        m.reap()?;
                        m.fill_barrier(&self.barrier, start_epoch - 1)?;
                    }
                }
            }
        }

        // Cross-epoch pre-dispatch is only sound when the verdict can
        // never say "stop": a speculatively dispatched epoch that early
        // stopping then cancels would burn invocations/cost the staged
        // reference never pays. With early stopping disabled (the
        // default) the epoch count is fixed and speculation is exact.
        // Growth joins additionally disable speculation: the donor's
        // active refs shrink at the join boundary, so a pre-dispatched
        // epoch would fan out the stale (pre-shed) partition.
        let speculate = match &self.backend {
            GradBackend::Serverless(offload) => {
                offload.mode() == OffloadMode::CrossEpoch
                    && offload.pipeline_depth() >= 2
                    && self.config.early_stop_patience == 0
                    && self
                        .armed_membership()
                        .map(|m| m.growth_epochs().is_empty())
                        .unwrap_or(true)
            }
            GradBackend::Local { .. } => false,
        };
        // epoch number whose fan-out is already running on the pool
        let mut predispatched: Option<u64> = None;

        // The epoch loop runs inside a closure so the cross-epoch
        // teardown below executes on *every* exit path — an abort or a
        // refused fold mid-loop must not leak in-flight branches,
        // pinned cache entries, or unswept generations. The immediate
        // call is the point: `?` must propagate to `epochs_outcome`,
        // not past the teardown.
        #[allow(clippy::redundant_closure_call)]
        let epochs_outcome = (|| -> Result<()> {
            for epoch in start_epoch..=self.config.epochs as u64 {
                // ---- 0. injected death ------------------------------------
                // a killed peer errors out *before* computing the epoch, so
                // it never publishes v(epoch); the `?` routes through the
                // offload teardown below and the cluster's spawn wrapper
                // then declares this rank dead
                if let Some(plan) = &self.faults {
                    if plan.should_kill(self.rank, epoch) {
                        return Err(Error::Runtime(format!(
                            "peer {}: fault plan killed this peer at epoch {epoch}",
                            self.rank
                        )));
                    }
                }

                // scope injected store/broker chaos to (rank, epoch) on
                // this thread for the rest of the iteration — I/O faults
                // in the plan target the epoch's owning rank
                let _fault_scope = FaultScope::enter(self.rank, epoch);

                // ---- 0b. growth-join donor shed ---------------------------
                // an admission that split this rank's partition parked the
                // shrunken half as a directive; apply it before computing
                if let Some(m) = self.armed_membership() {
                    if let Some(handle) = m.take_shed(self.rank, epoch) {
                        match (&self.backend, handle) {
                            (GradBackend::Serverless(offload), PartitionHandle::Refs(refs)) => {
                                offload.set_active_refs(refs);
                            }
                            (GradBackend::Local { .. }, PartitionHandle::Data(data)) => {
                                self.partition = *data;
                            }
                            _ => {
                                return Err(Error::Runtime(format!(
                                    "peer {}: shed partition handle does not match \
                                     this backend",
                                    self.rank
                                )));
                            }
                        }
                    }
                }

                // ---- 1. per-batch gradients + average ---------------------
                // (instance path) materialize this epoch's reshuffled
                // batches outside the timed compute stage
                let local_batches = match &self.backend {
                    GradBackend::Local { .. } => {
                        let b = batcher.epoch_batches(&self.partition, epoch as usize);
                        if b.is_empty() {
                            return Err(self.no_batch_error());
                        }
                        Some(b)
                    }
                    GradBackend::Serverless(_) => None,
                };
                let t = StageTimer::start(Stage::ComputeGradients);
                let (epoch_loss, my_grad) = match &self.backend {
                    GradBackend::Local { pallas } => {
                        let batches = local_batches.as_deref().unwrap_or_default();
                        // streaming mean: one running sum, O(params) memory
                        // no matter how many batches the partition yields
                        let mut acc = GradAccumulator::new();
                        let mut loss_sum = 0f64;
                        for b in batches {
                            let out = self.runtime.grad(b.size, &self.params, &b.x, &b.y, *pallas)?;
                            loss_sum += out.loss as f64;
                            acc.add(&out.grads)?;
                        }
                        ((loss_sum / batches.len() as f64) as f32, acc.mean()?)
                    }
                    GradBackend::Serverless(offload) => {
                        let out = if predispatched.take() == Some(epoch) {
                            // the fan-out has been executing since before
                            // last epoch's barrier — just fold it
                            let (collected, out) = offload.collect_epoch()?;
                            if collected as u64 != epoch {
                                // out-of-epoch-order completion: cannot
                                // happen at window <= 2, but deeper
                                // (stale-tolerant) windows must not fold a
                                // mismatched param version silently
                                return Err(Error::Faas(format!(
                                    "peer {}: collected epoch {collected} while \
                                     expecting {epoch} — generation-keyed fold refused",
                                    self.rank
                                )));
                            }
                            report.overlap_wall += out.overlap;
                            out
                        } else {
                            offload.compute_epoch(epoch as usize, &self.params)?
                        };
                        report.lambda_cost_usd += out.cost_usd;
                        report.lambda_invocations += out.invocations;
                        report.lambda_measured_wall += out.measured_wall;
                        report.lambda_retries += out.retries;
                        report.fold_stragglers += out.stragglers;
                        (out.loss, out.grads)
                    }
                };
                t.stop(&self.metrics);

                // ---- 2. publish to own queue ------------------------------
                let t = StageTimer::start(Stage::SendGradients);
                let sent = self
                    .wire
                    .publish(&self.broker, self.rank, epoch, &my_grad)?;
                t.stop(&self.metrics);
                report.sent_bytes.push(sent);

                // ---- 3. consume all other queues --------------------------
                let t = StageTimer::start(Stage::ReceiveGradients);
                let mut dict = GradientDict::new();
                dict.insert(self.rank, my_grad);
                // the exchange width is the (schedule-static) cluster
                // width at this epoch: growth joiners count from their
                // join epoch on, and every peer computes the same width
                // with no coordination
                let width = self
                    .armed_membership()
                    .map(|m| m.width_at(epoch))
                    .unwrap_or(self.config.peers);
                for peer in 0..width {
                    if peer == self.rank {
                        continue;
                    }
                    // never wait on (or drop/take over) a scheduled
                    // joiner whose admission hasn't landed — it was
                    // never up, so it owes nothing for this epoch
                    if self
                        .armed_membership()
                        .map(|m| m.awaiting_join(peer, epoch))
                        .unwrap_or(false)
                    {
                        continue;
                    }
                    let q = self.broker.get(&Broker::gradient_queue(peer))?;
                    match self.config.sync {
                        SyncMode::Synchronous => {
                            let grad = match self.armed_membership() {
                                None => Some(self.wire.decode(&q.await_epoch(epoch)?.payload)?),
                                Some(membership) => {
                                    let membership = membership.clone();
                                    loop {
                                        if let Some(msg) = q
                                            .await_epoch_timeout(epoch, membership.wait_slice())?
                                        {
                                            break Some(self.wire.decode(&msg.payload)?);
                                        }
                                        membership.reap()?;
                                        if membership.is_alive(peer) {
                                            continue;
                                        }
                                        // final drain: the peer may have
                                        // published v(epoch) in the instant
                                        // before its death was declared — a
                                        // landed gradient always wins
                                        if let Some(msg) =
                                            q.await_epoch_timeout(epoch, Duration::ZERO)?
                                        {
                                            break Some(self.wire.decode(&msg.payload)?);
                                        }
                                        match membership.policy() {
                                            FailurePolicy::Drop => {
                                                membership.note_dropped_grad();
                                                break None;
                                            }
                                            FailurePolicy::Takeover => {
                                                if membership
                                                    .claim_takeover(self.rank, peer, epoch)
                                                {
                                                    let g = self.takeover_grads(
                                                        &membership,
                                                        peer,
                                                        epoch,
                                                        &mut report,
                                                    )?;
                                                    self.wire
                                                        .publish(&self.broker, peer, epoch, &g)?;
                                                    membership
                                                        .note_takeover_published(peer, epoch);
                                                    // loop around and decode our
                                                    // own publish so every
                                                    // survivor folds identical
                                                    // wire-decoded bytes
                                                }
                                                // not the successor: it publishes
                                                // on the dead queue; keep waiting
                                            }
                                            // reap() aborts before the death is
                                            // ever visible here
                                            FailurePolicy::Abort => {
                                                return Err(Error::Aborted(format!(
                                                    "peer {peer} died under the abort policy"
                                                )));
                                            }
                                        }
                                    }
                                }
                            };
                            if let Some(g) = grad {
                                dict.insert(peer, g);
                            }
                        }
                        SyncMode::Asynchronous => {
                            // take whatever is freshest, even stale; skip if
                            // the peer has not published yet
                            if let Some(m) = q.peek_latest() {
                                dict.insert(peer, self.wire.decode(&m.payload)?);
                            }
                        }
                    }
                }
                t.stop(&self.metrics);

                // ---- 4. average + model update ----------------------------
                let avg = dict.average()?;
                let t = StageTimer::start(Stage::ModelUpdate);
                self.params = self.runtime.update(&self.params, &avg, lr)?;
                t.stop(&self.metrics);

                report.train_loss.push(epoch_loss);
                report.epochs_run = epoch as usize;

                // ---- 4b. cross-epoch pre-dispatch -------------------------
                // params v(e+1) exist now; ship epoch e+1's fan-out to the
                // pool *before* the eval/barrier/verdict stages below, so
                // the pool never drains at the epoch boundary
                if speculate && epoch < self.config.epochs as u64 {
                    if let GradBackend::Serverless(offload) = &self.backend {
                        let t = StageTimer::start(Stage::ComputeGradients);
                        offload.dispatch_epoch((epoch + 1) as usize, &self.params)?;
                        t.stop(&self.metrics);
                        predispatched = Some(epoch + 1);
                        report.predispatched_epochs += 1;
                    }
                }

                // ---- 5. convergence detection (leader broadcasts) ---------
                // the leader is the smallest *alive* rank: rank 0 until it
                // dies, then the membership table's fallback
                let leader = self.armed_membership().map(|m| m.leader()).unwrap_or(0);
                let mut stop = false;
                if self.rank == leader {
                    let t = StageTimer::start(Stage::ConvergenceDetection);
                    let (val_loss, val_acc) = self.runtime.eval_dataset(&self.params, &self.val)?;
                    stop = early.observe(val_loss);
                    lr = plateau.observe(val_loss);
                    let verdict = Verdict { epoch, stop, lr, val_loss, val_acc };
                    self.broker.publish(
                        &control_queue(),
                        Message::new(self.rank, epoch, verdict.to_payload()),
                    )?;
                    t.stop(&self.metrics);
                }

                // ---- 5b. elastic admissions (leader) ----------------------
                // scheduled joins due at the next epoch are admitted at
                // this boundary: after the verdict broadcast (so the
                // joiner can replay it) and before this rank's barrier
                // arrival (so the barrier can't fill until the table is
                // updated and the revival catch-up proxies are out)
                if self.rank == leader && !stop {
                    self.admit_scheduled_joins(epoch)?;
                }

                // ---- 6. barrier (synchronous mode) ------------------------
                if self.config.sync == SyncMode::Synchronous {
                    match self.armed_membership() {
                        None => self.barrier.arrive_and_wait(self.rank, epoch)?,
                        Some(m) => {
                            // arrive exactly once (the cumulative predicate
                            // counts publishes), then park in slices: each
                            // expiry reaps stale peers and back-fills proxy
                            // arrivals for the dead so the barrier still
                            // fills — the PR's fix for the epoch-barrier
                            // hang on peer death
                            self.barrier.arrive(self.rank, epoch)?;
                            m.note_barrier_arrival(self.rank, epoch);
                            m.fill_barrier(&self.barrier, epoch)?;
                            while !self.barrier.wait_timeout(epoch, m.wait_slice())? {
                                m.reap()?;
                                m.fill_barrier(&self.barrier, epoch)?;
                            }
                        }
                    }
                }

                // follow the leader's verdict
                if self.rank != leader {
                    let ctl = self.broker.get(&control_queue())?;
                    let mut stepped_up = false;
                    let msg = match self.config.sync {
                        SyncMode::Synchronous => match self.armed_membership() {
                            None => Some(ctl.await_epoch(epoch)?),
                            Some(membership) => {
                                let membership = membership.clone();
                                loop {
                                    if let Some(msg) = ctl
                                        .await_epoch_timeout(epoch, membership.wait_slice())?
                                    {
                                        break Some(msg);
                                    }
                                    membership.reap()?;
                                    if membership.leader() == self.rank && !stepped_up {
                                        // every rank below died before
                                        // broadcasting this epoch's verdict —
                                        // step up: evaluate, publish, then
                                        // read the broadcast back like any
                                        // other survivor
                                        let t =
                                            StageTimer::start(Stage::ConvergenceDetection);
                                        let (val_loss, val_acc) = self
                                            .runtime
                                            .eval_dataset(&self.params, &self.val)?;
                                        let v_stop = early.observe(val_loss);
                                        let v_lr = plateau.observe(val_loss);
                                        let verdict = Verdict {
                                            epoch,
                                            stop: v_stop,
                                            lr: v_lr,
                                            val_loss,
                                            val_acc,
                                        };
                                        self.broker.publish(
                                            &control_queue(),
                                            Message::new(self.rank, epoch, verdict.to_payload()),
                                        )?;
                                        t.stop(&self.metrics);
                                        stepped_up = true;
                                    }
                                }
                            }
                        },
                        SyncMode::Asynchronous => ctl.peek_latest(),
                    };
                    if let Some(m) = msg {
                        let v = Verdict::from_message(&m)?;
                        // under an armed membership every follower feeds the
                        // broadcast val-loss into its *local* convergence
                        // state, so a later leader fallback continues the
                        // same early-stop/plateau history the dead leader
                        // accumulated (the eval is deterministic, so the
                        // observed sequence is identical on every rank)
                        if !stepped_up && self.armed_membership().is_some() {
                            early.observe(v.val_loss);
                            plateau.observe(v.val_loss);
                        }
                        lr = if v.lr > 0.0 { v.lr } else { lr };
                        stop = v.stop;
                    }
                }
                if stop {
                    break;
                }
            }
            Ok(())
        })();
        // offload teardown, every mode: drain any abandoned in-flight
        // epoch (cross-epoch), sweep lagged generations, and release
        // the one-epoch-late shared-params reference staged/pipelined
        // epochs park — on success *and* on failure, so the store ends
        // empty on every exit path
        if let GradBackend::Serverless(offload) = &self.backend {
            offload.finish_run();
        }
        epochs_outcome?;
        report.params_fnv = crate::store::shard::hash_f32s(&self.params);
        Ok(report)
    }

    /// Absorb the partition the admission registered for this rank:
    /// the orphaned epoch-persistent refs on a revival (bit-identical
    /// to the dead peer's own batches), the donor's split-off half on
    /// a growth join.
    fn adopt_join_partition(&mut self) -> Result<()> {
        let m = self.membership.clone().ok_or_else(|| {
            Error::Runtime(format!(
                "peer {}: joined without a membership table",
                self.rank
            ))
        })?;
        let handle = m.partition_of(self.rank).ok_or_else(|| {
            Error::Runtime(format!(
                "peer {}: no partition registered to absorb on join",
                self.rank
            ))
        })?;
        match (&self.backend, handle) {
            (GradBackend::Serverless(offload), PartitionHandle::Refs(refs)) => {
                offload.adopt_batch_refs(refs)?;
            }
            (GradBackend::Local { .. }, PartitionHandle::Data(data)) => {
                self.partition = *data;
            }
            _ => {
                return Err(Error::Runtime(format!(
                    "peer {}: joined partition handle does not match this backend",
                    self.rank
                )));
            }
        }
        Ok(())
    }

    /// Leader-side admission at the end of epoch `epoch`: every
    /// scheduled join due at `epoch + 1` — or earlier, when a leader
    /// fail-over skipped a boundary — is matched against its announce
    /// message, admitted into the membership table, warm-started from
    /// this leader's post-update params, and released via its admit
    /// queue. A joiner that never announced within the peer timeout is
    /// declined so nobody waits for its gradients.
    fn admit_scheduled_joins(&self, epoch: u64) -> Result<()> {
        let Some(m) = self.armed_membership().cloned() else {
            return Ok(());
        };
        let pending = m.pending_joins_at(epoch + 1);
        if pending.is_empty() {
            return Ok(());
        }
        let store = self.store.as_ref().ok_or_else(|| {
            Error::Runtime(format!(
                "peer {}: join scheduled but no store plane attached",
                self.rank
            ))
        })?;
        let announce = self.broker.get(&Broker::join_queue())?;
        for (jrank, jepoch) in pending {
            let deadline = std::time::Instant::now() + m.peer_timeout();
            let mut announced = false;
            loop {
                if announce.snapshot().iter().any(|msg| msg.sender == jrank) {
                    announced = true;
                    break;
                }
                if std::time::Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(m.wait_slice());
            }
            let admit_q = self
                .broker
                .declare(&Broker::join_admit_queue(jrank), QueueMode::Fifo)?;
            let admission = if announced {
                m.admit_join(jrank, jepoch)?
            } else {
                None
            };
            match admission {
                None => {
                    let mut j = Json::obj();
                    j.set("admit", false);
                    admit_q.publish(Message::new(
                        self.rank,
                        jepoch,
                        Bytes::from(j.to_string().into_bytes()),
                    ))?;
                }
                Some(adm) => {
                    // warm-start: stage this leader's post-update params
                    // in the persistent generation for the joiner to
                    // decode (and the trainer teardown to sweep)
                    store.create_bucket(PARAMS_BUCKET);
                    let key = format!("join-warm-{jrank}-e{jepoch}");
                    let r = store.put_gen(
                        PARAMS_BUCKET,
                        &key,
                        Bytes::from(f32s_to_bytes(&self.params)),
                        GEN_PERSISTENT,
                    )?;
                    let mut j = Json::obj();
                    j.set("admit", true)
                        .set(
                            "kind",
                            match adm.kind {
                                JoinKind::Revival => "revival",
                                JoinKind::Growth => "growth",
                            },
                        )
                        .set("start", adm.start_epoch)
                        .set("bucket", r.bucket.as_str())
                        .set("key", r.key.as_str())
                        .set("size", r.size);
                    admit_q.publish(Message::new(
                        self.rank,
                        adm.start_epoch,
                        Bytes::from(j.to_string().into_bytes()),
                    ))?;
                    // revival catch-up: barrier epochs the dead rank
                    // still owed, claimed atomically in admit_join —
                    // published here so the widened barrier can't hang
                    m.proxy_catch_up(&self.barrier, jrank, &adm.catch_up)?;
                    if let Some(plan) = &self.faults {
                        plan.record_join_fired();
                    }
                }
            }
        }
        Ok(())
    }

    /// Recompute a dead peer's epoch-`epoch` gradient from its
    /// registered partition (the takeover policy). Serverless
    /// partitions re-dispatch the dead peer's epoch-persistent batch
    /// refs through its still-registered Lambda; instance partitions
    /// re-batch the raw data with the dead peer's shuffle seed. Either
    /// way the result is byte-identical to the gradient the dead peer
    /// would have published.
    fn takeover_grads(
        &self,
        membership: &Membership,
        dead: usize,
        epoch: u64,
        report: &mut PeerReport,
    ) -> Result<Vec<f32>> {
        let handle = membership.partition_of(dead).ok_or_else(|| {
            Error::Runtime(format!(
                "peer {}: no partition registered for dead peer {dead}",
                self.rank
            ))
        })?;
        match (&self.backend, handle) {
            (GradBackend::Serverless(offload), PartitionHandle::Refs(refs)) => {
                let out = offload.compute_takeover(epoch as usize, dead, &refs)?;
                report.lambda_cost_usd += out.cost_usd;
                report.lambda_invocations += out.invocations;
                report.lambda_measured_wall += out.measured_wall;
                report.lambda_retries += out.retries;
                report.fold_stragglers += out.stragglers;
                Ok(out.grads)
            }
            (GradBackend::Local { pallas }, PartitionHandle::Data(data)) => {
                let batcher =
                    Batcher::new(self.config.batch_size, self.config.seed ^ dead as u64);
                let batches = batcher.epoch_batches(&data, epoch as usize);
                if batches.is_empty() {
                    return Err(Error::Data(format!(
                        "peer {}: dead peer {dead}'s partition yields no batches",
                        self.rank
                    )));
                }
                let mut acc = GradAccumulator::new();
                for b in &batches {
                    let out = self.runtime.grad(b.size, &self.params, &b.x, &b.y, *pallas)?;
                    acc.add(&out.grads)?;
                }
                acc.mean()
            }
            _ => Err(Error::Runtime(format!(
                "peer {}: dead peer {dead}'s partition handle does not match \
                 this backend",
                self.rank
            ))),
        }
    }
}
