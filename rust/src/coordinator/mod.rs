//! The paper's L3 contribution: the serverless peer-to-peer training
//! coordinator (Algorithm 1 + the Lambda offload of §III-C).
//!
//! - [`peer`] — the per-rank actor running Algorithm 1;
//! - [`trainer`] — cluster assembly, thread lifecycle, reporting;
//! - [`gradient`] — exchange wire format, S3 overflow, averaging;
//! - [`serverless`] — the dynamic-state-machine Lambda fan-out;
//! - [`sync`] — the RabbitMQ epoch barrier;
//! - [`membership`] — heartbeat liveness, takeover, barrier back-fill;
//! - [`convergence`] — Early Stopping + ReduceLROnPlateau.

pub mod convergence;
pub mod gradient;
pub mod membership;
pub mod peer;
pub mod serverless;
pub mod sync;
pub mod trainer;

pub use convergence::{EarlyStopping, ReduceLROnPlateau};
pub use gradient::{average_batch_gradients, GradAccumulator, GradientDict, GradientWire};
pub use membership::{HeartbeatPump, Membership, PartitionHandle};
pub use peer::{control_queue, GradBackend, Peer, PeerReport, Verdict};
pub use serverless::{pack_batch, unpack_batch, OffloadResult, ServerlessOffload};
pub use sync::EpochBarrier;
pub use trainer::{Cluster, TrainReport};
