//! Gradient exchange plumbing (§III-B.3/.5): wire encoding with the
//! configured codec, the S3-overflow path for oversized messages, the
//! per-peer gradient dictionary and averaging.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::broker::{Broker, Message};
use crate::compress::Codec;
use crate::error::{Error, Result};
use crate::store::{ObjectRef, ObjectStore, GRADIENT_BUCKET};
use crate::util::Bytes;

/// Threshold above which a gradient payload is parked in the object
/// store and referenced by UUID (Amazon MQ's 100 MB cap in the paper;
/// kept configurable for tests).
pub struct GradientWire {
    codec: Arc<dyn Codec>,
    store: Arc<ObjectStore>,
    inline_cap: usize,
}

impl GradientWire {
    pub fn new(codec: Arc<dyn Codec>, store: Arc<ObjectStore>, inline_cap: usize) -> Self {
        Self { codec, store, inline_cap }
    }

    pub fn codec_name(&self) -> &'static str {
        self.codec.name()
    }

    /// Encode a gradient for the broker. Returns the message payload
    /// (either the codec wire bytes, or an [`ObjectRef`] pointing at
    /// them) plus the raw wire size for stats.
    pub fn encode(&self, grads: &[f32]) -> Result<(Bytes, usize)> {
        let wire = self.codec.encode(grads)?;
        let size = wire.len();
        if size <= self.inline_cap {
            return Ok((wire, size));
        }
        // the paper's S3+UUID path
        let r = self.store.put_new(GRADIENT_BUCKET, wire)?;
        Ok((Bytes::from(r.to_wire()), size))
    }

    /// Decode a broker payload back into a gradient vector.
    pub fn decode(&self, payload: &Bytes) -> Result<Vec<f32>> {
        if ObjectRef::is_wire(payload) {
            let r = ObjectRef::from_wire(payload)?;
            let wire = self.store.get_ref(&r)?;
            return self.codec.decode(&wire);
        }
        self.codec.decode(payload)
    }

    /// Publish peer `r`'s epoch-`e` gradient to its dedicated queue.
    pub fn publish(
        &self,
        broker: &Broker,
        sender: usize,
        epoch: u64,
        grads: &[f32],
    ) -> Result<usize> {
        let (payload, wire_size) = self.encode(grads)?;
        broker.publish(
            &Broker::gradient_queue(sender),
            Message::new(sender, epoch, payload),
        )?;
        Ok(wire_size)
    }
}

/// Algorithm 1's `Gradients_Peers` dictionary: rank -> gradient.
#[derive(Debug, Default)]
pub struct GradientDict {
    entries: BTreeMap<usize, Vec<f32>>,
}

impl GradientDict {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, rank: usize, grads: Vec<f32>) {
        self.entries.insert(rank, grads);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ranks(&self) -> Vec<usize> {
        self.entries.keys().copied().collect()
    }

    /// `AverageGradients`: elementwise mean across all entries.
    pub fn average(&self) -> Result<Vec<f32>> {
        let mut it = self.entries.values();
        let first = it
            .next()
            .ok_or_else(|| Error::Broker("averaging an empty gradient dict".into()))?;
        let mut acc: Vec<f64> = first.iter().map(|&x| x as f64).collect();
        let mut n = 1usize;
        for g in it {
            if g.len() != acc.len() {
                return Err(Error::Broker(format!(
                    "gradient length mismatch: {} vs {}",
                    g.len(),
                    acc.len()
                )));
            }
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x as f64;
            }
            n += 1;
        }
        let inv = 1.0 / n as f64;
        Ok(acc.into_iter().map(|a| (a * inv) as f32).collect())
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// Streaming elementwise mean of per-batch gradients (the
/// `AverageBatchesGradients` step): one running f64 sum instead of
/// materializing every per-batch gradient, so memory is O(params)
/// regardless of the batch count. Inputs must be dense f32 vectors —
/// a wire-plane-compressed gradient park is decoded *before* the fold
/// (see `ServerlessOffload::fold_branch`), so the fold order and f64
/// summation stay byte-identical whatever the wire codec.
#[derive(Debug, Default)]
pub struct GradAccumulator {
    acc: Vec<f64>,
    n: usize,
    /// Fold quorum `k`: adds beyond the first `k` are skipped (and
    /// counted), implementing the k-of-n partial fold. 0 = unbounded.
    quorum: usize,
    skipped: usize,
}

impl GradAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold at most the first `k` gradients; further [`Self::add`]
    /// calls are counted as skipped instead of folded (k-of-n partial
    /// folds, `--fold-quorum`). `k = 0` (the default) folds everything.
    pub fn with_quorum(mut self, k: usize) -> Self {
        self.quorum = k;
        self
    }

    /// Adds refused because the quorum was already met.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Fold one gradient into the running sum.
    pub fn add(&mut self, g: &[f32]) -> Result<()> {
        if self.quorum > 0 && self.n >= self.quorum {
            self.skipped += 1;
            return Ok(());
        }
        if self.n == 0 {
            self.acc = g.iter().map(|&x| x as f64).collect();
        } else {
            if g.len() != self.acc.len() {
                return Err(Error::Broker(format!(
                    "gradient length mismatch: {} vs {}",
                    g.len(),
                    self.acc.len()
                )));
            }
            for (a, &x) in self.acc.iter_mut().zip(g) {
                *a += x as f64;
            }
        }
        self.n += 1;
        Ok(())
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Consume the accumulator, returning the elementwise mean.
    pub fn mean(self) -> Result<Vec<f32>> {
        if self.n == 0 {
            return Err(Error::Broker("averaging zero gradients".into()));
        }
        let inv = 1.0 / self.n as f64;
        Ok(self.acc.into_iter().map(|a| (a * inv) as f32).collect())
    }
}

/// Elementwise mean of a set of per-batch gradients. Kept as the
/// slice-shaped convenience; delegates to the streaming
/// [`GradAccumulator`] (identical f64 summation order, so results are
/// bit-for-bit the same).
pub fn average_batch_gradients(grads: &[Vec<f32>]) -> Result<Vec<f32>> {
    let mut acc = GradAccumulator::new();
    for g in grads {
        acc.add(g)?;
    }
    acc.mean()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::QueueMode;
    use crate::compress::RawCodec;

    fn wire(cap: usize) -> (GradientWire, Arc<ObjectStore>) {
        let store = Arc::new(ObjectStore::new());
        (
            GradientWire::new(Arc::new(RawCodec), store.clone(), cap),
            store,
        )
    }

    #[test]
    fn small_gradient_inline() {
        let (w, store) = wire(1024);
        let g = vec![1.0f32, -2.0, 3.0];
        let (payload, size) = w.encode(&g).unwrap();
        assert!(!ObjectRef::is_wire(&payload));
        assert_eq!(size, payload.len());
        assert_eq!(w.decode(&payload).unwrap(), g);
        assert_eq!(store.stats().0, 0); // nothing parked
    }

    #[test]
    fn large_gradient_overflows_to_store() {
        let (w, store) = wire(16);
        let g: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let (payload, size) = w.encode(&g).unwrap();
        assert!(ObjectRef::is_wire(&payload));
        assert!(size > 16);
        assert_eq!(w.decode(&payload).unwrap(), g);
        assert_eq!(store.stats().0, 1);
        assert!(payload.len() < 100); // the ref is tiny
    }

    #[test]
    fn publish_routes_to_peer_queue() {
        let (w, _) = wire(1 << 20);
        let broker = Broker::default();
        broker
            .declare(&Broker::gradient_queue(2), QueueMode::LatestOnly)
            .unwrap();
        w.publish(&broker, 2, 7, &[1.0, 2.0]).unwrap();
        let m = broker
            .get(&Broker::gradient_queue(2))
            .unwrap()
            .peek_latest()
            .unwrap();
        assert_eq!(m.sender, 2);
        assert_eq!(m.epoch, 7);
        assert_eq!(w.decode(&m.payload).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn dict_average() {
        let mut d = GradientDict::new();
        d.insert(0, vec![1.0, 2.0]);
        d.insert(1, vec![3.0, 4.0]);
        d.insert(2, vec![5.0, 6.0]);
        assert_eq!(d.average().unwrap(), vec![3.0, 4.0]);
        assert_eq!(d.ranks(), vec![0, 1, 2]);
    }

    #[test]
    fn dict_rejects_mismatched_lengths() {
        let mut d = GradientDict::new();
        d.insert(0, vec![1.0]);
        d.insert(1, vec![1.0, 2.0]);
        assert!(d.average().is_err());
    }

    #[test]
    fn empty_dict_average_errors() {
        assert!(GradientDict::new().average().is_err());
    }

    #[test]
    fn batch_average_matches_manual() {
        let got =
            average_batch_gradients(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![2.0, 2.0]])
                .unwrap();
        assert_eq!(got, vec![1.0, 1.0]);
    }

    #[test]
    fn accumulator_matches_dict_average_bitwise() {
        // the streaming path must reproduce GradientDict::average exactly
        // (f64 sum in order, then * 1/n, then cast)
        let grads: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..5).map(|j| ((i * 5 + j) as f32).sin()).collect())
            .collect();
        let mut d = GradientDict::new();
        let mut acc = GradAccumulator::new();
        for (i, g) in grads.iter().enumerate() {
            d.insert(i, g.clone());
            acc.add(g).unwrap();
        }
        assert_eq!(acc.count(), 7);
        let via_dict = d.average().unwrap();
        let via_acc = acc.mean().unwrap();
        for (a, b) in via_dict.iter().zip(&via_acc) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn accumulator_rejects_mismatch_and_empty() {
        let mut acc = GradAccumulator::new();
        acc.add(&[1.0, 2.0]).unwrap();
        assert!(acc.add(&[1.0]).is_err());
        assert!(GradAccumulator::new().mean().is_err());
    }

    #[test]
    fn accumulator_quorum_folds_first_k_only() {
        let mut acc = GradAccumulator::new().with_quorum(2);
        acc.add(&[2.0, 0.0]).unwrap();
        acc.add(&[4.0, 2.0]).unwrap();
        // beyond the quorum: skipped, even a mismatched length
        acc.add(&[100.0, 100.0]).unwrap();
        acc.add(&[1.0]).unwrap();
        assert_eq!(acc.count(), 2);
        assert_eq!(acc.skipped(), 2);
        assert_eq!(acc.mean().unwrap(), vec![3.0, 1.0]);
        // quorum 0 folds everything (the default path is untouched)
        let mut all = GradAccumulator::new().with_quorum(0);
        for _ in 0..3 {
            all.add(&[3.0]).unwrap();
        }
        assert_eq!(all.count(), 3);
        assert_eq!(all.skipped(), 0);
    }
}
