//! Elastic peer membership: broker-backed heartbeats, death
//! declaration, partition takeover, and barrier back-fill.
//!
//! The paper pitches P2P-over-serverless as fault tolerant, but a
//! fixed peer set with fail-fast abort (`Cluster::run` pre-PR-8) dies
//! with its first casualty. This module makes liveness a tracked,
//! policy-driven property:
//!
//! - Every live peer runs a [`HeartbeatPump`] publishing on its
//!   `peer.{r}.heartbeat` queue every `--heartbeat-interval-ms`; the
//!   shared [`Membership`] table records the last beat per rank.
//! - Any waiting loop (gradient consume, epoch barrier, verdict wait)
//!   parks with a timeout and calls [`Membership::reap`] on expiry: a
//!   peer whose beat is staler than `--peer-timeout-ms` is declared
//!   dead. A peer whose thread *exits* with an error is declared dead
//!   immediately by the cluster's spawn wrapper — the timeout path
//!   only has to catch hangs.
//! - What happens next is the `--on-peer-failure` policy:
//!   [`FailurePolicy::Abort`] keeps the historical fail-fast,
//!   [`FailurePolicy::Drop`] shrinks the gradient average to the
//!   survivors, and [`FailurePolicy::Takeover`] assigns a deterministic
//!   successor (the next alive rank after the dead one, wrapping) that
//!   recomputes the dead peer's partition — re-dispatching its
//!   epoch-persistent batch refs through the successor's own Lambda
//!   lane — and publishes the gradient *on the dead peer's queue* so
//!   every consumer keeps seeing a full-width exchange.
//! - The cumulative epoch barrier (`version >= epoch * peers`) would
//!   never fill once a peer stops arriving, so survivors back-fill
//!   proxy arrivals for dead ranks via [`Membership::fill_barrier`],
//!   each (peer, epoch) proxy claimed exactly once.
//!
//! The membership plane is **armed** only when the policy is not
//! `abort` or a fault plan is active: an unarmed run publishes no
//! heartbeats and reaps nothing, keeping every broker/message counter
//! byte-identical to the pre-membership trainer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::sync::EpochBarrier;
use crate::broker::{Broker, Message, QueueMode};
use crate::config::FailurePolicy;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::store::ObjectRef;
use crate::util::Bytes;

/// What a successor needs to recompute a dead peer's partition.
#[derive(Debug, Clone)]
pub enum PartitionHandle {
    /// Serverless: the epoch-persistent packed-batch refs the dead
    /// peer uploaded at setup. Takeover re-dispatches these through
    /// the successor's own function — nothing is re-uploaded.
    Refs(Vec<ObjectRef>),
    /// Instance: the raw partition; the successor re-batches it with
    /// the dead peer's seed so the gradients are the ones the dead
    /// peer would have computed.
    Data(Box<Dataset>),
}

#[derive(Debug)]
struct Slot {
    alive: bool,
    /// Finished its run cleanly — stops beating but is not dead.
    done: bool,
    last_beat: Instant,
    reason: Option<String>,
    /// Highest epoch this peer really arrived at the barrier for.
    last_barrier_epoch: u64,
    /// Highest epoch proxied on this (dead) peer's behalf.
    proxied_to: u64,
    /// Assigned takeover successor once dead.
    successor: Option<usize>,
    /// Highest epoch a successor has published a gradient for.
    takeover_published: u64,
    partition: Option<PartitionHandle>,
}

/// Cluster-wide liveness table shared by every peer thread and the
/// trainer. All counters surface as `membership.*` in the train report.
pub struct Membership {
    peers: usize,
    policy: FailurePolicy,
    armed: bool,
    heartbeat_interval: Duration,
    peer_timeout: Duration,
    broker: Arc<Broker>,
    state: Mutex<Vec<Slot>>,
    beats: AtomicU64,
    deaths: AtomicU64,
    barrier_proxies: AtomicU64,
    takeover_epochs: AtomicU64,
    dropped_grads: AtomicU64,
}

impl Membership {
    /// Build the table. `armed` turns the heartbeat/reap machinery on;
    /// unarmed tables are inert observers that never publish or
    /// declare, so default runs stay byte-identical.
    pub fn new(
        broker: Arc<Broker>,
        peers: usize,
        policy: FailurePolicy,
        heartbeat_interval: Duration,
        peer_timeout: Duration,
        armed: bool,
    ) -> Result<Self> {
        if armed {
            for r in 0..peers {
                broker.declare(&Broker::heartbeat_queue(r), QueueMode::LatestOnly)?;
            }
        }
        let now = Instant::now();
        let slots = (0..peers)
            .map(|_| Slot {
                alive: true,
                done: false,
                last_beat: now,
                reason: None,
                last_barrier_epoch: 0,
                proxied_to: 0,
                successor: None,
                takeover_published: 0,
                partition: None,
            })
            .collect();
        Ok(Self {
            peers,
            policy,
            armed,
            heartbeat_interval,
            peer_timeout,
            broker,
            state: Mutex::new(slots),
            beats: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            barrier_proxies: AtomicU64::new(0),
            takeover_epochs: AtomicU64::new(0),
            dropped_grads: AtomicU64::new(0),
        })
    }

    pub fn armed(&self) -> bool {
        self.armed
    }

    pub fn policy(&self) -> FailurePolicy {
        self.policy
    }

    pub fn peers(&self) -> usize {
        self.peers
    }

    /// The wait-slice for membership-aware blocking loops: short enough
    /// to reap promptly, never zero.
    pub fn wait_slice(&self) -> Duration {
        self.heartbeat_interval.max(Duration::from_millis(1))
    }

    /// Publish one heartbeat for `rank` and refresh its table entry.
    pub fn beat(&self, rank: usize) {
        {
            let mut st = self.state.lock().unwrap();
            st[rank].last_beat = Instant::now();
        }
        if self.armed {
            let n = self.beats.fetch_add(1, Ordering::Relaxed) + 1;
            let _ = self
                .broker
                .publish(&Broker::heartbeat_queue(rank), Message::new(rank, n, Bytes::new()));
        }
    }

    /// Spawn the per-peer heartbeat thread; dropping the returned pump
    /// (on any exit path, including unwind) stops and joins it, so a
    /// peer's beats stop exactly when its thread does.
    pub fn start_pump(self: Arc<Self>, rank: usize) -> HeartbeatPump {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let interval = self.wait_slice();
        let table = self;
        let handle = std::thread::spawn(move || {
            while !flag.load(Ordering::SeqCst) {
                table.beat(rank);
                std::thread::sleep(interval);
            }
        });
        HeartbeatPump { stop, handle: Some(handle) }
    }

    /// Mark a clean exit: the peer stops beating but is *not* dead.
    pub fn mark_done(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        st[rank].done = true;
    }

    /// Declare `rank` dead. Returns whether this call did it (the
    /// first reason wins). Assigns the takeover successor — the next
    /// alive, unfinished rank after the dead one, wrapping — and
    /// reroutes any dead peer whose successor just died.
    pub fn declare_dead(&self, rank: usize, reason: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st[rank].alive {
            return false;
        }
        st[rank].alive = false;
        st[rank].reason = Some(reason.to_string());
        self.deaths.fetch_add(1, Ordering::Relaxed);
        let next_alive = |st: &Vec<Slot>, from: usize| -> Option<usize> {
            (1..self.peers)
                .map(|d| (from + d) % self.peers)
                .find(|&r| st[r].alive && !st[r].done)
        };
        st[rank].successor = next_alive(&st, rank);
        for r in 0..self.peers {
            if !st[r].alive && st[r].successor == Some(rank) {
                st[r].successor = next_alive(&st, r);
            }
        }
        true
    }

    pub fn is_alive(&self, rank: usize) -> bool {
        self.state.lock().unwrap()[rank].alive
    }

    pub fn alive_count(&self) -> usize {
        self.state.lock().unwrap().iter().filter(|s| s.alive).count()
    }

    /// Ranks currently declared dead, with their recorded reasons.
    pub fn dead_peers(&self) -> Vec<(usize, String)> {
        self.state
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.alive)
            .map(|(r, s)| (r, s.reason.clone().unwrap_or_default()))
            .collect()
    }

    /// The verdict leader: the smallest alive rank (rank 0 until it
    /// dies).
    pub fn leader(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .iter()
            .position(|s| s.alive)
            .unwrap_or(0)
    }

    /// Declare dead every peer whose heartbeat went stale. Under the
    /// `abort` policy a stale peer aborts the whole run (the fail-fast
    /// contract, now with a deadline instead of an infinite park);
    /// under `takeover`/`drop` the table just records the death and
    /// the caller's waiting loop routes around it. No-op when unarmed.
    pub fn reap(&self) -> Result<()> {
        if !self.armed {
            return Ok(());
        }
        let stale: Vec<usize> = {
            let st = self.state.lock().unwrap();
            st.iter()
                .enumerate()
                .filter(|(_, s)| s.alive && !s.done && s.last_beat.elapsed() > self.peer_timeout)
                .map(|(r, _)| r)
                .collect()
        };
        for r in stale {
            let reason = format!(
                "peer {r} heartbeat stale for over {}ms",
                self.peer_timeout.as_millis()
            );
            if self.policy == FailurePolicy::Abort {
                self.broker.abort(&reason);
                return Err(Error::Aborted(reason));
            }
            self.declare_dead(r, &reason);
        }
        Ok(())
    }

    /// Record that `rank` really arrived at the barrier for `epoch`
    /// (so proxies never double an arrival the peer already made).
    pub fn note_barrier_arrival(&self, rank: usize, epoch: u64) {
        let mut st = self.state.lock().unwrap();
        if epoch > st[rank].last_barrier_epoch {
            st[rank].last_barrier_epoch = epoch;
        }
    }

    /// Back-fill proxy arrivals for every dead peer up to `epoch`. Each
    /// (peer, epoch) pair is claimed exactly once under the table lock,
    /// so concurrent waiters never double-publish.
    pub fn fill_barrier(&self, barrier: &EpochBarrier, epoch: u64) -> Result<()> {
        if !self.armed {
            return Ok(());
        }
        let mut to_proxy: Vec<(usize, u64)> = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            for (r, slot) in st.iter_mut().enumerate() {
                if slot.alive {
                    continue;
                }
                let from = slot.proxied_to.max(slot.last_barrier_epoch) + 1;
                for e in from..=epoch {
                    to_proxy.push((r, e));
                }
                if epoch > slot.proxied_to {
                    slot.proxied_to = epoch;
                }
            }
        }
        for (r, e) in to_proxy {
            barrier.proxy_arrive(r, e)?;
            self.barrier_proxies.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Register what a successor would need to recompute `rank`'s
    /// partition (refs for serverless peers, the raw data for instance
    /// peers).
    pub fn register_partition(&self, rank: usize, handle: PartitionHandle) {
        let mut st = self.state.lock().unwrap();
        st[rank].partition = Some(handle);
    }

    /// The dead peer's registered partition, if any.
    pub fn partition_of(&self, rank: usize) -> Option<PartitionHandle> {
        self.state.lock().unwrap()[rank].partition.clone()
    }

    /// Should `me` compute and publish `dead`'s epoch-`epoch` gradient?
    /// True only for the assigned successor, only under the takeover
    /// policy, and only while that epoch is unpublished — the claim is
    /// finalized by [`Self::note_takeover_published`] after the publish
    /// lands, so a successor that dies mid-takeover is re-covered by
    /// its own successor.
    pub fn claim_takeover(&self, me: usize, dead: usize, epoch: u64) -> bool {
        if self.policy != FailurePolicy::Takeover {
            return false;
        }
        let st = self.state.lock().unwrap();
        let slot = &st[dead];
        !slot.alive && slot.successor == Some(me) && slot.takeover_published < epoch
    }

    /// Record a successful on-behalf gradient publish.
    pub fn note_takeover_published(&self, dead: usize, epoch: u64) {
        let mut st = self.state.lock().unwrap();
        if epoch > st[dead].takeover_published {
            st[dead].takeover_published = epoch;
        }
        self.takeover_epochs.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dead-peer gradient skipped under the `drop` policy.
    pub fn note_dropped_grad(&self) {
        self.dropped_grads.fetch_add(1, Ordering::Relaxed);
    }

    /// Heartbeats published.
    pub fn heartbeats(&self) -> u64 {
        self.beats.load(Ordering::Relaxed)
    }

    /// Peers declared dead.
    pub fn deaths(&self) -> u64 {
        self.deaths.load(Ordering::Relaxed)
    }

    /// Barrier arrivals proxied on behalf of dead peers.
    pub fn barrier_proxies(&self) -> u64 {
        self.barrier_proxies.load(Ordering::Relaxed)
    }

    /// Dead-peer epochs recomputed and published by successors.
    pub fn takeover_epochs(&self) -> u64 {
        self.takeover_epochs.load(Ordering::Relaxed)
    }

    /// Dead-peer gradients skipped under the `drop` policy.
    pub fn dropped_grads(&self) -> u64 {
        self.dropped_grads.load(Ordering::Relaxed)
    }
}

/// Guard for a peer's heartbeat thread; dropping stops and joins it.
pub struct HeartbeatPump {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HeartbeatPump {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(peers: usize, policy: FailurePolicy) -> (Arc<Broker>, Arc<Membership>) {
        let broker = Arc::new(Broker::default());
        let m = Membership::new(
            broker.clone(),
            peers,
            policy,
            Duration::from_millis(5),
            Duration::from_millis(30),
            true,
        )
        .unwrap();
        (broker, Arc::new(m))
    }

    #[test]
    fn stale_peer_is_reaped_under_drop_policy() {
        let (_, m) = table(3, FailurePolicy::Drop);
        m.beat(0);
        m.beat(2);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        m.beat(2);
        m.reap().unwrap();
        assert!(m.is_alive(0));
        assert!(!m.is_alive(1), "peer 1 never beat and should be dead");
        assert!(m.is_alive(2));
        assert_eq!(m.alive_count(), 2);
        assert_eq!(m.deaths(), 1);
    }

    #[test]
    fn stale_peer_aborts_under_abort_policy() {
        let (broker, m) = table(2, FailurePolicy::Abort);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        let err = m.reap().unwrap_err();
        assert!(err.to_string().contains("peer 1"), "{err}");
        assert!(broker.is_aborted());
    }

    #[test]
    fn unarmed_table_never_reaps() {
        let broker = Arc::new(Broker::default());
        let m = Membership::new(
            broker.clone(),
            2,
            FailurePolicy::Abort,
            Duration::from_millis(5),
            Duration::from_millis(10),
            false,
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        m.reap().unwrap();
        assert_eq!(m.alive_count(), 2);
        assert_eq!(m.heartbeats(), 0);
        // unarmed tables declare no heartbeat queues either
        assert!(broker.get(&Broker::heartbeat_queue(0)).is_err());
    }

    #[test]
    fn done_peers_are_not_reaped() {
        let (_, m) = table(2, FailurePolicy::Drop);
        m.mark_done(1);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        m.reap().unwrap();
        assert!(m.is_alive(1), "a finished peer is not a dead peer");
    }

    #[test]
    fn successor_assignment_wraps_and_reroutes() {
        let (_, m) = table(4, FailurePolicy::Takeover);
        assert!(m.declare_dead(3, "killed"));
        // takeover claim: only the successor (rank 0, wrapping) wins
        assert!(m.claim_takeover(0, 3, 1));
        assert!(!m.claim_takeover(1, 3, 1));
        // a published epoch cannot be claimed again
        m.note_takeover_published(3, 1);
        assert!(!m.claim_takeover(0, 3, 1));
        assert!(m.claim_takeover(0, 3, 2));
        // the successor dying reroutes the dead peer's coverage
        assert!(m.declare_dead(0, "killed too"));
        assert!(m.claim_takeover(1, 3, 2));
        assert!(!m.claim_takeover(2, 3, 2));
        // and the double-declare is refused
        assert!(!m.declare_dead(3, "again"));
        assert_eq!(m.deaths(), 2);
    }

    #[test]
    fn leader_falls_over_to_smallest_alive_rank() {
        let (_, m) = table(3, FailurePolicy::Takeover);
        assert_eq!(m.leader(), 0);
        m.declare_dead(0, "killed");
        assert_eq!(m.leader(), 1);
        m.declare_dead(1, "killed");
        assert_eq!(m.leader(), 2);
    }

    #[test]
    fn barrier_backfill_proxies_each_missing_epoch_once() {
        let (broker, m) = table(2, FailurePolicy::Takeover);
        let barrier = EpochBarrier::new(&broker, 2).unwrap();
        // peer 1 really arrived for epoch 1, then died
        barrier.arrive(1, 1).unwrap();
        m.note_barrier_arrival(1, 1);
        m.declare_dead(1, "killed");
        // survivor arrives for epochs 1..=3 and back-fills
        for e in 1..=3u64 {
            barrier.arrive(0, e).unwrap();
            m.note_barrier_arrival(0, e);
            m.fill_barrier(&barrier, e).unwrap();
            assert!(
                barrier.wait_timeout(e, Duration::from_millis(100)).unwrap(),
                "barrier {e} should fill via proxies"
            );
        }
        // epochs 2 and 3 proxied; epoch 1 was a real arrival
        assert_eq!(m.barrier_proxies(), 2);
        // re-filling claims nothing new
        m.fill_barrier(&barrier, 3).unwrap();
        assert_eq!(m.barrier_proxies(), 2);
    }

    #[test]
    fn pump_beats_until_dropped() {
        let (_, m) = table(1, FailurePolicy::Drop);
        let pump = m.clone().start_pump(0);
        std::thread::sleep(Duration::from_millis(25));
        drop(pump);
        let beats = m.heartbeats();
        assert!(beats >= 2, "expected a few beats, got {beats}");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(m.heartbeats(), beats, "pump must stop after drop");
    }

    #[test]
    fn partition_registry_roundtrips() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        assert!(m.partition_of(1).is_none());
        m.register_partition(1, PartitionHandle::Refs(Vec::new()));
        assert!(matches!(m.partition_of(1), Some(PartitionHandle::Refs(_))));
    }
}
