//! Elastic peer membership: broker-backed heartbeats, death
//! declaration, partition takeover, and barrier back-fill.
//!
//! The paper pitches P2P-over-serverless as fault tolerant, but a
//! fixed peer set with fail-fast abort (`Cluster::run` pre-PR-8) dies
//! with its first casualty. This module makes liveness a tracked,
//! policy-driven property:
//!
//! - Every live peer runs a [`HeartbeatPump`] publishing on its
//!   `peer.{r}.heartbeat` queue every `--heartbeat-interval-ms`; the
//!   shared [`Membership`] table records the last beat per rank.
//! - Any waiting loop (gradient consume, epoch barrier, verdict wait)
//!   parks with a timeout and calls [`Membership::reap`] on expiry: a
//!   peer whose beat is staler than `--peer-timeout-ms` is declared
//!   dead. A peer whose thread *exits* with an error is declared dead
//!   immediately by the cluster's spawn wrapper — the timeout path
//!   only has to catch hangs.
//! - What happens next is the `--on-peer-failure` policy:
//!   [`FailurePolicy::Abort`] keeps the historical fail-fast,
//!   [`FailurePolicy::Drop`] shrinks the gradient average to the
//!   survivors, and [`FailurePolicy::Takeover`] assigns a deterministic
//!   successor (the next alive rank after the dead one, wrapping) that
//!   recomputes the dead peer's partition — re-dispatching its
//!   epoch-persistent batch refs through the successor's own Lambda
//!   lane — and publishes the gradient *on the dead peer's queue* so
//!   every consumer keeps seeing a full-width exchange.
//! - The cumulative epoch barrier (`version >= epoch * peers`) would
//!   never fill once a peer stops arriving, so survivors back-fill
//!   proxy arrivals for dead ranks via [`Membership::fill_barrier`],
//!   each (peer, epoch) proxy claimed exactly once.
//!
//! **Elastic scale-up (PR 10).** Membership is no longer shrink-only:
//! a fault plan can script `join:rankN@E` events and the table admits
//! the new peer at the epoch-`E` boundary. The lifecycle is
//! announce → admit → warm-start:
//!
//! - the joiner thread publishes its rank on the `membership.join`
//!   Fifo queue at spawn and parks on `membership.join.admit.{rank}`;
//! - the **leader**, after folding epoch `E-1`'s model update, calls
//!   [`Membership::admit_join`] for every scheduled join at `E`:
//!   a *revival* (rank below the original width, currently dead)
//!   re-arms the dead slot and hands back its registered partition —
//!   the joiner absorbs the orphaned batch refs bit-identically, so
//!   the post-join loss curve matches the fault-free run; a *growth*
//!   join (rank == current width) splits the largest live partition,
//!   the donor sheds half via a [`Membership::take_shed`] directive it
//!   picks up at its next epoch start;
//! - the leader uploads a warm-start copy of the post-`E-1` params to
//!   the shared store and publishes the admit message (params ref +
//!   start epoch) so the joiner can decode state without replaying
//!   history;
//! - the cumulative barrier widens piecewise
//!   ([`EpochBarrier::with_growth`]); revival catch-up epochs the dead
//!   rank still owes are proxied by the admitting leader exactly once.
//!
//! The membership plane is **armed** only when the policy is not
//! `abort` or a fault plan is active: an unarmed run publishes no
//! heartbeats and reaps nothing, keeping every broker/message counter
//! byte-identical to the pre-membership trainer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::sync::EpochBarrier;
use crate::broker::{Broker, Message, QueueMode};
use crate::config::FailurePolicy;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::store::ObjectRef;
use crate::util::Bytes;

/// What a successor needs to recompute a dead peer's partition.
#[derive(Debug, Clone)]
pub enum PartitionHandle {
    /// Serverless: the epoch-persistent packed-batch refs the dead
    /// peer uploaded at setup. Takeover re-dispatches these through
    /// the successor's own function — nothing is re-uploaded.
    Refs(Vec<ObjectRef>),
    /// Instance: the raw partition; the successor re-batches it with
    /// the dead peer's seed so the gradients are the ones the dead
    /// peer would have computed.
    Data(Box<Dataset>),
}

/// How a scheduled join lands in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinKind {
    /// The rank existed at start, died, and rejoins: it absorbs its own
    /// orphaned partition, so the math is bit-identical to a fault-free
    /// run once the takeover hand-back completes.
    Revival,
    /// A brand-new rank widens the cluster; it receives half of the
    /// largest live partition (deterministic, but a different batch
    /// split than the fault-free run).
    Growth,
}

/// What the admitting leader must do after [`Membership::admit_join`]
/// flips the slot.
#[derive(Debug)]
pub struct JoinAdmission {
    pub kind: JoinKind,
    /// First epoch the joiner computes (its join epoch).
    pub start_epoch: u64,
    /// Barrier epochs the revived rank still owes that nobody proxied
    /// yet — claimed under the table lock, published by the leader
    /// before its own barrier arrival so the barrier can't hang.
    pub catch_up: Vec<u64>,
}

/// One scheduled join (from the fault plan), tracked to admission.
#[derive(Debug, Clone)]
struct JoinEntry {
    rank: usize,
    epoch: u64,
    admitted: bool,
}

/// A pending "shrink your partition" directive for a growth-join
/// donor, picked up at the donor's next epoch start.
#[derive(Debug)]
struct Shed {
    donor: usize,
    epoch: u64,
    handle: PartitionHandle,
}

#[derive(Debug)]
struct Slot {
    alive: bool,
    /// Finished its run cleanly — stops beating but is not dead.
    done: bool,
    last_beat: Instant,
    reason: Option<String>,
    /// Highest epoch this peer really arrived at the barrier for.
    last_barrier_epoch: u64,
    /// Highest epoch proxied on this (dead) peer's behalf.
    proxied_to: u64,
    /// Assigned takeover successor once dead.
    successor: Option<usize>,
    /// Highest epoch a successor has published a gradient for.
    takeover_published: u64,
    partition: Option<PartitionHandle>,
    /// A growth joiner that hasn't been admitted yet: the slot exists
    /// (so beats/indexing work) but it is neither alive nor dead —
    /// reaping, proxying and takeover all skip it.
    pending_join: Option<u64>,
}

impl Slot {
    fn fresh(now: Instant, pending_join: Option<u64>) -> Self {
        Slot {
            alive: pending_join.is_none(),
            done: false,
            last_beat: now,
            reason: None,
            last_barrier_epoch: 0,
            proxied_to: 0,
            successor: None,
            takeover_published: 0,
            partition: None,
            pending_join,
        }
    }
}

/// Cluster-wide liveness table shared by every peer thread and the
/// trainer. All counters surface as `membership.*` in the train report.
pub struct Membership {
    peers: usize,
    policy: FailurePolicy,
    armed: bool,
    heartbeat_interval: Duration,
    peer_timeout: Duration,
    broker: Arc<Broker>,
    state: Mutex<Vec<Slot>>,
    /// Scheduled joins from the fault plan (locked after `state` is
    /// never needed: always lock `schedule` first, then `state`).
    schedule: Mutex<Vec<JoinEntry>>,
    /// Pending partition-shrink directives for growth-join donors.
    sheds: Mutex<Vec<Shed>>,
    beats: AtomicU64,
    deaths: AtomicU64,
    barrier_proxies: AtomicU64,
    takeover_epochs: AtomicU64,
    dropped_grads: AtomicU64,
    joins_admitted: AtomicU64,
}

impl Membership {
    /// Build the table. `armed` turns the heartbeat/reap machinery on;
    /// unarmed tables are inert observers that never publish or
    /// declare, so default runs stay byte-identical.
    pub fn new(
        broker: Arc<Broker>,
        peers: usize,
        policy: FailurePolicy,
        heartbeat_interval: Duration,
        peer_timeout: Duration,
        armed: bool,
    ) -> Result<Self> {
        if armed {
            for r in 0..peers {
                broker.declare(&Broker::heartbeat_queue(r), QueueMode::LatestOnly)?;
            }
        }
        let now = Instant::now();
        let slots = (0..peers).map(|_| Slot::fresh(now, None)).collect();
        Ok(Self {
            peers,
            policy,
            armed,
            heartbeat_interval,
            peer_timeout,
            broker,
            state: Mutex::new(slots),
            schedule: Mutex::new(Vec::new()),
            sheds: Mutex::new(Vec::new()),
            beats: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            barrier_proxies: AtomicU64::new(0),
            takeover_epochs: AtomicU64::new(0),
            dropped_grads: AtomicU64::new(0),
            joins_admitted: AtomicU64::new(0),
        })
    }

    pub fn armed(&self) -> bool {
        self.armed
    }

    pub fn policy(&self) -> FailurePolicy {
        self.policy
    }

    pub fn peers(&self) -> usize {
        self.peers
    }

    /// Install the scheduled joins (from the resolved fault plan),
    /// ordered (epoch, rank). Growth ranks must extend the table
    /// contiguously — guaranteed by the plan's width simulation, but
    /// re-checked here. Armed tables declare the new ranks' heartbeat
    /// queues up front so consumers never race a missing queue.
    pub fn set_join_schedule(&self, joins: &[(usize, u64)]) -> Result<()> {
        let mut sched = self.schedule.lock().unwrap();
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        for &(rank, epoch) in joins {
            if rank >= st.len() {
                if rank != st.len() {
                    return Err(Error::Config(format!(
                        "growth join rank {rank} is not contiguous with \
                         the table width {}",
                        st.len()
                    )));
                }
                st.push(Slot::fresh(now, Some(epoch)));
                if self.armed {
                    self.broker
                        .declare(&Broker::heartbeat_queue(rank), QueueMode::LatestOnly)?;
                }
            }
            sched.push(JoinEntry { rank, epoch, admitted: false });
        }
        sched.sort_by_key(|j| (j.epoch, j.rank));
        Ok(())
    }

    /// Every scheduled join as (rank, epoch), admission order.
    pub fn join_schedule(&self) -> Vec<(usize, u64)> {
        self.schedule
            .lock()
            .unwrap()
            .iter()
            .map(|j| (j.rank, j.epoch))
            .collect()
    }

    /// The epochs at which *growth* joins widen the barrier (one entry
    /// per new rank) — feed to [`EpochBarrier::with_growth`].
    pub fn growth_epochs(&self) -> Vec<u64> {
        self.schedule
            .lock()
            .unwrap()
            .iter()
            .filter(|j| j.rank >= self.peers)
            .map(|j| j.epoch)
            .collect()
    }

    /// Cluster width at `epoch`: the base peers plus every growth rank
    /// whose join epoch has arrived. Static in the schedule, so every
    /// peer computes the same consume/fold width with no coordination.
    pub fn width_at(&self, epoch: u64) -> usize {
        self.peers
            + self
                .schedule
                .lock()
                .unwrap()
                .iter()
                .filter(|j| j.rank >= self.peers && j.epoch <= epoch)
                .count()
    }

    /// The widest the cluster ever gets (for teardown/reporting).
    pub fn max_width(&self) -> usize {
        self.state.lock().unwrap().len()
    }

    /// Scheduled joins due at or before `epoch` that were not admitted
    /// yet, in admission order. The leader drains this at each epoch
    /// boundary (`<=` so a boundary skipped by a leader fail-over is
    /// caught up at the next one).
    pub fn pending_joins_at(&self, epoch: u64) -> Vec<(usize, u64)> {
        self.schedule
            .lock()
            .unwrap()
            .iter()
            .filter(|j| !j.admitted && j.epoch <= epoch)
            .map(|j| (j.rank, j.epoch))
            .collect()
    }

    /// Admit scheduled joiner `rank` at its `join_epoch` boundary.
    ///
    /// Returns `Ok(None)` when the admission is declined — a revival
    /// whose rank never died (the scripted kill didn't land) has
    /// nothing to rejoin. A *revival* re-arms the dead slot: the
    /// registered partition stays put for the joiner to absorb, and the
    /// barrier epochs the dead rank still owes are claimed here (under
    /// the lock, so concurrent [`Self::fill_barrier`] callers can't
    /// double-proxy) and returned for the leader to publish. A *growth*
    /// join activates the pending slot and splits the largest live
    /// partition: the donor's shrunken handle is parked as a shed
    /// directive ([`Self::take_shed`]) and the split-off half becomes
    /// the joiner's registered partition.
    pub fn admit_join(&self, rank: usize, join_epoch: u64) -> Result<Option<JoinAdmission>> {
        let mut sched = self.schedule.lock().unwrap();
        let entry = sched
            .iter_mut()
            .find(|j| j.rank == rank && j.epoch == join_epoch && !j.admitted)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no pending join scheduled for rank {rank} at epoch {join_epoch}"
                ))
            })?;
        let mut st = self.state.lock().unwrap();
        if rank < self.peers {
            // Revival: the rank must actually be dead.
            if st[rank].alive {
                entry.admitted = true;
                return Ok(None);
            }
            let slot = &mut st[rank];
            let from = slot.proxied_to.max(slot.last_barrier_epoch) + 1;
            let catch_up: Vec<u64> = (from..join_epoch).collect();
            slot.proxied_to = slot.proxied_to.max(join_epoch.saturating_sub(1));
            slot.alive = true;
            slot.done = false;
            slot.reason = None;
            slot.successor = None;
            slot.last_beat = Instant::now();
            entry.admitted = true;
            self.joins_admitted.fetch_add(1, Ordering::Relaxed);
            return Ok(Some(JoinAdmission {
                kind: JoinKind::Revival,
                start_epoch: join_epoch,
                catch_up,
            }));
        }
        // Growth: activate the pending slot, then split the largest
        // live partition between the donor and the joiner.
        let donor = st
            .iter()
            .enumerate()
            .filter(|&(r, s)| {
                r != rank && s.alive && s.pending_join.is_none() && s.partition.is_some()
            })
            .max_by_key(|&(r, s)| {
                let len = match s.partition.as_ref() {
                    Some(PartitionHandle::Refs(v)) => v.len(),
                    Some(PartitionHandle::Data(d)) => d.len(),
                    None => 0,
                };
                (len, std::cmp::Reverse(r))
            })
            .map(|(r, _)| r)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "growth join rank {rank}: no live peer with a registered \
                     partition to split"
                ))
            })?;
        let handle = st[donor].partition.take().expect("donor has a partition");
        let (keep, give) = split_partition(handle)?;
        st[donor].partition = Some(keep.clone());
        st[rank].partition = Some(give);
        st[rank].alive = true;
        st[rank].pending_join = None;
        st[rank].last_beat = Instant::now();
        self.sheds.lock().unwrap().push(Shed { donor, epoch: join_epoch, handle: keep });
        entry.admitted = true;
        self.joins_admitted.fetch_add(1, Ordering::Relaxed);
        Ok(Some(JoinAdmission {
            kind: JoinKind::Growth,
            start_epoch: join_epoch,
            catch_up: Vec::new(),
        }))
    }

    /// The shrink directive waiting for donor `me` with effect at or
    /// before `epoch`, if any — consumed exactly once. The donor
    /// applies the returned (smaller) handle as its active partition
    /// before computing the epoch.
    pub fn take_shed(&self, me: usize, epoch: u64) -> Option<PartitionHandle> {
        let mut sheds = self.sheds.lock().unwrap();
        let i = sheds.iter().position(|s| s.donor == me && s.epoch <= epoch)?;
        Some(sheds.remove(i).handle)
    }

    /// Is `rank` a scheduled joiner whose admission hasn't landed by
    /// `epoch`? Consumers skip such ranks instead of applying the
    /// failure policy to a peer that was never up.
    pub fn awaiting_join(&self, rank: usize, epoch: u64) -> bool {
        self.schedule
            .lock()
            .unwrap()
            .iter()
            .any(|j| j.rank == rank && !j.admitted && j.epoch <= epoch)
    }

    /// Publish an admission's claimed catch-up proxies — the barrier
    /// epochs a revived rank still owed, returned by
    /// [`Self::admit_join`] — and count them with the regular proxies.
    pub fn proxy_catch_up(
        &self,
        barrier: &EpochBarrier,
        rank: usize,
        epochs: &[u64],
    ) -> Result<()> {
        for &e in epochs {
            barrier.proxy_arrive(rank, e)?;
            self.barrier_proxies.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// The configured peer-death deadline (the admitting leader bounds
    /// its announce wait with it).
    pub fn peer_timeout(&self) -> Duration {
        self.peer_timeout
    }

    /// The wait-slice for membership-aware blocking loops: short enough
    /// to reap promptly, never zero.
    pub fn wait_slice(&self) -> Duration {
        self.heartbeat_interval.max(Duration::from_millis(1))
    }

    /// Publish one heartbeat for `rank` and refresh its table entry.
    pub fn beat(&self, rank: usize) {
        {
            let mut st = self.state.lock().unwrap();
            st[rank].last_beat = Instant::now();
        }
        if self.armed {
            let n = self.beats.fetch_add(1, Ordering::Relaxed) + 1;
            let _ = self
                .broker
                .publish(&Broker::heartbeat_queue(rank), Message::new(rank, n, Bytes::new()));
        }
    }

    /// Spawn the per-peer heartbeat thread; dropping the returned pump
    /// (on any exit path, including unwind) stops and joins it, so a
    /// peer's beats stop exactly when its thread does.
    pub fn start_pump(self: Arc<Self>, rank: usize) -> HeartbeatPump {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let interval = self.wait_slice();
        let table = self;
        let handle = std::thread::spawn(move || {
            while !flag.load(Ordering::SeqCst) {
                table.beat(rank);
                std::thread::sleep(interval);
            }
        });
        HeartbeatPump { stop, handle: Some(handle) }
    }

    /// Mark a clean exit: the peer stops beating but is *not* dead.
    pub fn mark_done(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        st[rank].done = true;
    }

    /// Declare `rank` dead. Returns whether this call did it (the
    /// first reason wins). Assigns the takeover successor — the next
    /// alive, unfinished rank after the dead one, wrapping — and
    /// reroutes any dead peer whose successor just died.
    pub fn declare_dead(&self, rank: usize, reason: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st[rank].alive {
            return false;
        }
        st[rank].alive = false;
        st[rank].reason = Some(reason.to_string());
        self.deaths.fetch_add(1, Ordering::Relaxed);
        // successors come from the full (possibly grown) table; pending
        // growth slots are not candidates until admitted
        let next_alive = |st: &Vec<Slot>, from: usize| -> Option<usize> {
            let n = st.len();
            (1..n)
                .map(|d| (from + d) % n)
                .find(|&r| st[r].alive && !st[r].done && st[r].pending_join.is_none())
        };
        st[rank].successor = next_alive(&st, rank);
        for r in 0..st.len() {
            if !st[r].alive && st[r].pending_join.is_none() && st[r].successor == Some(rank) {
                st[r].successor = next_alive(&st, r);
            }
        }
        true
    }

    pub fn is_alive(&self, rank: usize) -> bool {
        self.state.lock().unwrap()[rank].alive
    }

    pub fn alive_count(&self) -> usize {
        self.state.lock().unwrap().iter().filter(|s| s.alive).count()
    }

    /// Ranks currently declared dead, with their recorded reasons
    /// (pending growth joiners are neither alive nor dead).
    pub fn dead_peers(&self) -> Vec<(usize, String)> {
        self.state
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.alive && s.pending_join.is_none())
            .map(|(r, s)| (r, s.reason.clone().unwrap_or_default()))
            .collect()
    }

    /// The verdict leader: the smallest alive rank (rank 0 until it
    /// dies).
    pub fn leader(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .iter()
            .position(|s| s.alive)
            .unwrap_or(0)
    }

    /// Declare dead every peer whose heartbeat went stale. Under the
    /// `abort` policy a stale peer aborts the whole run (the fail-fast
    /// contract, now with a deadline instead of an infinite park);
    /// under `takeover`/`drop` the table just records the death and
    /// the caller's waiting loop routes around it. No-op when unarmed.
    pub fn reap(&self) -> Result<()> {
        if !self.armed {
            return Ok(());
        }
        let stale: Vec<usize> = {
            let st = self.state.lock().unwrap();
            st.iter()
                .enumerate()
                .filter(|(_, s)| s.alive && !s.done && s.last_beat.elapsed() > self.peer_timeout)
                .map(|(r, _)| r)
                .collect()
        };
        for r in stale {
            let reason = format!(
                "peer {r} heartbeat stale for over {}ms",
                self.peer_timeout.as_millis()
            );
            if self.policy == FailurePolicy::Abort {
                self.broker.abort(&reason);
                return Err(Error::Aborted(reason));
            }
            self.declare_dead(r, &reason);
        }
        Ok(())
    }

    /// Record that `rank` really arrived at the barrier for `epoch`
    /// (so proxies never double an arrival the peer already made).
    pub fn note_barrier_arrival(&self, rank: usize, epoch: u64) {
        let mut st = self.state.lock().unwrap();
        if epoch > st[rank].last_barrier_epoch {
            st[rank].last_barrier_epoch = epoch;
        }
    }

    /// Back-fill proxy arrivals for every dead peer up to `epoch`. Each
    /// (peer, epoch) pair is claimed exactly once under the table lock,
    /// so concurrent waiters never double-publish.
    pub fn fill_barrier(&self, barrier: &EpochBarrier, epoch: u64) -> Result<()> {
        if !self.armed {
            return Ok(());
        }
        let mut to_proxy: Vec<(usize, u64)> = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            for (r, slot) in st.iter_mut().enumerate() {
                if slot.alive || slot.pending_join.is_some() {
                    continue;
                }
                let from = slot.proxied_to.max(slot.last_barrier_epoch) + 1;
                for e in from..=epoch {
                    to_proxy.push((r, e));
                }
                if epoch > slot.proxied_to {
                    slot.proxied_to = epoch;
                }
            }
        }
        for (r, e) in to_proxy {
            barrier.proxy_arrive(r, e)?;
            self.barrier_proxies.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Register what a successor would need to recompute `rank`'s
    /// partition (refs for serverless peers, the raw data for instance
    /// peers).
    pub fn register_partition(&self, rank: usize, handle: PartitionHandle) {
        let mut st = self.state.lock().unwrap();
        st[rank].partition = Some(handle);
    }

    /// The dead peer's registered partition, if any.
    pub fn partition_of(&self, rank: usize) -> Option<PartitionHandle> {
        self.state.lock().unwrap()[rank].partition.clone()
    }

    /// Should `me` compute and publish `dead`'s epoch-`epoch` gradient?
    /// True only for the assigned successor, only under the takeover
    /// policy, and only while that epoch is unpublished — the claim is
    /// finalized by [`Self::note_takeover_published`] after the publish
    /// lands, so a successor that dies mid-takeover is re-covered by
    /// its own successor.
    pub fn claim_takeover(&self, me: usize, dead: usize, epoch: u64) -> bool {
        if self.policy != FailurePolicy::Takeover {
            return false;
        }
        let st = self.state.lock().unwrap();
        let slot = &st[dead];
        !slot.alive && slot.successor == Some(me) && slot.takeover_published < epoch
    }

    /// Record a successful on-behalf gradient publish.
    pub fn note_takeover_published(&self, dead: usize, epoch: u64) {
        let mut st = self.state.lock().unwrap();
        if epoch > st[dead].takeover_published {
            st[dead].takeover_published = epoch;
        }
        self.takeover_epochs.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dead-peer gradient skipped under the `drop` policy.
    pub fn note_dropped_grad(&self) {
        self.dropped_grads.fetch_add(1, Ordering::Relaxed);
    }

    /// Heartbeats published.
    pub fn heartbeats(&self) -> u64 {
        self.beats.load(Ordering::Relaxed)
    }

    /// Peers declared dead.
    pub fn deaths(&self) -> u64 {
        self.deaths.load(Ordering::Relaxed)
    }

    /// Barrier arrivals proxied on behalf of dead peers.
    pub fn barrier_proxies(&self) -> u64 {
        self.barrier_proxies.load(Ordering::Relaxed)
    }

    /// Dead-peer epochs recomputed and published by successors.
    pub fn takeover_epochs(&self) -> u64 {
        self.takeover_epochs.load(Ordering::Relaxed)
    }

    /// Dead-peer gradients skipped under the `drop` policy.
    pub fn dropped_grads(&self) -> u64 {
        self.dropped_grads.load(Ordering::Relaxed)
    }

    /// Joins actually admitted (revivals + growth).
    pub fn joins(&self) -> u64 {
        self.joins_admitted.load(Ordering::Relaxed)
    }
}

/// Split a partition in two for a growth join: the donor keeps the
/// first (never smaller by more than one element/ref) half, the joiner
/// takes the rest. Deterministic, so every replay splits identically.
fn split_partition(handle: PartitionHandle) -> Result<(PartitionHandle, PartitionHandle)> {
    match handle {
        PartitionHandle::Refs(mut refs) => {
            if refs.len() < 2 {
                return Err(Error::Runtime(format!(
                    "cannot split a {}-ref partition for a growth join",
                    refs.len()
                )));
            }
            let give = refs.split_off(refs.len() - refs.len() / 2);
            Ok((PartitionHandle::Refs(refs), PartitionHandle::Refs(give)))
        }
        PartitionHandle::Data(d) => {
            let mut parts = d.partition(2)?;
            let give = parts.pop().expect("partition(2) yields two");
            let keep = parts.pop().expect("partition(2) yields two");
            Ok((
                PartitionHandle::Data(Box::new(keep)),
                PartitionHandle::Data(Box::new(give)),
            ))
        }
    }
}

/// Guard for a peer's heartbeat thread; dropping stops and joins it.
pub struct HeartbeatPump {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HeartbeatPump {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(peers: usize, policy: FailurePolicy) -> (Arc<Broker>, Arc<Membership>) {
        let broker = Arc::new(Broker::default());
        let m = Membership::new(
            broker.clone(),
            peers,
            policy,
            Duration::from_millis(5),
            Duration::from_millis(30),
            true,
        )
        .unwrap();
        (broker, Arc::new(m))
    }

    #[test]
    fn stale_peer_is_reaped_under_drop_policy() {
        let (_, m) = table(3, FailurePolicy::Drop);
        m.beat(0);
        m.beat(2);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        m.beat(2);
        m.reap().unwrap();
        assert!(m.is_alive(0));
        assert!(!m.is_alive(1), "peer 1 never beat and should be dead");
        assert!(m.is_alive(2));
        assert_eq!(m.alive_count(), 2);
        assert_eq!(m.deaths(), 1);
    }

    #[test]
    fn stale_peer_aborts_under_abort_policy() {
        let (broker, m) = table(2, FailurePolicy::Abort);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        let err = m.reap().unwrap_err();
        assert!(err.to_string().contains("peer 1"), "{err}");
        assert!(broker.is_aborted());
    }

    #[test]
    fn unarmed_table_never_reaps() {
        let broker = Arc::new(Broker::default());
        let m = Membership::new(
            broker.clone(),
            2,
            FailurePolicy::Abort,
            Duration::from_millis(5),
            Duration::from_millis(10),
            false,
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        m.reap().unwrap();
        assert_eq!(m.alive_count(), 2);
        assert_eq!(m.heartbeats(), 0);
        // unarmed tables declare no heartbeat queues either
        assert!(broker.get(&Broker::heartbeat_queue(0)).is_err());
    }

    #[test]
    fn done_peers_are_not_reaped() {
        let (_, m) = table(2, FailurePolicy::Drop);
        m.mark_done(1);
        std::thread::sleep(Duration::from_millis(40));
        m.beat(0);
        m.reap().unwrap();
        assert!(m.is_alive(1), "a finished peer is not a dead peer");
    }

    #[test]
    fn successor_assignment_wraps_and_reroutes() {
        let (_, m) = table(4, FailurePolicy::Takeover);
        assert!(m.declare_dead(3, "killed"));
        // takeover claim: only the successor (rank 0, wrapping) wins
        assert!(m.claim_takeover(0, 3, 1));
        assert!(!m.claim_takeover(1, 3, 1));
        // a published epoch cannot be claimed again
        m.note_takeover_published(3, 1);
        assert!(!m.claim_takeover(0, 3, 1));
        assert!(m.claim_takeover(0, 3, 2));
        // the successor dying reroutes the dead peer's coverage
        assert!(m.declare_dead(0, "killed too"));
        assert!(m.claim_takeover(1, 3, 2));
        assert!(!m.claim_takeover(2, 3, 2));
        // and the double-declare is refused
        assert!(!m.declare_dead(3, "again"));
        assert_eq!(m.deaths(), 2);
    }

    #[test]
    fn leader_falls_over_to_smallest_alive_rank() {
        let (_, m) = table(3, FailurePolicy::Takeover);
        assert_eq!(m.leader(), 0);
        m.declare_dead(0, "killed");
        assert_eq!(m.leader(), 1);
        m.declare_dead(1, "killed");
        assert_eq!(m.leader(), 2);
    }

    #[test]
    fn barrier_backfill_proxies_each_missing_epoch_once() {
        let (broker, m) = table(2, FailurePolicy::Takeover);
        let barrier = EpochBarrier::new(&broker, 2).unwrap();
        // peer 1 really arrived for epoch 1, then died
        barrier.arrive(1, 1).unwrap();
        m.note_barrier_arrival(1, 1);
        m.declare_dead(1, "killed");
        // survivor arrives for epochs 1..=3 and back-fills
        for e in 1..=3u64 {
            barrier.arrive(0, e).unwrap();
            m.note_barrier_arrival(0, e);
            m.fill_barrier(&barrier, e).unwrap();
            assert!(
                barrier.wait_timeout(e, Duration::from_millis(100)).unwrap(),
                "barrier {e} should fill via proxies"
            );
        }
        // epochs 2 and 3 proxied; epoch 1 was a real arrival
        assert_eq!(m.barrier_proxies(), 2);
        // re-filling claims nothing new
        m.fill_barrier(&barrier, 3).unwrap();
        assert_eq!(m.barrier_proxies(), 2);
    }

    #[test]
    fn pump_beats_until_dropped() {
        let (_, m) = table(1, FailurePolicy::Drop);
        let pump = m.clone().start_pump(0);
        std::thread::sleep(Duration::from_millis(25));
        drop(pump);
        let beats = m.heartbeats();
        assert!(beats >= 2, "expected a few beats, got {beats}");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(m.heartbeats(), beats, "pump must stop after drop");
    }

    #[test]
    fn partition_registry_roundtrips() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        assert!(m.partition_of(1).is_none());
        m.register_partition(1, PartitionHandle::Refs(Vec::new()));
        assert!(matches!(m.partition_of(1), Some(PartitionHandle::Refs(_))));
    }

    fn refs(n: usize) -> PartitionHandle {
        PartitionHandle::Refs(
            (0..n)
                .map(|i| ObjectRef {
                    bucket: "b".into(),
                    key: format!("k{i}"),
                    size: 1,
                })
                .collect(),
        )
    }

    #[test]
    fn revival_admission_rearms_dead_slot_and_claims_catch_up() {
        let (_, m) = table(3, FailurePolicy::Takeover);
        m.set_join_schedule(&[(1, 3)]).unwrap();
        m.register_partition(1, refs(2));
        m.note_barrier_arrival(1, 1);
        m.declare_dead(1, "killed");
        assert!(m.claim_takeover(2, 1, 2));
        assert_eq!(m.pending_joins_at(3), vec![(1, 3)]);
        let adm = m.admit_join(1, 3).unwrap().expect("revival admitted");
        assert_eq!(adm.kind, JoinKind::Revival);
        assert_eq!(adm.start_epoch, 3);
        // peer 1 really arrived for epoch 1; epoch 2 is still owed
        assert_eq!(adm.catch_up, vec![2]);
        assert!(m.is_alive(1));
        assert_eq!(m.joins(), 1);
        // the revived rank computes for itself again
        assert!(!m.claim_takeover(2, 1, 3));
        // its orphaned partition is still registered for it to absorb
        assert!(matches!(m.partition_of(1), Some(PartitionHandle::Refs(v)) if v.len() == 2));
        assert!(m.pending_joins_at(3).is_empty());
        // barrier width never changes for a revival
        assert!(m.growth_epochs().is_empty());
        assert_eq!(m.width_at(3), 3);
    }

    #[test]
    fn revival_is_declined_when_the_rank_never_died() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        m.set_join_schedule(&[(1, 2)]).unwrap();
        assert!(m.admit_join(1, 2).unwrap().is_none());
        assert_eq!(m.joins(), 0);
        assert!(m.pending_joins_at(2).is_empty());
        // double-admission is an error, not a second flip
        assert!(m.admit_join(1, 2).is_err());
    }

    #[test]
    fn growth_admission_splits_the_largest_live_partition() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        m.set_join_schedule(&[(2, 2)]).unwrap();
        m.register_partition(0, refs(4));
        m.register_partition(1, refs(2));
        assert_eq!(m.width_at(1), 2);
        assert_eq!(m.width_at(2), 3);
        assert_eq!(m.growth_epochs(), vec![2]);
        assert_eq!(m.max_width(), 3);
        // pending slot is neither alive nor dead
        assert_eq!(m.alive_count(), 2);
        assert!(m.dead_peers().is_empty());
        let adm = m.admit_join(2, 2).unwrap().expect("growth admitted");
        assert_eq!(adm.kind, JoinKind::Growth);
        assert!(adm.catch_up.is_empty());
        assert_eq!(m.alive_count(), 3);
        assert_eq!(m.joins(), 1);
        // rank 0 (4 refs) was the donor: keeps 2, sheds a directive
        assert!(matches!(m.partition_of(2), Some(PartitionHandle::Refs(v)) if v.len() == 2));
        assert!(matches!(m.partition_of(0), Some(PartitionHandle::Refs(v)) if v.len() == 2));
        let shed = m.take_shed(0, 2).expect("donor directive parked");
        assert!(matches!(shed, PartitionHandle::Refs(v) if v.len() == 2));
        assert!(m.take_shed(0, 9).is_none(), "directive is consumed once");
        assert!(m.take_shed(1, 9).is_none());
    }

    #[test]
    fn growth_schedule_requires_contiguous_ranks() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        assert!(m.set_join_schedule(&[(4, 2)]).is_err());
        // contiguous ranks in epoch order are accepted
        m.set_join_schedule(&[(2, 2), (3, 3)]).unwrap();
        assert_eq!(m.width_at(3), 4);
    }

    #[test]
    fn grown_rank_can_be_a_takeover_successor() {
        let (_, m) = table(2, FailurePolicy::Takeover);
        m.set_join_schedule(&[(2, 2)]).unwrap();
        m.register_partition(0, refs(4));
        m.admit_join(2, 2).unwrap().expect("growth admitted");
        // rank 1 dies after the join: rank 2 is next alive after it
        m.declare_dead(1, "killed");
        assert!(m.claim_takeover(2, 1, 2));
        assert!(!m.claim_takeover(0, 1, 2));
    }
}
