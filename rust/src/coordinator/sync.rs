//! The RabbitMQ-based epoch barrier (§III-B.6).
//!
//! "Each peer sends a message to a designated synchronization queue …
//! once the size of this synchronization queue matches the total number
//! of peers, all peers have completed the current epoch."
//!
//! The barrier is cumulative: after epoch `e`, the queue has seen
//! `e * peers` publishes (the queue is never drained mid-run; version
//! counts are monotone, so late peers still observe past epochs).

use std::sync::Arc;
use std::time::Duration;

use crate::broker::{Broker, Message, Queue, QueueMode};
use crate::error::Result;
use crate::util::Bytes;

pub struct EpochBarrier {
    queue: Arc<Queue>,
    peers: usize,
    /// Epochs at which a *growth* join widens the barrier (one entry
    /// per admitted rank; revivals reuse an original slot and don't
    /// appear here). Sorted ascending. A join at epoch `E` means the
    /// new peer arrives for every epoch `>= E`.
    growth_epochs: Vec<u64>,
}

impl EpochBarrier {
    pub fn new(broker: &Broker, peers: usize) -> Result<Self> {
        Self::with_growth(broker, peers, Vec::new())
    }

    /// A barrier whose width grows at scheduled epochs: `growth_epochs`
    /// holds the first epoch each *new* rank participates in (one entry
    /// per growth join; duplicates allowed when two ranks join at the
    /// same boundary). The schedule is fixed up front — joins are
    /// scripted by the fault plan, so every peer computes the same
    /// piecewise-cumulative expectation with no runtime coordination.
    pub fn with_growth(broker: &Broker, peers: usize, mut growth_epochs: Vec<u64>) -> Result<Self> {
        let queue = broker.declare(&Broker::sync_queue(), QueueMode::Fifo)?;
        growth_epochs.sort_unstable();
        Ok(Self { queue, peers, growth_epochs })
    }

    /// Signal that `rank` finished epoch `epoch` (1-based), then block
    /// until all peers have. Errors with [`crate::error::Error::Aborted`]
    /// if the run aborts while parked — a failed peer must not leave the
    /// rest at the barrier forever.
    pub fn arrive_and_wait(&self, rank: usize, epoch: u64) -> Result<()> {
        self.arrive(rank, epoch)?;
        self.queue.await_version(self.expected(epoch))
    }

    /// As above but with a timeout; `Ok(false)` if the barrier never
    /// filled, an abort error if the run aborted first.
    pub fn arrive_and_wait_timeout(
        &self,
        rank: usize,
        epoch: u64,
        timeout: Duration,
    ) -> Result<bool> {
        self.arrive(rank, epoch)?;
        self.wait_timeout(epoch, timeout)
    }

    /// Publish `rank`'s arrival for `epoch` without waiting. A waiter
    /// that re-tries its timed wait must arrive exactly once — the
    /// barrier predicate counts publishes.
    pub fn arrive(&self, rank: usize, epoch: u64) -> Result<()> {
        self.queue
            .publish(Message::new(rank, epoch, Bytes::from_static(b"done")))
    }

    /// Publish an arrival *on behalf of* a dead peer so the cumulative
    /// predicate still fills. The membership table claims each
    /// (peer, epoch) proxy exactly once before calling this.
    pub fn proxy_arrive(&self, rank: usize, epoch: u64) -> Result<()> {
        self.queue
            .publish(Message::new(rank, epoch, Bytes::from_static(b"proxy")))
    }

    /// Wait (without arriving) until epoch `epoch`'s barrier fills;
    /// `Ok(false)` on timeout, an abort error if the run aborted first.
    pub fn wait_timeout(&self, epoch: u64, timeout: Duration) -> Result<bool> {
        self.queue.await_version_timeout(self.expected(epoch), timeout)
    }

    /// Cumulative arrivals the barrier expects after epoch `epoch`.
    ///
    /// Piecewise with growth joins: the base width contributes
    /// `peers * epoch` and a rank joining at epoch `E` contributes one
    /// arrival per epoch in `E..=epoch`, i.e. `max(0, epoch - E + 1)`.
    pub fn expected(&self, epoch: u64) -> u64 {
        let grown: u64 = self
            .growth_epochs
            .iter()
            .map(|&e| (epoch + 1).saturating_sub(e))
            .sum();
        epoch * self.peers as u64 + grown
    }

    /// Barrier width (number of expected arrivals) *at* `epoch`.
    pub fn width_at(&self, epoch: u64) -> usize {
        self.peers + self.growth_epochs.iter().filter(|&&e| e <= epoch).count()
    }

    /// Completed arrivals so far (all epochs).
    pub fn arrivals(&self) -> u64 {
        self.queue.version()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn barrier_releases_all_threads_together() {
        let broker = Arc::new(Broker::default());
        let barrier = Arc::new(EpochBarrier::new(&broker, 3).unwrap());
        let progressed = Arc::new(AtomicUsize::new(0));

        let handles: Vec<_> = (0..3)
            .map(|rank| {
                let b = barrier.clone();
                let p = progressed.clone();
                std::thread::spawn(move || {
                    // stagger arrivals
                    std::thread::sleep(Duration::from_millis(5 * rank as u64));
                    b.arrive_and_wait(rank, 1).unwrap();
                    p.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(progressed.load(Ordering::SeqCst), 3);
        assert_eq!(barrier.arrivals(), 3);
    }

    #[test]
    fn barrier_times_out_with_missing_peer() {
        let broker = Arc::new(Broker::default());
        let barrier = EpochBarrier::new(&broker, 2).unwrap();
        let ok = barrier
            .arrive_and_wait_timeout(0, 1, Duration::from_millis(30))
            .unwrap();
        assert!(!ok, "barrier should time out when peer 1 never arrives");
    }

    #[test]
    fn growth_expectation_is_piecewise_cumulative() {
        let broker = Arc::new(Broker::default());
        // 2 base peers; one rank joins at epoch 2, another at epoch 3.
        let barrier = EpochBarrier::with_growth(&broker, 2, vec![3, 2]).unwrap();
        assert_eq!(barrier.expected(1), 2); // base only
        assert_eq!(barrier.expected(2), 5); // 4 base + 1 (joiner@2)
        assert_eq!(barrier.expected(3), 9); // 6 base + 2 + 1
        assert_eq!(barrier.expected(4), 13); // 8 base + 3 + 2
        assert_eq!(barrier.width_at(1), 2);
        assert_eq!(barrier.width_at(2), 3);
        assert_eq!(barrier.width_at(3), 4);
    }

    #[test]
    fn grown_barrier_fills_with_joiner_arrivals() {
        let broker = Arc::new(Broker::default());
        let barrier = Arc::new(EpochBarrier::with_growth(&broker, 2, vec![2]).unwrap());
        // Epoch 1: only the 2 base peers.
        let b0 = barrier.clone();
        let t = std::thread::spawn(move || b0.arrive_and_wait(0, 1).unwrap());
        barrier.arrive_and_wait(1, 1).unwrap();
        t.join().unwrap();
        // Epoch 2: base peers park until rank 2 arrives too.
        let ok = barrier
            .arrive_and_wait_timeout(0, 2, Duration::from_millis(20))
            .unwrap();
        assert!(!ok, "barrier must now expect the epoch-2 joiner");
        barrier.arrive(1, 2).unwrap();
        barrier.arrive(2, 2).unwrap();
        assert!(barrier.wait_timeout(2, Duration::from_millis(200)).unwrap());
        assert_eq!(barrier.arrivals(), 5);
    }

    #[test]
    fn cumulative_epochs() {
        let broker = Arc::new(Broker::default());
        let barrier = Arc::new(EpochBarrier::new(&broker, 2).unwrap());
        for epoch in 1..=3u64 {
            let b0 = barrier.clone();
            let t = std::thread::spawn(move || b0.arrive_and_wait(0, epoch).unwrap());
            barrier.arrive_and_wait(1, epoch).unwrap();
            t.join().unwrap();
        }
        assert_eq!(barrier.arrivals(), 6);
    }
}
