//! Per-stage resource metrics (the paper's §III-B.8: tracemalloc /
//! psutil / perf_counter equivalents).
//!
//! Table I decomposes an epoch into five stages; [`Stage`] mirrors them.
//! [`StageTimer`] measures wall time plus CPU utilisation (from
//! `/proc/self/stat`, like psutil) and RSS (from `/proc/self/statm`,
//! like tracemalloc's high-water proxy) around a stage.

use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::time::{Duration, Instant};

use std::sync::Mutex;

/// The five training stages of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    ComputeGradients,
    SendGradients,
    ReceiveGradients,
    ModelUpdate,
    ConvergenceDetection,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::ComputeGradients,
        Stage::SendGradients,
        Stage::ReceiveGradients,
        Stage::ModelUpdate,
        Stage::ConvergenceDetection,
    ];
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Stage::ComputeGradients => "compute_gradients",
            Stage::SendGradients => "send_gradients",
            Stage::ReceiveGradients => "receive_gradients",
            Stage::ModelUpdate => "model_update",
            Stage::ConvergenceDetection => "convergence_detection",
        };
        f.write_str(s)
    }
}

/// One stage sample.
#[derive(Debug, Clone, Copy)]
pub struct StageSample {
    pub wall: Duration,
    /// CPU utilisation percent over the stage (can exceed 100 on
    /// multi-core, matching psutil semantics).
    pub cpu_pct: f64,
    /// Resident set size at stage end, bytes.
    pub rss_bytes: u64,
}

/// Aggregated stats for a stage.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageSummary {
    pub count: u64,
    pub total_wall: Duration,
    pub mean_cpu_pct: f64,
    pub peak_rss_bytes: u64,
}

impl StageSummary {
    pub fn mean_wall(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total_wall / self.count as u32
        }
    }
}

/// Process CPU time (user+sys) and RSS, read from /proc (Linux).
fn proc_cpu_rss() -> (Duration, u64) {
    let cpu = std::fs::read_to_string("/proc/self/stat")
        .ok()
        .and_then(|s| {
            // utime+stime are fields 14 and 15 (1-based), after comm which
            // may contain spaces — split after the closing paren.
            let rest = s.rsplit_once(')')?.1;
            let f: Vec<&str> = rest.split_whitespace().collect();
            let utime: u64 = f.get(11)?.parse().ok()?;
            let stime: u64 = f.get(12)?.parse().ok()?;
            let tck = 100.0; // USER_HZ on linux
            Some(Duration::from_secs_f64((utime + stime) as f64 / tck))
        })
        .unwrap_or(Duration::ZERO);
    let rss = std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| s.split_whitespace().nth(1)?.parse::<u64>().ok())
        .map(|pages| pages * 4096)
        .unwrap_or(0);
    (cpu, rss)
}

/// RAII-ish stage timer.
pub struct StageTimer {
    stage: Stage,
    t0: Instant,
    cpu0: Duration,
}

impl StageTimer {
    pub fn start(stage: Stage) -> Self {
        let (cpu0, _) = proc_cpu_rss();
        Self { stage, t0: Instant::now(), cpu0 }
    }

    /// Finish and record into `registry`.
    pub fn stop(self, registry: &MetricsRegistry) -> StageSample {
        let wall = self.t0.elapsed();
        let (cpu1, rss) = proc_cpu_rss();
        let cpu_pct = if wall.as_secs_f64() > 0.0 {
            (cpu1.saturating_sub(self.cpu0)).as_secs_f64() / wall.as_secs_f64() * 100.0
        } else {
            0.0
        };
        let sample = StageSample { wall, cpu_pct, rss_bytes: rss };
        registry.record(self.stage, sample);
        sample
    }
}

/// Thread-safe per-stage aggregation, plus named utilization counters
/// (scheduler queue depth, executor busy threads, per-peer branches
/// served, `wire.*` bytes-on-wire accounting) so fairness and
/// data-plane regressions are observable in the run report.
#[derive(Default)]
pub struct MetricsRegistry {
    stages: Mutex<HashMap<Stage, StageSummary>>,
    counters: Mutex<BTreeMap<String, u64>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, stage: Stage, s: StageSample) {
        let mut map = self.stages.lock().unwrap();
        let e = map.entry(stage).or_default();
        let n = e.count as f64;
        e.mean_cpu_pct = (e.mean_cpu_pct * n + s.cpu_pct) / (n + 1.0);
        e.count += 1;
        e.total_wall += s.wall;
        e.peak_rss_bytes = e.peak_rss_bytes.max(s.rss_bytes);
    }

    /// Record a wall-time-only sample (modeled durations).
    pub fn record_wall(&self, stage: Stage, wall: Duration) {
        self.record(stage, StageSample { wall, cpu_pct: 0.0, rss_bytes: 0 });
    }

    pub fn summary(&self, stage: Stage) -> StageSummary {
        self.stages.lock().unwrap().get(&stage).copied().unwrap_or_default()
    }

    pub fn all(&self) -> Vec<(Stage, StageSummary)> {
        Stage::ALL
            .iter()
            .map(|&s| (s, self.summary(s)))
            .collect()
    }

    /// Set a named utilization counter (gauge semantics: last write
    /// wins).
    pub fn set_counter(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    /// Add to a named counter (creates it at zero).
    pub fn add_counter(&self, name: &str, delta: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.lock().unwrap().get(name).copied()
    }

    /// All named counters, sorted by name.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    }

    /// The Table-I question: which stage dominates wall time?
    pub fn dominant_stage(&self) -> Option<Stage> {
        self.all()
            .into_iter()
            .filter(|(_, s)| s.count > 0)
            .max_by(|a, b| a.1.total_wall.cmp(&b.1.total_wall))
            .map(|(s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_records_wall_time() {
        let reg = MetricsRegistry::new();
        let t = StageTimer::start(Stage::ComputeGradients);
        std::thread::sleep(Duration::from_millis(15));
        let s = t.stop(&reg);
        assert!(s.wall >= Duration::from_millis(15));
        let sum = reg.summary(Stage::ComputeGradients);
        assert_eq!(sum.count, 1);
        assert!(sum.total_wall >= Duration::from_millis(15));
    }

    #[test]
    fn proc_sampler_reads_something() {
        let (cpu, rss) = proc_cpu_rss();
        // this process has burned some CPU and holds some memory
        assert!(rss > 0);
        let _ = cpu;
    }

    #[test]
    fn registry_aggregates_means() {
        let reg = MetricsRegistry::new();
        for i in 1..=3u64 {
            reg.record(
                Stage::SendGradients,
                StageSample {
                    wall: Duration::from_millis(10 * i),
                    cpu_pct: 50.0,
                    rss_bytes: 1000 * i,
                },
            );
        }
        let s = reg.summary(Stage::SendGradients);
        assert_eq!(s.count, 3);
        assert_eq!(s.total_wall, Duration::from_millis(60));
        assert_eq!(s.mean_wall(), Duration::from_millis(20));
        assert!((s.mean_cpu_pct - 50.0).abs() < 1e-9);
        assert_eq!(s.peak_rss_bytes, 3000);
    }

    #[test]
    fn dominant_stage_is_largest_total() {
        let reg = MetricsRegistry::new();
        reg.record_wall(Stage::ComputeGradients, Duration::from_secs(10));
        reg.record_wall(Stage::SendGradients, Duration::from_secs(1));
        assert_eq!(reg.dominant_stage(), Some(Stage::ComputeGradients));
    }

    #[test]
    fn empty_registry_has_no_dominant() {
        assert_eq!(MetricsRegistry::new().dominant_stage(), None);
    }

    #[test]
    fn counters_set_add_list() {
        let reg = MetricsRegistry::new();
        assert_eq!(reg.counter("sched.peak_queue_depth"), None);
        reg.set_counter("sched.peak_queue_depth", 7);
        reg.set_counter("sched.peak_queue_depth", 5); // gauge: last wins
        reg.add_counter("sched.peer0.served", 3);
        reg.add_counter("sched.peer0.served", 2);
        assert_eq!(reg.counter("sched.peak_queue_depth"), Some(5));
        assert_eq!(reg.counter("sched.peer0.served"), Some(5));
        let all = reg.counters();
        assert_eq!(all.len(), 2);
        // sorted by name
        assert_eq!(all[0].0, "sched.peak_queue_depth");
    }

    #[test]
    fn stage_display_names() {
        assert_eq!(Stage::ComputeGradients.to_string(), "compute_gradients");
        assert_eq!(Stage::ALL.len(), 5);
    }
}
