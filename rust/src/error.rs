//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem.
#[derive(Error, Debug)]
pub enum Error {
    #[error("broker: {0}")]
    Broker(String),

    #[error("run aborted: {0}")]
    Aborted(String),

    #[error("message of {size} bytes exceeds queue cap of {cap} bytes")]
    MessageTooLarge { size: usize, cap: usize },

    #[error("object store: {0}")]
    Store(String),

    #[error("faas: {0}")]
    Faas(String),

    #[error("lambda function timed out after {elapsed_ms} ms (limit {limit_ms} ms)")]
    FaasTimeout { elapsed_ms: u64, limit_ms: u64 },

    #[error("codec: {0}")]
    Codec(String),

    #[error("runtime: {0}")]
    Runtime(String),

    #[error("config: {0}")]
    Config(String),

    #[error("data: {0}")]
    Data(String),

    #[error("xla: {0}")]
    Xla(String),

    #[error("json: {0}")]
    Json(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
