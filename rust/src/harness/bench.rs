//! Micro-benchmark harness (in-tree criterion stand-in — the build is
//! offline). Used by every target under `rust/benches/`.
//!
//! Methodology: warmup iterations, then timed samples; reports mean,
//! median, p95 and throughput. Deliberately simple and deterministic —
//! no outlier rejection, which keeps before/after comparisons in
//! EXPERIMENTS.md §Perf honest.

use std::time::{Duration, Instant};

/// One benchmark's statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/second given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A benchmark group with shared sample counts.
pub struct Bench {
    group: String,
    warmup: usize,
    samples: usize,
    results: Vec<BenchStats>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // keep sample counts moderate: several benches run real PJRT
        Self { group: group.to_string(), warmup: 3, samples: 12, results: Vec::new() }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup = warmup;
        self.samples = samples;
        self
    }

    /// Time `f` (which must do one full unit of work per call).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: format!("{}/{}", self.group, name),
            samples: self.samples,
            mean_ns: times.iter().sum::<f64>() / times.len() as f64,
            median_ns: times[times.len() / 2],
            p95_ns: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min_ns: times[0],
        };
        println!(
            "{:<52} mean {:>10}  median {:>10}  p95 {:>10}",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p95_ns)
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Like `bench` but annotates throughput for `items` per iteration.
    pub fn bench_throughput<R>(
        &mut self,
        name: &str,
        items: f64,
        unit: &str,
        f: impl FnMut() -> R,
    ) -> &BenchStats {
        let before = self.results.len();
        self.bench(name, f);
        let stats = &self.results[before];
        println!(
            "{:<52}   -> {:.2} {unit}/s",
            "",
            stats.throughput(items)
        );
        &self.results[before]
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Print the standard bench header.
pub fn header(group: &str, note: &str) {
    println!("\n=== bench: {group} ===");
    if !note.is_empty() {
        println!("{note}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut b = Bench::new("t").with_samples(1, 5);
        let s = b.bench("noop", || 1 + 1).clone();
        assert_eq!(s.samples, 5);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns + 1.0);
    }

    #[test]
    fn throughput_math() {
        let s = BenchStats {
            name: "x".into(),
            samples: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p95_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((s.throughput(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
