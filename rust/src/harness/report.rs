//! Report plumbing shared by the experiment drivers: aligned console
//! tables + JSON export under `results/`.

use std::path::Path;

use crate::error::Result;
use crate::util::Json;

/// A printable, exportable table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join("  "));
        }
        for n in &self.notes {
            println!("note: {n}");
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("title", self.title.as_str());
        j.set(
            "columns",
            Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        j.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        j.set(
            "notes",
            Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        j
    }

    /// Write `results/<name>.json` (directory created on demand).
    pub fn save(&self, out_dir: &str, name: &str) -> Result<()> {
        std::fs::create_dir_all(out_dir)?;
        let path = Path::new(out_dir).join(format!("{name}.json"));
        std::fs::write(&path, self.to_json().to_string())?;
        println!("saved {}", path.display());
        Ok(())
    }
}

/// `12.34 s` / `567 ms` formatting for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.1} s")
    } else {
        format!("{:.0} ms", s * 1e3)
    }
}

pub fn fmt_usd(v: f64) -> String {
    format!("${v:.5}")
}

pub fn fmt_pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip_and_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("n");
        let j = t.to_json();
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), 1);
        t.print(); // must not panic
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(258.0), "258 s");
        assert_eq!(fmt_secs(41.2), "41.2 s");
        assert_eq!(fmt_secs(0.084), "84 ms");
        assert_eq!(fmt_usd(0.03567), "$0.03567");
        assert_eq!(fmt_pct(0.9734), "97.34%");
    }
}
