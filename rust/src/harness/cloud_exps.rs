//! Cloud-scale experiment drivers (perfmodel-calibrated): fig 3,
//! Tables II/III, fig 4, fig 5 and the headline numbers.
//!
//! These reproduce the paper's AWS-scale measurements through the
//! calibrated time model (DESIGN.md substitution table) while running
//! the *real* orchestration code: the Step-Functions Map state executes
//! with modeled durations and real billing, the QSGD codec really
//! encodes VGG-scale gradients for fig 5.

use std::sync::Arc;
use std::time::Instant;

use super::report::{fmt_pct, fmt_secs, fmt_usd, Table};
use crate::cloud;
use crate::compress::{Codec, QsgdCodec};
use crate::costs::{instance_cost_per_peer, serverless_cost_per_peer, CostInputs};
use crate::error::Result;
use crate::faas::{FaasPlatform, FunctionSpec, Handler, StateMachine};
use crate::perfmodel::{
    self, paper_model, PaperModel, LAMBDA_COLD_START,
};
use crate::util::{Bytes, Rng};

/// MNIST-scale training set the paper partitions (60 000 samples).
pub const DATASET_SIZE: usize = 60_000;
/// AWS default account-level Lambda concurrency.
pub const LAMBDA_CONCURRENCY: usize = 1000;

/// One fig-3 cell: serverless vs instance partition-pass time.
#[derive(Debug, Clone, Copy)]
pub struct Fig3Cell {
    pub peers: usize,
    pub batch: usize,
    pub nbatches: usize,
    pub instance_s: f64,
    pub serverless_s: f64,
    pub improvement: f64,
}

/// Compute one fig-3 cell, running the *real* state machine with
/// modeled durations (so orchestration, retry and billing code paths
/// are exercised, not just arithmetic).
pub fn fig3_cell(model: PaperModel, peers: usize, batch: usize) -> Result<Fig3Cell> {
    let spec = paper_model(model);
    let inst = cloud::instance(spec.paper_instance)?;
    let partition = DATASET_SIZE / peers;
    let nbatches = (partition / batch).max(1);

    let instance_s =
        perfmodel::instance_partition_time(spec, inst, batch, nbatches).as_secs_f64();

    // serverless: dynamic Map state over nbatches modeled lambdas
    let mem = perfmodel::lambda_memory_for(spec, batch);
    let lam = perfmodel::lambda_batch_time(spec, mem, batch);
    let platform = Arc::new(FaasPlatform::new(LAMBDA_COLD_START));
    let noop: Handler = Arc::new(|b: &Bytes| Ok(b.clone()));
    platform.register(FunctionSpec::new("grad", mem, noop))?;
    let items: Vec<Bytes> = (0..nbatches).map(|_| Bytes::new()).collect();
    let modeled = vec![Some(lam); nbatches];
    let sm = StateMachine::parallel_batches("fig3", "grad", items, modeled, LAMBDA_CONCURRENCY);
    let report = sm.execute(&platform)?;
    let serverless_s = report.wall.as_secs_f64();

    Ok(Fig3Cell {
        peers,
        batch,
        nbatches,
        instance_s,
        serverless_s,
        improvement: 1.0 - serverless_s / instance_s,
    })
}

/// Fig 3: gradient-computation time with and without serverless, for
/// peers x batch-size grid (VGG-11/MNIST as in the paper).
pub fn fig3() -> Result<Table> {
    let mut t = Table::new(
        "Fig 3 — gradient computation time per epoch partition: serverless vs instance (VGG-11, MNIST)",
        &["peers", "batch", "nbatches", "serverless", "instance", "improvement"],
    );
    for &peers in &[4usize, 8, 12] {
        for &batch in &[64usize, 128, 512, 1024] {
            let c = fig3_cell(PaperModel::Vgg11, peers, batch)?;
            t.row(vec![
                c.peers.to_string(),
                c.batch.to_string(),
                c.nbatches.to_string(),
                fmt_secs(c.serverless_s),
                fmt_secs(c.instance_s),
                fmt_pct(c.improvement),
            ]);
        }
    }
    t.note("paper: 97.34% at 4 peers/batch 64; improvement shrinks as batch grows");
    t.note("serverless wall = real Step-Functions Map execution with perfmodel durations");
    Ok(t)
}

/// Table II: time + cost of serverless gradient computation (4 peers).
pub fn table2() -> Result<Table> {
    let spec = paper_model(PaperModel::Vgg11);
    let host = cloud::instance("t2.small")?;
    let mut t = Table::new(
        "Table II — serverless compute gradients: time & cost (VGG-11, MNIST, 4 peers, t2.small hosts)",
        &["batch", "nbatches", "lambda mem", "time", "lambda $/s", "ec2 $/s", "cost/peer", "paper cost"],
    );
    let paper_cost = [(1024usize, 0.03567f64), (512, 0.03069), (128, 0.03451), (64, 0.05435)];
    for &(batch, paper) in &paper_cost {
        let nbatches = (DATASET_SIZE / 4 / batch).max(1);
        let mem = perfmodel::lambda_memory_for(spec, batch);
        let time = perfmodel::lambda_batch_time(spec, mem, batch).as_secs_f64();
        let rep = serverless_cost_per_peer(
            host,
            CostInputs { compute_time_s: time, num_batches: nbatches, lambda_memory_mb: mem },
        );
        t.row(vec![
            batch.to_string(),
            nbatches.to_string(),
            format!("{mem} MB"),
            fmt_secs(time),
            format!("{:.7}", rep.lambda_rate_per_s),
            format!("{:.8}", rep.ec2_rate_per_s),
            fmt_usd(rep.cost_per_peer_usd),
            fmt_usd(paper),
        ]);
    }
    t.note("cost per paper Eq.(1); time from the calibrated lambda model");
    Ok(t)
}

/// Table III: time + cost of instance-based gradient computation.
pub fn table3() -> Result<Table> {
    let spec = paper_model(PaperModel::Vgg11);
    let inst = cloud::instance("t2.large")?;
    let mut t = Table::new(
        "Table III — instance-based compute gradients: time & cost (VGG-11, MNIST, 4 peers, t2.large)",
        &["batch", "nbatches", "time", "ec2 $/s", "cost/peer", "paper cost"],
    );
    let paper_cost = [(1024usize, 0.00665f64), (512, 0.00717), (128, 0.00851), (64, 0.01017)];
    for &(batch, paper) in &paper_cost {
        let nbatches = (DATASET_SIZE / 4 / batch).max(1);
        let time = perfmodel::instance_partition_time(spec, inst, batch, nbatches).as_secs_f64();
        let rep = instance_cost_per_peer(inst, time);
        t.row(vec![
            batch.to_string(),
            nbatches.to_string(),
            fmt_secs(time),
            format!("{:.8}", rep.ec2_rate_per_s),
            fmt_usd(rep.cost_per_peer_usd),
            fmt_usd(paper),
        ]);
    }
    t.note("cost per paper Eq.(2)");
    Ok(t)
}

/// Fig 4: computation vs communication time as the peer count grows
/// (VGG-11 and MobileNetV3-Small, batch 1024).
pub fn fig4() -> Result<Table> {
    let mut t = Table::new(
        "Fig 4 — compute vs communication time per epoch over #peers (batch 1024)",
        &["model", "peers", "compute", "send", "recv", "comm total"],
    );
    for model in [PaperModel::Vgg11, PaperModel::MobilenetV3Small] {
        let spec = paper_model(model);
        let inst = cloud::instance(spec.paper_instance)?;
        for &peers in &[2usize, 4, 8, 12, 16] {
            let partition = DATASET_SIZE / peers;
            let nbatches = (partition / 1024).max(1);
            let compute =
                perfmodel::instance_partition_time(spec, inst, 1024, nbatches).as_secs_f64();
            let send = perfmodel::send_time(spec.gradient_bytes(), 1.0).as_secs_f64();
            let recv =
                perfmodel::recv_time(spec.gradient_bytes(), peers - 1, 1.0).as_secs_f64();
            t.row(vec![
                spec.name.to_string(),
                peers.to_string(),
                fmt_secs(compute),
                fmt_secs(send),
                fmt_secs(recv),
                fmt_secs(send + recv),
            ]);
        }
    }
    t.note("paper shape: compute shrinks with peers (smaller partition), comm grows with peers");
    t.note("VGG's comm growth dwarfs MobileNet's (531.6 MB vs 10 MB gradients)");
    Ok(t)
}

/// Fig 5: QSGD compression impact on send/receive time (VGG-11, MNIST,
/// 4 peers). The codec time is *measured* on a real VGG-sized gradient;
/// transfer time comes from the calibrated bandwidth model.
pub fn fig5() -> Result<Table> {
    let spec = paper_model(PaperModel::Vgg11);
    let n = spec.params as usize;
    let codec = QsgdCodec::new(16, 7);

    // measured on a real 132.9M-element gradient
    let mut rng = Rng::seed_from_u64(11);
    let v: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-1.0, 1.0)).collect();
    let t0 = Instant::now();
    let wire = codec.encode(&v)?;
    let enc = t0.elapsed();
    let t0 = Instant::now();
    let _ = codec.decode(&wire)?;
    let dec = t0.elapsed();
    let ratio = (n * 4) as f64 / wire.len() as f64;
    drop(v);

    let mut t = Table::new(
        "Fig 5 — compression impact on communication time (VGG-11, 4 peers, QSGD s=16)",
        &["batch", "send plain", "send qsgd", "recv plain", "recv qsgd", "speedup"],
    );
    for &batch in &[64usize, 128, 512, 1024] {
        let bytes = spec.gradient_bytes();
        let send_plain = perfmodel::send_time(bytes, 1.0);
        let recv_plain = perfmodel::recv_time(bytes, 3, 1.0);
        // compressed: transfer shrinks by the wire ratio, encode/decode
        // CPU time is added on the respective sides
        let send_q = perfmodel::send_time(bytes, ratio) + enc;
        let recv_q = perfmodel::recv_time(bytes, 3, ratio) + dec * 3;
        let speedup = (send_plain + recv_plain).as_secs_f64()
            / (send_q + recv_q).as_secs_f64();
        t.row(vec![
            batch.to_string(),
            fmt_secs(send_plain.as_secs_f64()),
            fmt_secs(send_q.as_secs_f64()),
            fmt_secs(recv_plain.as_secs_f64()),
            fmt_secs(recv_q.as_secs_f64()),
            format!("{speedup:.2}x"),
        ]);
    }
    t.note(format!(
        "measured rust QSGD on {} params: encode {:?}, decode {:?}, wire ratio {:.2}x",
        n, enc, dec, ratio
    ));
    t.note("gradient size is batch-independent; the paper's per-batch variation is measurement noise");
    Ok(t)
}

/// The paper's two headline numbers, derived from the same machinery.
pub fn headline() -> Result<Table> {
    let c = fig3_cell(PaperModel::Vgg11, 4, 64)?;
    let spec = paper_model(PaperModel::Vgg11);
    let host = cloud::instance("t2.small")?;
    let inst = cloud::instance("t2.large")?;
    let nb = DATASET_SIZE / 4 / 1024;
    let mem = perfmodel::lambda_memory_for(spec, 1024);
    let lam_t = perfmodel::lambda_batch_time(spec, mem, 1024).as_secs_f64();
    let srv = serverless_cost_per_peer(
        host,
        CostInputs { compute_time_s: lam_t, num_batches: nb, lambda_memory_mb: mem },
    )
    .cost_per_peer_usd;
    let ins_t = perfmodel::instance_partition_time(spec, inst, 1024, nb).as_secs_f64();
    let ins = instance_cost_per_peer(inst, ins_t).cost_per_peer_usd;

    let mut t = Table::new(
        "Headline claims",
        &["claim", "paper", "reproduced"],
    );
    t.row(vec![
        "gradient-time improvement (4 peers, batch 64)".into(),
        "97.34%".into(),
        fmt_pct(c.improvement),
    ]);
    t.row(vec![
        "serverless/instance cost ratio (batch 1024)".into(),
        "5.34x".into(),
        format!("{:.2}x", srv / ins),
    ]);
    Ok(t)
}

/// Sanity helper for tests: the improvement monotone story.
pub fn improvement_at(peers: usize, batch: usize) -> Result<f64> {
    Ok(fig3_cell(PaperModel::Vgg11, peers, batch)?.improvement)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_headline_cell() {
        let c = fig3_cell(PaperModel::Vgg11, 4, 64).unwrap();
        assert!(c.improvement > 0.95, "improvement {}", c.improvement);
        assert_eq!(c.nbatches, 234); // 15000/64
    }

    #[test]
    fn fig3_improvement_decreases_with_larger_batches() {
        let small = improvement_at(4, 64).unwrap();
        let large = improvement_at(4, 1024).unwrap();
        assert!(small > large, "{small} vs {large}");
    }

    #[test]
    fn fig4_crossover_shape() {
        // VGG comm at 12 peers must exceed MobileNet comm at 12 peers by
        // a wide margin, and VGG compute must shrink with peers.
        let spec = paper_model(PaperModel::Vgg11);
        let inst = cloud::instance("t2.large").unwrap();
        let c4 = perfmodel::instance_partition_time(spec, inst, 1024, DATASET_SIZE / 4 / 1024);
        let c12 = perfmodel::instance_partition_time(spec, inst, 1024, DATASET_SIZE / 12 / 1024);
        assert!(c12 < c4);
        let comm_vgg = perfmodel::recv_time(spec.gradient_bytes(), 11, 1.0);
        let mb = paper_model(PaperModel::MobilenetV3Small);
        let comm_mb = perfmodel::recv_time(mb.gradient_bytes(), 11, 1.0);
        assert!(comm_vgg > comm_mb * 10);
    }

    #[test]
    fn tables_build() {
        // fig5 measures a 132.9M-element encode — skip here (bench
        // covers it); the cheap tables must all build.
        for t in [table2().unwrap(), table3().unwrap(), fig4().unwrap()] {
            assert!(!t.rows.is_empty());
        }
    }
}
