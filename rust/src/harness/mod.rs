//! Experiment harness: one driver per paper table/figure, plus the
//! in-tree micro-benchmark harness.
//!
//! | id       | paper artefact | driver |
//! |----------|----------------|--------|
//! | table1   | Table I        | [`real_exps::table1`] (real PJRT) |
//! | fig3     | Fig 3          | [`cloud_exps::fig3`] (modeled)    |
//! | table2   | Table II       | [`cloud_exps::table2`]            |
//! | table3   | Table III      | [`cloud_exps::table3`]            |
//! | fig4     | Fig 4          | [`cloud_exps::fig4`]              |
//! | fig5     | Fig 5          | [`cloud_exps::fig5`] (real codec) |
//! | fig6     | Fig 6          | [`real_exps::fig6`] (real PJRT)   |
//! | headline | abstract       | [`cloud_exps::headline`]          |

pub mod bench;
pub mod cloud_exps;
pub mod faults;
pub mod real_exps;
pub mod report;

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::Engine;

pub const ALL_EXPERIMENTS: &[&str] = &[
    "table1", "fig3", "table2", "table3", "fig4", "fig5", "fig6", "headline",
];

/// Run one experiment by id, print its table, save JSON to `out_dir`.
pub fn run(id: &str, quick: bool, out_dir: &str, engine: Option<Arc<Engine>>) -> Result<()> {
    let need_engine = || -> Result<Arc<Engine>> {
        match &engine {
            Some(e) => Ok(e.clone()),
            None => Ok(Arc::new(Engine::new()?)),
        }
    };
    let table = match id {
        "table1" => real_exps::table1(need_engine()?, quick)?,
        "fig3" => cloud_exps::fig3()?,
        "table2" => cloud_exps::table2()?,
        "table3" => cloud_exps::table3()?,
        "fig4" => cloud_exps::fig4()?,
        "fig5" => cloud_exps::fig5()?,
        "fig6" => real_exps::fig6(need_engine()?, quick)?,
        "headline" => cloud_exps::headline()?,
        other => {
            return Err(Error::Config(format!(
                "unknown experiment {other:?}; try one of {ALL_EXPERIMENTS:?} or `all`"
            )))
        }
    };
    table.print();
    table.save(out_dir, id)?;
    Ok(())
}

/// Run every experiment (a shared engine keeps PJRT compiles cached).
pub fn run_all(quick: bool, out_dir: &str) -> Result<()> {
    let engine = Arc::new(Engine::new()?);
    for id in ALL_EXPERIMENTS {
        run(id, quick, out_dir, Some(engine.clone()))?;
    }
    Ok(())
}
