//! Real-execution experiment drivers: Table I (per-stage resource
//! usage) and fig 6 (sync vs async convergence). These run the actual
//! cluster — PJRT gradients, broker exchange, barriers — on the mini
//! models, and print measured numbers next to the paper's.

use std::sync::Arc;

use super::report::{fmt_secs, Table};
use crate::config::{Backend, SyncMode, TrainConfig};
use crate::coordinator::{Cluster, TrainReport};
use crate::error::Result;
use crate::metrics::Stage;
use crate::runtime::Engine;

/// Paper Table I reference values (MNIST column, seconds) for the
/// side-by-side: (model, [compute, send, recv, update, convergence]).
pub const PAPER_TABLE1_MNIST_S: &[(&str, [f64; 5])] = &[
    ("mini_squeezenet", [14.93, 0.084, 0.25, 0.18, 0.19]),
    ("mini_mobilenet", [29.72, 0.11, 0.38, 0.015, 1.12]),
    ("mini_vgg", [104.37, 7.38, 15.55, 4.8, 9.20]),
];

fn stage_mean_s(report: &TrainReport, stage: Stage) -> f64 {
    report
        .stages
        .iter()
        .find(|(s, _)| *s == stage)
        .map(|(_, sum)| sum.mean_wall().as_secs_f64())
        .unwrap_or(0.0)
}

fn stage_cpu(report: &TrainReport, stage: Stage) -> f64 {
    report
        .stages
        .iter()
        .find(|(s, _)| *s == stage)
        .map(|(_, sum)| sum.mean_cpu_pct)
        .unwrap_or(0.0)
}

fn stage_rss_mb(report: &TrainReport, stage: Stage) -> f64 {
    report
        .stages
        .iter()
        .find(|(s, _)| *s == stage)
        .map(|(_, sum)| sum.peak_rss_bytes as f64 / 1e6)
        .unwrap_or(0.0)
}

/// Table I: run 4 peers on each model (both datasets unless quick) and
/// report the measured per-stage wall/CPU/RSS, with the paper's MNIST
/// wall times alongside.
pub fn table1(engine: Arc<Engine>, quick: bool) -> Result<Table> {
    let mut t = Table::new(
        "Table I — per-stage resource usage, 4 peers (measured on mini models, real PJRT)",
        &[
            "model", "dataset", "stage", "wall (mean)", "cpu %", "rss MB", "paper wall (full-scale)",
        ],
    );
    let datasets: &[&str] = if quick { &["mnist"] } else { &["mnist", "cifar"] };
    let models: &[&str] = if quick {
        &["mini_squeezenet"]
    } else {
        &["mini_squeezenet", "mini_mobilenet", "mini_vgg"]
    };
    for &model in models {
        for &dataset in datasets {
            let config = TrainConfig {
                model: model.into(),
                dataset: dataset.into(),
                peers: 4,
                batch_size: 16,
                epochs: if quick { 1 } else { 2 },
                train_samples: 4 * 16 * if quick { 2 } else { 4 },
                val_samples: 256,
                backend: Backend::Instance,
                sync: SyncMode::Synchronous,
                ..Default::default()
            };
            let report = Cluster::with_engine(config, engine.clone())?.run()?;
            let paper = PAPER_TABLE1_MNIST_S
                .iter()
                .find(|(m, _)| *m == model)
                .map(|(_, v)| *v)
                .unwrap_or([f64::NAN; 5]);
            for (i, stage) in Stage::ALL.iter().enumerate() {
                t.row(vec![
                    model.into(),
                    dataset.into(),
                    stage.to_string(),
                    fmt_secs(stage_mean_s(&report, *stage)),
                    format!("{:.1}", stage_cpu(&report, *stage)),
                    format!("{:.0}", stage_rss_mb(&report, *stage)),
                    if dataset == "mnist" {
                        fmt_secs(paper[i])
                    } else {
                        "-".into()
                    },
                ]);
            }
        }
    }
    t.note("paper columns are full-scale models on t2 instances; ours are CPU-PJRT minis —");
    t.note("the claim under test is the SHAPE: compute_gradients dominates every other stage");
    Ok(t)
}

/// The Table-I conclusion as a checkable predicate: gradient computation
/// dominates all other stages.
pub fn table1_dominant_stage(engine: Arc<Engine>) -> Result<Stage> {
    let config = TrainConfig {
        model: "mini_squeezenet".into(),
        dataset: "mnist".into(),
        peers: 2,
        batch_size: 16,
        epochs: 1,
        train_samples: 2 * 16 * 2,
        val_samples: 64,
        ..Default::default()
    };
    let report = Cluster::with_engine(config, engine)?.run()?;
    let mut best = (Stage::SendGradients, std::time::Duration::ZERO);
    for (stage, s) in &report.stages {
        if s.total_wall > best.1 {
            best = (*stage, s.total_wall);
        }
    }
    Ok(best.0)
}

/// Fig 6: synchronous vs asynchronous P2P convergence (MobileNet-style
/// model, the paper's batch 64 scaled to the testbed).
pub fn fig6(engine: Arc<Engine>, quick: bool) -> Result<Table> {
    let epochs = if quick { 4 } else { 12 };
    let base = TrainConfig {
        model: "mini_mobilenet".into(),
        dataset: "mnist".into(),
        peers: 4,
        batch_size: 16,
        epochs,
        lr: 0.05,
        train_samples: 4 * 16 * 4,
        val_samples: 256,
        backend: Backend::Instance,
        ..Default::default()
    };
    let sync_cfg = TrainConfig { sync: SyncMode::Synchronous, ..base.clone() };
    let async_cfg = TrainConfig { sync: SyncMode::Asynchronous, ..base };
    let sync_rep = Cluster::with_engine(sync_cfg, engine.clone())?.run()?;
    let async_rep = Cluster::with_engine(async_cfg, engine)?.run()?;

    let mut t = Table::new(
        "Fig 6 — synchronous vs asynchronous P2P training (mini MobileNetV3)",
        &["epoch", "sync val_loss", "sync acc", "async val_loss", "async acc"],
    );
    let n = sync_rep.val_curve.len().max(async_rep.val_curve.len());
    for i in 0..n {
        let s = sync_rep.val_curve.get(i);
        let a = async_rep.val_curve.get(i);
        t.row(vec![
            (i + 1).to_string(),
            s.map(|v| format!("{:.4}", v.1)).unwrap_or("-".into()),
            s.map(|v| format!("{:.3}", v.2)).unwrap_or("-".into()),
            a.map(|v| format!("{:.4}", v.1)).unwrap_or("-".into()),
            a.map(|v| format!("{:.3}", v.2)).unwrap_or("-".into()),
        ]);
    }
    t.note("paper: sync reaches higher accuracy sooner; async risks stale gradients");
    Ok(t)
}

#[cfg(test)]
mod tests {
    // Real-PJRT drivers are exercised by rust/tests/ integration tests
    // and the `p2pless exp` CLI; nothing cheap to assert here.
}
