//! Deterministic fault injection for membership / robustness tests.
//!
//! A fault plan is a seeded, fully explicit schedule of failures —
//! *kill* a peer at an epoch, *delay* a gradient branch, *duplicate* a
//! branch delivery, *join* a peer mid-run, or break the I/O planes
//! (transient store put/get errors, injected store latency, corrupted
//! reads, broker publish drops/delays) — parsed from a compact spec
//! string (`--fault-plan`) and resolved against the concrete cluster
//! shape before the run starts. Resolution is pure: the same spec, peer
//! count, and epoch count always produce the same event list, so every
//! failure mode is replayable byte-for-byte in tests and benches.
//!
//! Spec grammar (entries joined by `;`):
//!
//! | entry                          | effect                                    |
//! |--------------------------------|-------------------------------------------|
//! | `kill:peer1@2`                 | peer 1 exits at the start of epoch 2      |
//! | `delay:peer0@3:5ms`            | every epoch-3 branch of peer 0 sleeps 5ms |
//! | `delay:peer0.branch3@1:5ms`    | only branch 3 sleeps                      |
//! | `dup:peer2.branch0@1`          | branch 0 is dispatched twice in epoch 1   |
//! | `join:peer1@3`                 | peer 1 (re)joins at the epoch-3 boundary  |
//! | `join:peer4@3`                 | a brand-new rank 4 grows the cluster      |
//! | `storeput:peer1@2`             | one transient S3 put error (retried)      |
//! | `storeget:peer1@2`             | one transient S3 get error (retried)      |
//! | `storedelay:peer1@2:5ms`       | one store op sleeps 5ms (measured only)   |
//! | `storecorrupt:peer1@2`         | one read returns corrupted bytes          |
//! | `brokerdrop:peer1@2`           | one publish is dropped (retried)          |
//! | `brokerdelay:peer1@2:5ms`      | one publish sleeps 5ms (measured only)    |
//! | `rate:kill=0.25,seed=7`        | seeded kills covering 25% of the peers    |
//! | `rate:join=0.5,seed=7`         | seeded growth joins (floor(rate × peers)) |
//! | `rate:store=0.2,seed=7`        | seeded store faults over peer × epoch     |
//!
//! Kills and joins take effect in the coordinator (peer loop /
//! membership admission); delays and duplicates are applied at the
//! serverless branch dispatch site; store and broker faults fire inside
//! [`crate::store::ObjectStore`] / [`crate::broker::Broker`] via the
//! chaos hook, scoped to the injecting peer's ops by the thread-local
//! [`FaultScope`]. Every I/O fault is *transparent* by construction —
//! transient errors are retried under the shared
//! [`crate::util::retry::RetryPolicy`], corrupted reads are caught by
//! content-hash verification and re-fetched, and delays move only the
//! measured wall — so an armed run's training math is bit-identical to
//! the fault-free run.

use std::cell::Cell;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::error::{Error, Result};

/// What a single fault event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// The peer's training thread exits at the start of the epoch.
    Kill,
    /// The branch's Lambda invocation sleeps before executing.
    Delay,
    /// The branch is dispatched twice; the duplicate's result is
    /// discarded deterministically before the fold.
    Dup,
    /// The peer joins the run at this epoch's boundary: a dead rank is
    /// revived onto its old partition, a rank equal to the current
    /// cluster width grows the cluster.
    Join,
    /// One store put by the peer fails transiently (succeeds on retry).
    StorePutErr,
    /// One store get by the peer fails transiently (succeeds on retry).
    StoreGetErr,
    /// One store op by the peer sleeps (measured time only).
    StoreDelay,
    /// One store get returns corrupted bytes (caught by hash
    /// verification, re-fetched).
    StoreCorrupt,
    /// One broker publish by the peer is dropped (succeeds on retry).
    BrokerDrop,
    /// One broker publish by the peer sleeps (measured time only).
    BrokerDelay,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            Self::Kill => "kill",
            Self::Delay => "delay",
            Self::Dup => "dup",
            Self::Join => "join",
            Self::StorePutErr => "storeput",
            Self::StoreGetErr => "storeget",
            Self::StoreDelay => "storedelay",
            Self::StoreCorrupt => "storecorrupt",
            Self::BrokerDrop => "brokerdrop",
            Self::BrokerDelay => "brokerdelay",
        }
    }

    /// Kinds carrying a `:Tms` duration suffix.
    fn has_duration(self) -> bool {
        matches!(self, Self::Delay | Self::StoreDelay | Self::BrokerDelay)
    }

    /// Kinds injected at the store/broker layer (fire-once I/O faults).
    fn is_io(self) -> bool {
        matches!(
            self,
            Self::StorePutErr
                | Self::StoreGetErr
                | Self::StoreDelay
                | Self::StoreCorrupt
                | Self::BrokerDrop
                | Self::BrokerDelay
        )
    }
}

/// One resolved fault: kind × peer × (optional branch) × epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub peer: usize,
    /// Target branch for delay/dup; `None` hits every branch (delay
    /// only — a blanket duplicate would double the whole epoch).
    pub branch: Option<usize>,
    /// 1-based training epoch the fault fires in.
    pub epoch: u64,
    /// Injected sleep for the delay kinds, in microseconds.
    pub delay_us: u64,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:peer{}", self.kind.name(), self.peer)?;
        if let Some(b) = self.branch {
            write!(f, ".branch{b}")?;
        }
        write!(f, "@{}", self.epoch)?;
        if self.kind.has_duration() {
            write!(f, ":{}ms", self.delay_us / 1000)?;
        }
        Ok(())
    }
}

/// A parsed-but-unresolved `--fault-plan`: explicit events plus
/// optional seeded rate clauses that expand once the cluster shape
/// (peers, epochs) is known.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlanSpec {
    explicit: Vec<FaultEvent>,
    /// `(kill_rate, join_rate, store_rate, seed)` from a `rate:` clause.
    rate: Option<(f64, f64, f64, u64)>,
}

impl FaultPlanSpec {
    /// Parse a spec string; `""` is the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .ok_or_else(|| Error::Config(format!("bad fault entry {entry:?}")))?;
            match kind {
                "kill" => {
                    let (peer, branch, epoch) = parse_target(rest)?;
                    if branch.is_some() {
                        return Err(Error::Config(format!(
                            "kill targets a peer, not a branch: {entry:?}"
                        )));
                    }
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Kill,
                        peer,
                        branch: None,
                        epoch,
                        delay_us: 0,
                    });
                }
                "delay" => {
                    let (target, us) = parse_duration_suffix(entry, rest)?;
                    let (peer, branch, epoch) = parse_target(target)?;
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Delay,
                        peer,
                        branch,
                        epoch,
                        delay_us: us,
                    });
                }
                "dup" => {
                    let (peer, branch, epoch) = parse_target(rest)?;
                    let branch = branch.ok_or_else(|| {
                        Error::Config(format!("dup targets a specific branch: {entry:?}"))
                    })?;
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Dup,
                        peer,
                        branch: Some(branch),
                        epoch,
                        delay_us: 0,
                    });
                }
                "join" => {
                    let (peer, branch, epoch) = parse_target(rest)?;
                    if branch.is_some() {
                        return Err(Error::Config(format!(
                            "join targets a peer, not a branch: {entry:?}"
                        )));
                    }
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Join,
                        peer,
                        branch: None,
                        epoch,
                        delay_us: 0,
                    });
                }
                "storeput" | "storeget" | "storecorrupt" | "brokerdrop" => {
                    let k = match kind {
                        "storeput" => FaultKind::StorePutErr,
                        "storeget" => FaultKind::StoreGetErr,
                        "storecorrupt" => FaultKind::StoreCorrupt,
                        _ => FaultKind::BrokerDrop,
                    };
                    let (peer, branch, epoch) = parse_target(rest)?;
                    if branch.is_some() {
                        return Err(Error::Config(format!(
                            "{kind} targets a peer, not a branch: {entry:?}"
                        )));
                    }
                    plan.explicit.push(FaultEvent {
                        kind: k,
                        peer,
                        branch: None,
                        epoch,
                        delay_us: 0,
                    });
                }
                "storedelay" | "brokerdelay" => {
                    let k = if kind == "storedelay" {
                        FaultKind::StoreDelay
                    } else {
                        FaultKind::BrokerDelay
                    };
                    let (target, us) = parse_duration_suffix(entry, rest)?;
                    let (peer, branch, epoch) = parse_target(target)?;
                    if branch.is_some() {
                        return Err(Error::Config(format!(
                            "{kind} targets a peer, not a branch: {entry:?}"
                        )));
                    }
                    plan.explicit.push(FaultEvent {
                        kind: k,
                        peer,
                        branch: None,
                        epoch,
                        delay_us: us,
                    });
                }
                "rate" => {
                    let mut kill_rate = 0f64;
                    let mut join_rate = 0f64;
                    let mut store_rate = 0f64;
                    let mut any = false;
                    let mut seed = 0u64;
                    let parse_rate = |key: &str, v: &str| -> Result<f64> {
                        let r: f64 = v.parse().map_err(|_| {
                            Error::Config(format!("bad fault {key} rate {v:?}"))
                        })?;
                        if !(0.0..=1.0).contains(&r) {
                            return Err(Error::Config(format!(
                                "fault {key} rate {r} outside [0,1]"
                            )));
                        }
                        Ok(r)
                    };
                    for kv in rest.split(',').map(str::trim) {
                        match kv.split_once('=') {
                            Some(("kill", v)) => {
                                kill_rate = parse_rate("kill", v)?;
                                any = true;
                            }
                            Some(("join", v)) => {
                                join_rate = parse_rate("join", v)?;
                                any = true;
                            }
                            Some(("store", v)) => {
                                store_rate = parse_rate("store", v)?;
                                any = true;
                            }
                            Some(("seed", v)) => {
                                seed = v.parse().map_err(|_| {
                                    Error::Config(format!("bad fault seed {v:?}"))
                                })?;
                            }
                            _ => {
                                return Err(Error::Config(format!(
                                    "bad fault rate clause {kv:?}"
                                )))
                            }
                        }
                    }
                    if !any {
                        return Err(Error::Config(format!(
                            "rate clause needs kill=/join=/store=<frac>: {entry:?}"
                        )));
                    }
                    plan.rate = Some((kill_rate, join_rate, store_rate, seed));
                }
                other => {
                    return Err(Error::Config(format!("unknown fault kind {other:?}")))
                }
            }
        }
        Ok(plan)
    }

    /// No entries at all?
    pub fn is_empty(&self) -> bool {
        self.explicit.is_empty() && self.rate.is_none()
    }

    /// Expand against the concrete cluster shape into a sorted,
    /// deterministic event list. Rate-based kills pick distinct victims
    /// among ranks `1..peers` (rank 0 is spared so the seeded sweep
    /// always keeps the natural leader) and fire in seeded epochs
    /// `1..=epochs`; rate-based joins grow the cluster with
    /// `floor(rate × peers)` new ranks at seeded epochs `2..=epochs`
    /// (earliest epoch gets the lowest new rank, so admission order is
    /// well-formed); rate-based store faults spread
    /// `floor(rate × peers × epochs)` events over the peer × epoch
    /// grid, cycling get-error / put-error / corrupt kinds.
    pub fn resolve(&self, peers: usize, epochs: usize) -> Result<FaultPlan> {
        let mut events = self.explicit.clone();
        for ev in &events {
            if ev.kind == FaultKind::Join {
                // join ranks are validated by the width simulation
                // below (a growth join's rank exceeds `peers` by
                // design); epochs start at 2 — admission happens at
                // the end of epoch-1 at the earliest
                if ev.epoch < 2 || ev.epoch > epochs as u64 {
                    return Err(Error::Config(format!(
                        "join at epoch {} outside 2..={epochs} \
                         (admission needs a completed prior epoch)",
                        ev.epoch
                    )));
                }
                continue;
            }
            if ev.peer >= peers {
                return Err(Error::Config(format!(
                    "fault plan targets peer {} but the cluster has {peers}",
                    ev.peer
                )));
            }
            if ev.epoch == 0 || ev.epoch > epochs as u64 {
                return Err(Error::Config(format!(
                    "fault plan targets epoch {} outside 1..={epochs}",
                    ev.epoch
                )));
            }
        }
        if let Some((kill_rate, join_rate, store_rate, seed)) = self.rate {
            let mut rng = seed ^ 0x9e37_79b9_7f4a_7c15;
            let kills =
                ((kill_rate * peers as f64).floor() as usize).min(peers.saturating_sub(1));
            let mut victims: Vec<usize> = (1..peers).collect();
            for k in 0..kills {
                let pick = k + (splitmix(&mut rng) as usize) % (victims.len() - k).max(1);
                victims.swap(k, pick);
                let epoch = 1 + splitmix(&mut rng) % epochs.max(1) as u64;
                events.push(FaultEvent {
                    kind: FaultKind::Kill,
                    peer: victims[k],
                    branch: None,
                    epoch,
                    delay_us: 0,
                });
            }
            if epochs >= 2 {
                let joins = (join_rate * peers as f64).floor() as usize;
                let mut join_epochs: Vec<u64> = (0..joins)
                    .map(|_| 2 + splitmix(&mut rng) % (epochs as u64 - 1))
                    .collect();
                // earliest join gets the lowest new rank so each growth
                // admission sees a contiguous width
                join_epochs.sort_unstable();
                for (i, epoch) in join_epochs.into_iter().enumerate() {
                    events.push(FaultEvent {
                        kind: FaultKind::Join,
                        peer: peers + i,
                        branch: None,
                        epoch,
                        delay_us: 0,
                    });
                }
            }
            let cells = peers * epochs;
            let store_faults =
                ((store_rate * cells as f64).floor() as usize).min(cells);
            const STORE_KINDS: [FaultKind; 3] =
                [FaultKind::StoreGetErr, FaultKind::StorePutErr, FaultKind::StoreCorrupt];
            for i in 0..store_faults {
                let peer = (splitmix(&mut rng) as usize) % peers.max(1);
                let epoch = 1 + splitmix(&mut rng) % epochs.max(1) as u64;
                events.push(FaultEvent {
                    kind: STORE_KINDS[i % STORE_KINDS.len()],
                    peer,
                    branch: None,
                    epoch,
                    delay_us: 0,
                });
            }
        }
        events.sort();
        events.dedup();
        // joins must form a well-ordered admission sequence: a revival
        // targets an original rank, a growth join's rank must equal the
        // cluster width at its admission boundary, and no rank joins
        // twice (rank 0 — the epoch-1 leader — never joins)
        let mut joins: Vec<&FaultEvent> =
            events.iter().filter(|e| e.kind == FaultKind::Join).collect();
        joins.sort_by_key(|e| (e.epoch, e.peer));
        let mut width = peers;
        let mut seen: Vec<usize> = Vec::new();
        for j in &joins {
            if j.peer == 0 {
                return Err(Error::Config("rank 0 (the leader) cannot join".into()));
            }
            if seen.contains(&j.peer) {
                return Err(Error::Config(format!("peer {} joins twice", j.peer)));
            }
            seen.push(j.peer);
            if j.peer >= peers {
                if j.peer != width {
                    return Err(Error::Config(format!(
                        "growth join rank {} does not match the cluster \
                         width {width} at epoch {}",
                        j.peer, j.epoch
                    )));
                }
                width += 1;
            }
        }
        Ok(FaultPlan::new(events))
    }
}

/// Split a duration-suffixed entry (`target:Tms`) into target and
/// microseconds.
fn parse_duration_suffix<'a>(entry: &str, rest: &'a str) -> Result<(&'a str, u64)> {
    let (target, ms) = rest
        .rsplit_once(':')
        .ok_or_else(|| Error::Config(format!("delay needs a duration: {entry:?}")))?;
    let ms = ms.strip_suffix("ms").unwrap_or(ms);
    let ms: u64 = ms
        .parse()
        .map_err(|_| Error::Config(format!("bad fault delay duration {ms:?}")))?;
    Ok((target, ms * 1000))
}

fn parse_target(s: &str) -> Result<(usize, Option<usize>, u64)> {
    let (who, epoch) = s
        .split_once('@')
        .ok_or_else(|| Error::Config(format!("fault target needs @epoch: {s:?}")))?;
    let epoch: u64 = epoch
        .parse()
        .map_err(|_| Error::Config(format!("bad fault epoch {epoch:?}")))?;
    let (peer, branch) = match who.split_once('.') {
        Some((p, b)) => {
            let b = b
                .strip_prefix("branch")
                .and_then(|n| n.parse().ok())
                .ok_or_else(|| Error::Config(format!("bad fault branch {b:?}")))?;
            (p, Some(b))
        }
        None => (who, None),
    };
    let peer: usize = peer
        .strip_prefix("peer")
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| Error::Config(format!("bad fault peer {peer:?}")))?;
    Ok((peer, branch, epoch))
}

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

thread_local! {
    /// The (rank, epoch) whose I/O the current thread is performing —
    /// set by the peer loop around each epoch and by the Lambda handler
    /// around each branch, read by the store/broker chaos hooks.
    /// Threads without a scope (trainer setup/teardown, tests) are
    /// never faulted.
    static FAULT_SCOPE: Cell<Option<(usize, u64)>> = const { Cell::new(None) };
}

/// RAII guard scoping the current thread's store/broker ops to one
/// (rank, epoch) for fault matching; restores the previous scope on
/// drop so nested scopes (a takeover fan-out inside a survivor's
/// epoch) compose.
pub struct FaultScope {
    prev: Option<(usize, u64)>,
}

impl FaultScope {
    pub fn enter(rank: usize, epoch: u64) -> Self {
        let prev = FAULT_SCOPE.with(|s| s.replace(Some((rank, epoch))));
        Self { prev }
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        let prev = self.prev;
        FAULT_SCOPE.with(|s| s.set(prev));
    }
}

/// The (rank, epoch) scope of the current thread, if any.
pub fn current_fault_scope() -> Option<(usize, u64)> {
    FAULT_SCOPE.with(|s| s.get())
}

/// Which store primitive is asking for a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Put,
    Get,
}

/// An injected store fault, consumed (at most once per scheduled
/// event) by the [`crate::store::ObjectStore`] chaos hook.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFault {
    /// Fail this op with a transient error (the retry loop recovers).
    Transient,
    /// Sleep this many microseconds before the op (measured time only).
    Delay(u64),
    /// Return corrupted bytes from this get (hash verification catches
    /// it and re-fetches).
    Corrupt,
}

/// An injected broker fault, consumed by the [`crate::broker::Broker`]
/// publish hook.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BrokerFault {
    /// Drop this publish (fail transiently; the retry loop recovers).
    Drop,
    /// Sleep this many microseconds before publishing.
    Delay(u64),
}

/// A resolved fault schedule, consulted by the peer loop (kills,
/// joins), the serverless branch dispatch (delays, duplicates) and the
/// store/broker chaos hooks (I/O faults). Counters track how many
/// injections actually fired, surfaced as `fault.*` in the train
/// report. I/O events fire exactly once each: the first matching op
/// under the event's (peer, epoch) scope consumes it atomically —
/// *which* op wins under concurrency is timing-dependent, but every
/// injected fault is transparent (retried / re-fetched / sleep-only),
/// so the training math never sees the difference.
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    /// Per-event consumed flags (I/O kinds only; index-aligned with
    /// `events`).
    fired: Vec<AtomicBool>,
    kills_fired: AtomicU64,
    delays_fired: AtomicU64,
    dups_fired: AtomicU64,
    joins_fired: AtomicU64,
    store_faults_fired: AtomicU64,
    broker_faults_fired: AtomicU64,
}

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>) -> Self {
        let fired = events.iter().map(|_| AtomicBool::new(false)).collect();
        Self { events, fired, ..Default::default() }
    }

    /// The resolved schedule, sorted and deduplicated.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Canonical spec string for the resolved schedule — two plans
    /// that replay identically render identically.
    pub fn to_spec(&self) -> String {
        let parts: Vec<String> = self.events.iter().map(|e| e.to_string()).collect();
        parts.join(";")
    }

    /// Does `rank` die at the start of `epoch`? Fires the kill counter
    /// on a hit (callers act on every hit exactly once).
    pub fn should_kill(&self, rank: usize, epoch: u64) -> bool {
        let hit = self
            .events
            .iter()
            .any(|e| e.kind == FaultKind::Kill && e.peer == rank && e.epoch == epoch);
        if hit {
            self.kills_fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// The earliest epoch `rank` is scheduled to die in, if any.
    pub fn kill_epoch(&self, rank: usize) -> Option<u64> {
        self.events
            .iter()
            .filter(|e| e.kind == FaultKind::Kill && e.peer == rank)
            .map(|e| e.epoch)
            .min()
    }

    /// Every scheduled join as (rank, first-epoch), ordered by epoch
    /// then rank — the membership admission schedule.
    pub fn join_events(&self) -> Vec<(usize, u64)> {
        let mut joins: Vec<(usize, u64)> = self
            .events
            .iter()
            .filter(|e| e.kind == FaultKind::Join)
            .map(|e| (e.peer, e.epoch))
            .collect();
        joins.sort_by_key(|&(r, e)| (e, r));
        joins
    }

    /// The epoch `rank` is scheduled to join in, if any.
    pub fn join_epoch(&self, rank: usize) -> Option<u64> {
        self.events
            .iter()
            .filter(|e| e.kind == FaultKind::Join && e.peer == rank)
            .map(|e| e.epoch)
            .min()
    }

    /// Record one admitted join (fired by membership on admission).
    pub fn record_join_fired(&self) {
        self.joins_fired.fetch_add(1, Ordering::Relaxed);
    }

    /// Injected sleep for this branch invocation, if any (the longest
    /// matching delay wins when a blanket and a targeted entry both
    /// apply).
    pub fn branch_delay_us(&self, rank: usize, epoch: u64, branch: usize) -> Option<u64> {
        let us = self
            .events
            .iter()
            .filter(|e| {
                e.kind == FaultKind::Delay
                    && e.peer == rank
                    && e.epoch == epoch
                    && (e.branch.is_none() || e.branch == Some(branch))
            })
            .map(|e| e.delay_us)
            .max();
        if us.is_some() {
            self.delays_fired.fetch_add(1, Ordering::Relaxed);
        }
        us
    }

    /// Should this branch be dispatched twice?
    pub fn duplicate(&self, rank: usize, epoch: u64, branch: usize) -> bool {
        let hit = self.events.iter().any(|e| {
            e.kind == FaultKind::Dup
                && e.peer == rank
                && e.epoch == epoch
                && e.branch == Some(branch)
        });
        if hit {
            self.dups_fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Does any delay/dup entry target `rank`'s branches at all? Used
    /// to decide whether branch indices must ride in the payload.
    pub fn targets_branches(&self, rank: usize) -> bool {
        self.events
            .iter()
            .any(|e| e.peer == rank && matches!(e.kind, FaultKind::Delay | FaultKind::Dup))
    }

    /// Consume one matching event atomically (fire-once).
    fn take(&self, want: impl Fn(&FaultEvent) -> bool) -> Option<&FaultEvent> {
        for (i, e) in self.events.iter().enumerate() {
            if !e.kind.is_io() || !want(e) {
                continue;
            }
            if self.fired[i]
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(e);
            }
        }
        None
    }

    /// One store fault owed to `(rank, epoch)` for this op, if any —
    /// consumed exactly once per scheduled event. Put sites take
    /// put-errors and delays; get sites take get-errors, corruption
    /// and delays.
    pub fn take_store_fault(
        &self,
        rank: usize,
        epoch: u64,
        op: StoreOp,
    ) -> Option<StoreFault> {
        let ev = self.take(|e| {
            e.peer == rank
                && e.epoch == epoch
                && match e.kind {
                    FaultKind::StorePutErr => op == StoreOp::Put,
                    FaultKind::StoreGetErr | FaultKind::StoreCorrupt => op == StoreOp::Get,
                    FaultKind::StoreDelay => true,
                    _ => false,
                }
        })?;
        self.store_faults_fired.fetch_add(1, Ordering::Relaxed);
        Some(match ev.kind {
            FaultKind::StorePutErr | FaultKind::StoreGetErr => StoreFault::Transient,
            FaultKind::StoreDelay => StoreFault::Delay(ev.delay_us),
            _ => StoreFault::Corrupt,
        })
    }

    /// One broker fault owed to `(rank, epoch)` for this publish, if
    /// any — consumed exactly once per scheduled event.
    pub fn take_broker_fault(&self, rank: usize, epoch: u64) -> Option<BrokerFault> {
        let ev = self.take(|e| {
            e.peer == rank
                && e.epoch == epoch
                && matches!(e.kind, FaultKind::BrokerDrop | FaultKind::BrokerDelay)
        })?;
        self.broker_faults_fired.fetch_add(1, Ordering::Relaxed);
        Some(match ev.kind {
            FaultKind::BrokerDrop => BrokerFault::Drop,
            _ => BrokerFault::Delay(ev.delay_us),
        })
    }

    /// Does the plan schedule any store/broker fault at all? Gates the
    /// chaos arming of the I/O planes (unarmed = untouched fast path).
    pub fn has_io_faults(&self) -> bool {
        self.events.iter().any(|e| e.kind.is_io())
    }

    /// Kills that actually fired.
    pub fn kills_fired(&self) -> u64 {
        self.kills_fired.load(Ordering::Relaxed)
    }

    /// Branch delays that actually fired.
    pub fn delays_fired(&self) -> u64 {
        self.delays_fired.load(Ordering::Relaxed)
    }

    /// Branch duplicates that actually fired.
    pub fn dups_fired(&self) -> u64 {
        self.dups_fired.load(Ordering::Relaxed)
    }

    /// Joins that were actually admitted.
    pub fn joins_fired(&self) -> u64 {
        self.joins_fired.load(Ordering::Relaxed)
    }

    /// Store faults that actually fired.
    pub fn store_faults_fired(&self) -> u64 {
        self.store_faults_fired.load(Ordering::Relaxed)
    }

    /// Broker faults that actually fired.
    pub fn broker_faults_fired(&self) -> u64 {
        self.broker_faults_fired.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let plan = FaultPlanSpec::parse(
            "kill:peer1@2;delay:peer0@3:5ms;delay:peer0.branch3@1:2ms;dup:peer2.branch0@1",
        )
        .unwrap();
        let plan = plan.resolve(4, 4).unwrap();
        assert_eq!(plan.events().len(), 4);
        assert!(plan.should_kill(1, 2));
        assert!(!plan.should_kill(1, 1));
        assert_eq!(plan.branch_delay_us(0, 3, 7), Some(5000));
        assert_eq!(plan.branch_delay_us(0, 1, 3), Some(2000));
        assert_eq!(plan.branch_delay_us(0, 1, 4), None);
        assert!(plan.duplicate(2, 1, 0));
        assert!(!plan.duplicate(2, 1, 1));
        assert_eq!(plan.kills_fired(), 1);
        assert_eq!(plan.delays_fired(), 2);
        assert_eq!(plan.dups_fired(), 1);
    }

    #[test]
    fn parses_join_and_io_kinds() {
        let plan = FaultPlanSpec::parse(
            "join:peer1@3;join:peer4@2;storeput:peer0@1;storeget:peer1@2;\
             storedelay:peer2@1:5ms;storecorrupt:peer3@2;brokerdrop:peer0@2;\
             brokerdelay:peer1@1:3ms",
        )
        .unwrap()
        .resolve(4, 4)
        .unwrap();
        assert_eq!(plan.events().len(), 8);
        assert_eq!(plan.join_epoch(1), Some(3));
        assert_eq!(plan.join_epoch(4), Some(2));
        assert_eq!(plan.join_events(), vec![(4, 2), (1, 3)]);
        assert!(plan.has_io_faults());
        assert_eq!(
            plan.take_store_fault(0, 1, StoreOp::Put),
            Some(StoreFault::Transient)
        );
        assert_eq!(
            plan.take_store_fault(3, 2, StoreOp::Get),
            Some(StoreFault::Corrupt)
        );
        assert_eq!(
            plan.take_store_fault(2, 1, StoreOp::Get),
            Some(StoreFault::Delay(5000))
        );
        assert_eq!(plan.take_broker_fault(0, 2), Some(BrokerFault::Drop));
        assert_eq!(plan.take_broker_fault(1, 1), Some(BrokerFault::Delay(3000)));
        assert_eq!(plan.store_faults_fired(), 3);
        assert_eq!(plan.broker_faults_fired(), 2);
    }

    #[test]
    fn io_faults_fire_exactly_once() {
        let plan = FaultPlanSpec::parse("storeget:peer1@2")
            .unwrap()
            .resolve(4, 4)
            .unwrap();
        // a get-error never matches a put site
        assert_eq!(plan.take_store_fault(1, 2, StoreOp::Put), None);
        assert_eq!(
            plan.take_store_fault(1, 2, StoreOp::Get),
            Some(StoreFault::Transient)
        );
        // consumed: the second matching op sees nothing
        assert_eq!(plan.take_store_fault(1, 2, StoreOp::Get), None);
        assert_eq!(plan.store_faults_fired(), 1);
    }

    #[test]
    fn fault_scope_nests_and_restores() {
        assert_eq!(current_fault_scope(), None);
        {
            let _outer = FaultScope::enter(1, 2);
            assert_eq!(current_fault_scope(), Some((1, 2)));
            {
                let _inner = FaultScope::enter(3, 4);
                assert_eq!(current_fault_scope(), Some((3, 4)));
            }
            assert_eq!(current_fault_scope(), Some((1, 2)));
        }
        assert_eq!(current_fault_scope(), None);
    }

    #[test]
    fn empty_plan_is_empty() {
        let plan = FaultPlanSpec::parse("").unwrap();
        assert!(plan.is_empty());
        assert!(plan.resolve(4, 4).unwrap().events().is_empty());
    }

    #[test]
    fn rejects_malformed_entries() {
        assert!(FaultPlanSpec::parse("explode:peer1@2").is_err());
        assert!(FaultPlanSpec::parse("kill:peer1").is_err());
        assert!(FaultPlanSpec::parse("kill:peer1.branch2@1").is_err());
        assert!(FaultPlanSpec::parse("dup:peer1@1").is_err());
        assert!(FaultPlanSpec::parse("delay:peer1@1").is_err());
        assert!(FaultPlanSpec::parse("delay:peer1@1:banana").is_err());
        assert!(FaultPlanSpec::parse("rate:kill=2.0").is_err());
        assert!(FaultPlanSpec::parse("rate:seed=7").is_err());
        // the new kinds reject the same malformed shapes
        assert!(FaultPlanSpec::parse("join:banana").is_err());
        assert!(FaultPlanSpec::parse("join:peer1").is_err());
        assert!(FaultPlanSpec::parse("join:peer1.branch2@3").is_err());
        assert!(FaultPlanSpec::parse("storeput:peer1.branch0@1").is_err());
        assert!(FaultPlanSpec::parse("storedelay:peer1@1").is_err());
        assert!(FaultPlanSpec::parse("storedelay:peer1@1:soon").is_err());
        assert!(FaultPlanSpec::parse("brokerdrop:peerX@1").is_err());
        assert!(FaultPlanSpec::parse("rate:join=-0.5,seed=1").is_err());
        assert!(FaultPlanSpec::parse("rate:store=1.5,seed=1").is_err());
    }

    #[test]
    fn resolve_bounds_checks_the_cluster_shape() {
        let plan = FaultPlanSpec::parse("kill:peer7@2").unwrap();
        assert!(plan.resolve(4, 4).is_err());
        let plan = FaultPlanSpec::parse("kill:peer1@9").unwrap();
        assert!(plan.resolve(4, 4).is_err());
        // joins: epoch 1 is too early, rank 0 never joins, growth must
        // be contiguous, nobody joins twice
        assert!(FaultPlanSpec::parse("join:peer1@1").unwrap().resolve(4, 4).is_err());
        assert!(FaultPlanSpec::parse("join:peer0@2").unwrap().resolve(4, 4).is_err());
        assert!(FaultPlanSpec::parse("join:peer6@2").unwrap().resolve(4, 4).is_err());
        assert!(FaultPlanSpec::parse("join:peer1@2;join:peer1@3")
            .unwrap()
            .resolve(4, 4)
            .is_err());
        // a contiguous growth pair is fine
        assert!(FaultPlanSpec::parse("join:peer4@2;join:peer5@3")
            .unwrap()
            .resolve(4, 4)
            .is_ok());
    }

    #[test]
    fn seeded_rate_resolution_is_deterministic() {
        let spec = FaultPlanSpec::parse("rate:kill=0.5,seed=7").unwrap();
        let a = spec.resolve(8, 4).unwrap();
        let b = spec.resolve(8, 4).unwrap();
        assert_eq!(a.to_spec(), b.to_spec());
        assert_eq!(a.events().len(), 4); // floor(0.5 * 8)
        // rank 0 is always spared; victims are distinct
        let mut victims: Vec<usize> = a.events().iter().map(|e| e.peer).collect();
        assert!(!victims.contains(&0));
        victims.sort_unstable();
        victims.dedup();
        assert_eq!(victims.len(), 4);
        // a different seed picks a different schedule
        let other = FaultPlanSpec::parse("rate:kill=0.5,seed=8")
            .unwrap()
            .resolve(8, 4)
            .unwrap();
        assert_ne!(a.to_spec(), other.to_spec());
    }

    #[test]
    fn seeded_join_and_store_rates_resolve_deterministically() {
        let spec = FaultPlanSpec::parse("rate:join=0.5,store=0.25,seed=9").unwrap();
        let a = spec.resolve(4, 4).unwrap();
        let b = spec.resolve(4, 4).unwrap();
        assert_eq!(a.to_spec(), b.to_spec());
        let joins = a.join_events();
        assert_eq!(joins.len(), 2, "floor(0.5 × 4) growth joins");
        // growth ranks are contiguous from the initial width, in epoch
        // order, within the epoch range
        assert_eq!(joins[0].0, 4);
        assert_eq!(joins[1].0, 5);
        for &(_, e) in &joins {
            assert!((2..=4).contains(&e));
        }
        let io = a.events().iter().filter(|e| e.kind.is_io()).count();
        assert!(io >= 1 && io <= 4, "floor(0.25 × 16) store faults minus dedup");
        // and the canonical form re-resolves identically
        let again = FaultPlanSpec::parse(&a.to_spec()).unwrap().resolve(4, 4).unwrap();
        assert_eq!(again.to_spec(), a.to_spec());
    }

    #[test]
    fn rate_always_leaves_a_survivor() {
        let spec = FaultPlanSpec::parse("rate:kill=1.0,seed=1").unwrap();
        let plan = spec.resolve(4, 4).unwrap();
        assert_eq!(plan.events().len(), 3); // capped at peers - 1
    }

    #[test]
    fn canonical_spec_roundtrips() {
        let spec = "delay:peer0.branch3@1:2ms;dup:peer2.branch0@1;kill:peer1@2";
        let plan = FaultPlanSpec::parse(spec).unwrap().resolve(4, 4).unwrap();
        // to_spec renders sorted canonical form; reparsing it resolves
        // to the identical schedule
        let again = FaultPlanSpec::parse(&plan.to_spec())
            .unwrap()
            .resolve(4, 4)
            .unwrap();
        assert_eq!(plan.events(), again.events());
    }

    #[test]
    fn canonical_spec_roundtrips_with_new_kinds() {
        let spec = "join:peer4@2;storecorrupt:peer1@2;brokerdelay:peer0@1:2ms;\
                    storedelay:peer3@3:1ms;kill:peer2@2";
        let plan = FaultPlanSpec::parse(spec).unwrap().resolve(4, 4).unwrap();
        let again = FaultPlanSpec::parse(&plan.to_spec())
            .unwrap()
            .resolve(4, 4)
            .unwrap();
        assert_eq!(plan.events(), again.events());
        assert_eq!(plan.to_spec(), again.to_spec());
    }
}
