//! Deterministic fault injection for membership / robustness tests.
//!
//! A fault plan is a seeded, fully explicit schedule of failures —
//! *kill* a peer at an epoch, *delay* a gradient branch, *duplicate* a
//! branch delivery — parsed from a compact spec string
//! (`--fault-plan`) and resolved against the concrete cluster shape
//! before the run starts. Resolution is pure: the same spec, peer
//! count, and epoch count always produce the same event list, so every
//! failure mode is replayable byte-for-byte in tests and benches.
//!
//! Spec grammar (entries joined by `;`):
//!
//! | entry                          | effect                                    |
//! |--------------------------------|-------------------------------------------|
//! | `kill:peer1@2`                 | peer 1 exits at the start of epoch 2      |
//! | `delay:peer0@3:5ms`            | every epoch-3 branch of peer 0 sleeps 5ms |
//! | `delay:peer0.branch3@1:5ms`    | only branch 3 sleeps                      |
//! | `dup:peer2.branch0@1`          | branch 0 is dispatched twice in epoch 1   |
//! | `rate:kill=0.25,seed=7`        | seeded kills covering 25% of the peers    |
//!
//! Kills take effect in [`crate::coordinator::peer::Peer::run`];
//! delays and duplicates are applied at the serverless branch dispatch
//! site (the delay sleeps inside the Lambda handler, so it moves only
//! the *measured* wall — modeled accounting is untouched — and a
//! duplicate's second landing is suppressed before the fold so the
//! gradient math never sees it).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// What a single fault event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// The peer's training thread exits at the start of the epoch.
    Kill,
    /// The branch's Lambda invocation sleeps before executing.
    Delay,
    /// The branch is dispatched twice; the duplicate's result is
    /// discarded deterministically before the fold.
    Dup,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            Self::Kill => "kill",
            Self::Delay => "delay",
            Self::Dup => "dup",
        }
    }
}

/// One resolved fault: kind × peer × (optional branch) × epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub peer: usize,
    /// Target branch for delay/dup; `None` hits every branch (delay
    /// only — a blanket duplicate would double the whole epoch).
    pub branch: Option<usize>,
    /// 1-based training epoch the fault fires in.
    pub epoch: u64,
    /// Injected sleep for [`FaultKind::Delay`], in microseconds.
    pub delay_us: u64,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:peer{}", self.kind.name(), self.peer)?;
        if let Some(b) = self.branch {
            write!(f, ".branch{b}")?;
        }
        write!(f, "@{}", self.epoch)?;
        if self.kind == FaultKind::Delay {
            write!(f, ":{}ms", self.delay_us / 1000)?;
        }
        Ok(())
    }
}

/// A parsed-but-unresolved `--fault-plan`: explicit events plus an
/// optional seeded kill-rate clause that expands once the cluster
/// shape (peers, epochs) is known.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlanSpec {
    explicit: Vec<FaultEvent>,
    /// `(kill_rate, seed)` from a `rate:` clause.
    rate: Option<(f64, u64)>,
}

impl FaultPlanSpec {
    /// Parse a spec string; `""` is the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::default();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .ok_or_else(|| Error::Config(format!("bad fault entry {entry:?}")))?;
            match kind {
                "kill" => {
                    let (peer, branch, epoch) = parse_target(rest)?;
                    if branch.is_some() {
                        return Err(Error::Config(format!(
                            "kill targets a peer, not a branch: {entry:?}"
                        )));
                    }
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Kill,
                        peer,
                        branch: None,
                        epoch,
                        delay_us: 0,
                    });
                }
                "delay" => {
                    let (target, ms) = rest.rsplit_once(':').ok_or_else(|| {
                        Error::Config(format!("delay needs a duration: {entry:?}"))
                    })?;
                    let ms = ms.strip_suffix("ms").unwrap_or(ms);
                    let ms: u64 = ms.parse().map_err(|_| {
                        Error::Config(format!("bad fault delay duration {ms:?}"))
                    })?;
                    let (peer, branch, epoch) = parse_target(target)?;
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Delay,
                        peer,
                        branch,
                        epoch,
                        delay_us: ms * 1000,
                    });
                }
                "dup" => {
                    let (peer, branch, epoch) = parse_target(rest)?;
                    let branch = branch.ok_or_else(|| {
                        Error::Config(format!("dup targets a specific branch: {entry:?}"))
                    })?;
                    plan.explicit.push(FaultEvent {
                        kind: FaultKind::Dup,
                        peer,
                        branch: Some(branch),
                        epoch,
                        delay_us: 0,
                    });
                }
                "rate" => {
                    let mut kill_rate = None;
                    let mut seed = 0u64;
                    for kv in rest.split(',').map(str::trim) {
                        match kv.split_once('=') {
                            Some(("kill", v)) => {
                                let r: f64 = v.parse().map_err(|_| {
                                    Error::Config(format!("bad fault kill rate {v:?}"))
                                })?;
                                if !(0.0..=1.0).contains(&r) {
                                    return Err(Error::Config(format!(
                                        "fault kill rate {r} outside [0,1]"
                                    )));
                                }
                                kill_rate = Some(r);
                            }
                            Some(("seed", v)) => {
                                seed = v.parse().map_err(|_| {
                                    Error::Config(format!("bad fault seed {v:?}"))
                                })?;
                            }
                            _ => {
                                return Err(Error::Config(format!(
                                    "bad fault rate clause {kv:?}"
                                )))
                            }
                        }
                    }
                    let kill_rate = kill_rate.ok_or_else(|| {
                        Error::Config(format!("rate clause needs kill=<frac>: {entry:?}"))
                    })?;
                    plan.rate = Some((kill_rate, seed));
                }
                other => {
                    return Err(Error::Config(format!("unknown fault kind {other:?}")))
                }
            }
        }
        Ok(plan)
    }

    /// No entries at all?
    pub fn is_empty(&self) -> bool {
        self.explicit.is_empty() && self.rate.is_none()
    }

    /// Expand against the concrete cluster shape into a sorted,
    /// deterministic event list. Rate-based kills pick distinct
    /// victims among ranks `1..peers` (rank 0 is spared so the seeded
    /// sweep always keeps the natural leader) and fire in seeded
    /// epochs `1..=epochs`; the count is `floor(rate × peers)` capped
    /// at `peers - 1` so at least one survivor remains.
    pub fn resolve(&self, peers: usize, epochs: usize) -> Result<FaultPlan> {
        let mut events = self.explicit.clone();
        for ev in &events {
            if ev.peer >= peers {
                return Err(Error::Config(format!(
                    "fault plan targets peer {} but the cluster has {peers}",
                    ev.peer
                )));
            }
            if ev.epoch == 0 || ev.epoch > epochs as u64 {
                return Err(Error::Config(format!(
                    "fault plan targets epoch {} outside 1..={epochs}",
                    ev.epoch
                )));
            }
        }
        if let Some((rate, seed)) = self.rate {
            let kills = ((rate * peers as f64).floor() as usize).min(peers.saturating_sub(1));
            let mut rng = seed ^ 0x9e37_79b9_7f4a_7c15;
            let mut victims: Vec<usize> = (1..peers).collect();
            for k in 0..kills {
                let pick = k + (splitmix(&mut rng) as usize) % (victims.len() - k).max(1);
                victims.swap(k, pick);
                let epoch = 1 + splitmix(&mut rng) % epochs.max(1) as u64;
                events.push(FaultEvent {
                    kind: FaultKind::Kill,
                    peer: victims[k],
                    branch: None,
                    epoch,
                    delay_us: 0,
                });
            }
        }
        events.sort();
        events.dedup();
        Ok(FaultPlan::new(events))
    }
}

fn parse_target(s: &str) -> Result<(usize, Option<usize>, u64)> {
    let (who, epoch) = s
        .split_once('@')
        .ok_or_else(|| Error::Config(format!("fault target needs @epoch: {s:?}")))?;
    let epoch: u64 = epoch
        .parse()
        .map_err(|_| Error::Config(format!("bad fault epoch {epoch:?}")))?;
    let (peer, branch) = match who.split_once('.') {
        Some((p, b)) => {
            let b = b
                .strip_prefix("branch")
                .and_then(|n| n.parse().ok())
                .ok_or_else(|| Error::Config(format!("bad fault branch {b:?}")))?;
            (p, Some(b))
        }
        None => (who, None),
    };
    let peer: usize = peer
        .strip_prefix("peer")
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| Error::Config(format!("bad fault peer {peer:?}")))?;
    Ok((peer, branch, epoch))
}

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A resolved fault schedule, consulted by the peer loop (kills) and
/// the serverless branch dispatch (delays, duplicates). Counters track
/// how many injections actually fired, surfaced as `fault.*` in the
/// train report.
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    kills_fired: AtomicU64,
    delays_fired: AtomicU64,
    dups_fired: AtomicU64,
}

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>) -> Self {
        Self { events, ..Default::default() }
    }

    /// The resolved schedule, sorted and deduplicated.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Canonical spec string for the resolved schedule — two plans
    /// that replay identically render identically.
    pub fn to_spec(&self) -> String {
        let parts: Vec<String> = self.events.iter().map(|e| e.to_string()).collect();
        parts.join(";")
    }

    /// Does `rank` die at the start of `epoch`? Fires the kill counter
    /// on a hit (callers act on every hit exactly once).
    pub fn should_kill(&self, rank: usize, epoch: u64) -> bool {
        let hit = self
            .events
            .iter()
            .any(|e| e.kind == FaultKind::Kill && e.peer == rank && e.epoch == epoch);
        if hit {
            self.kills_fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// The earliest epoch `rank` is scheduled to die in, if any.
    pub fn kill_epoch(&self, rank: usize) -> Option<u64> {
        self.events
            .iter()
            .filter(|e| e.kind == FaultKind::Kill && e.peer == rank)
            .map(|e| e.epoch)
            .min()
    }

    /// Injected sleep for this branch invocation, if any (the longest
    /// matching delay wins when a blanket and a targeted entry both
    /// apply).
    pub fn branch_delay_us(&self, rank: usize, epoch: u64, branch: usize) -> Option<u64> {
        let us = self
            .events
            .iter()
            .filter(|e| {
                e.kind == FaultKind::Delay
                    && e.peer == rank
                    && e.epoch == epoch
                    && (e.branch.is_none() || e.branch == Some(branch))
            })
            .map(|e| e.delay_us)
            .max();
        if us.is_some() {
            self.delays_fired.fetch_add(1, Ordering::Relaxed);
        }
        us
    }

    /// Should this branch be dispatched twice?
    pub fn duplicate(&self, rank: usize, epoch: u64, branch: usize) -> bool {
        let hit = self.events.iter().any(|e| {
            e.kind == FaultKind::Dup
                && e.peer == rank
                && e.epoch == epoch
                && e.branch == Some(branch)
        });
        if hit {
            self.dups_fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Does any delay/dup entry target `rank`'s branches at all? Used
    /// to decide whether branch indices must ride in the payload.
    pub fn targets_branches(&self, rank: usize) -> bool {
        self.events
            .iter()
            .any(|e| e.peer == rank && e.kind != FaultKind::Kill)
    }

    /// Kills that actually fired.
    pub fn kills_fired(&self) -> u64 {
        self.kills_fired.load(Ordering::Relaxed)
    }

    /// Branch delays that actually fired.
    pub fn delays_fired(&self) -> u64 {
        self.delays_fired.load(Ordering::Relaxed)
    }

    /// Branch duplicates that actually fired.
    pub fn dups_fired(&self) -> u64 {
        self.dups_fired.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let plan = FaultPlanSpec::parse(
            "kill:peer1@2;delay:peer0@3:5ms;delay:peer0.branch3@1:2ms;dup:peer2.branch0@1",
        )
        .unwrap();
        let plan = plan.resolve(4, 4).unwrap();
        assert_eq!(plan.events().len(), 4);
        assert!(plan.should_kill(1, 2));
        assert!(!plan.should_kill(1, 1));
        assert_eq!(plan.branch_delay_us(0, 3, 7), Some(5000));
        assert_eq!(plan.branch_delay_us(0, 1, 3), Some(2000));
        assert_eq!(plan.branch_delay_us(0, 1, 4), None);
        assert!(plan.duplicate(2, 1, 0));
        assert!(!plan.duplicate(2, 1, 1));
        assert_eq!(plan.kills_fired(), 1);
        assert_eq!(plan.delays_fired(), 2);
        assert_eq!(plan.dups_fired(), 1);
    }

    #[test]
    fn empty_plan_is_empty() {
        let plan = FaultPlanSpec::parse("").unwrap();
        assert!(plan.is_empty());
        assert!(plan.resolve(4, 4).unwrap().events().is_empty());
    }

    #[test]
    fn rejects_malformed_entries() {
        assert!(FaultPlanSpec::parse("explode:peer1@2").is_err());
        assert!(FaultPlanSpec::parse("kill:peer1").is_err());
        assert!(FaultPlanSpec::parse("kill:peer1.branch2@1").is_err());
        assert!(FaultPlanSpec::parse("dup:peer1@1").is_err());
        assert!(FaultPlanSpec::parse("delay:peer1@1").is_err());
        assert!(FaultPlanSpec::parse("delay:peer1@1:banana").is_err());
        assert!(FaultPlanSpec::parse("rate:kill=2.0").is_err());
        assert!(FaultPlanSpec::parse("rate:seed=7").is_err());
    }

    #[test]
    fn resolve_bounds_checks_the_cluster_shape() {
        let plan = FaultPlanSpec::parse("kill:peer7@2").unwrap();
        assert!(plan.resolve(4, 4).is_err());
        let plan = FaultPlanSpec::parse("kill:peer1@9").unwrap();
        assert!(plan.resolve(4, 4).is_err());
    }

    #[test]
    fn seeded_rate_resolution_is_deterministic() {
        let spec = FaultPlanSpec::parse("rate:kill=0.5,seed=7").unwrap();
        let a = spec.resolve(8, 4).unwrap();
        let b = spec.resolve(8, 4).unwrap();
        assert_eq!(a.to_spec(), b.to_spec());
        assert_eq!(a.events().len(), 4); // floor(0.5 * 8)
        // rank 0 is always spared; victims are distinct
        let mut victims: Vec<usize> = a.events().iter().map(|e| e.peer).collect();
        assert!(!victims.contains(&0));
        victims.sort_unstable();
        victims.dedup();
        assert_eq!(victims.len(), 4);
        // a different seed picks a different schedule
        let other = FaultPlanSpec::parse("rate:kill=0.5,seed=8")
            .unwrap()
            .resolve(8, 4)
            .unwrap();
        assert_ne!(a.to_spec(), other.to_spec());
    }

    #[test]
    fn rate_always_leaves_a_survivor() {
        let spec = FaultPlanSpec::parse("rate:kill=1.0,seed=1").unwrap();
        let plan = spec.resolve(4, 4).unwrap();
        assert_eq!(plan.events().len(), 3); // capped at peers - 1
    }

    #[test]
    fn canonical_spec_roundtrips() {
        let spec = "delay:peer0.branch3@1:2ms;dup:peer2.branch0@1;kill:peer1@2";
        let plan = FaultPlanSpec::parse(spec).unwrap().resolve(4, 4).unwrap();
        // to_spec renders sorted canonical form; reparsing it resolves
        // to the identical schedule
        let again = FaultPlanSpec::parse(&plan.to_spec())
            .unwrap()
            .resolve(4, 4)
            .unwrap();
        assert_eq!(plan.events(), again.events());
    }
}
