//! The compressed serverless **wire plane**: the codec layer between
//! the coordinator and the object store (§III-B.4 applied to the
//! storage-mediated data path, the bottleneck "Towards Demystifying
//! Serverless ML Training" identifies).
//!
//! Two independent paths, both off by default:
//!
//! - **Params uploads** (`--params-delta-every N`, N > 0): params v(e)
//!   are delta-encoded against v(e−1) — both resident under the lagged
//!   generation sweep — and framed as a *delta frame* that names the
//!   previous generation's object. The handler reconstructs through the
//!   [`DecodedCache`], which memoizes each generation's decoded view
//!   cluster-wide, so the recursion terminates after one hop in steady
//!   state. A *full frame* is emitted for the first generation, every N
//!   generations (the resync cadence), on a generation gap or dimension
//!   change, and whenever the previous generation's object is gone from
//!   the store (restart/eviction) — that last case is the broken-chain
//!   resync counted in `wire.delta_resyncs`.
//! - **Gradient returns** (`--wire-compression qsgd:S|topk:F`): the
//!   gradient Lambda parks its result encoded instead of as dense f32s,
//!   and the collect path decodes right before the `GradAccumulator`
//!   fold.
//!
//! With both knobs off ([`WirePlane::off`]) every byte on the store is
//! identical to the uncompressed plane — no framing, no extra fields,
//! counters all zero — which the cluster invariance test pins down.
//!
//! ## Frame format (params objects, magic `WPv1`)
//!
//! ```text
//! full:  "WPv1" | 0x00 | RawCodec wire of params
//! delta: "WPv1" | 0x01 | u64 prev_gen LE | u32 ref_len LE
//!        | prev ObjectRef wire (ref_len bytes) | inner-codec wire of Δ
//! ```
//!
//! The inner delta codec is the configured `--wire-compression` codec
//! (RawCodec when `none`), seeded by (run seed, generation) only — no
//! peer rank — so synchronous peers emit byte-identical frames and the
//! shared-params dedupe keeps storing one object per epoch. The sender
//! mirrors the receiver's (possibly lossy) reconstruction and commits
//! *that* as the next delta base, so every peer and handler agree on
//! v(e) bit-for-bit even under lossy inner codecs.
//!
//! Unlike [`DeltaCodec`](super::DeltaCodec), whose reference vector is
//! implicit codec state (correct only when encode/decode calls alternate
//! one-to-one on one stream), the params chain is explicitly keyed by
//! generation: the frame itself names the base object, and a decoder can
//! verify and resolve it from the store.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::{codec_for, Codec, RawCodec};
use crate::config::Compression;
use crate::error::{Error, Result};
use crate::store::{DecodedCache, ObjectRef, ObjectStore};
use crate::util::bytes::bytes_to_f32s;
use crate::util::Bytes;

/// Magic prefix of a wire-plane params frame.
pub const FRAME_MAGIC: &[u8; 4] = b"WPv1";
const KIND_FULL: u8 = 0;
const KIND_DELTA: u8 = 1;

/// splitmix64 finalizer — decorrelates the (seed, generation, …) tuples
/// fed to the stochastic quantizer.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn mix(parts: &[u64]) -> u64 {
    parts.iter().fold(0x243f_6a88_85a3_08d3, |h, &p| splitmix(h ^ p))
}

/// The previous generation's upload, tracked by [`ParamsChain`]: the
/// stored object the next delta frame will name, and the receiver-side
/// reconstruction the next delta is computed against.
struct PrevParams {
    generation: u64,
    object: ObjectRef,
    reconstructed: Vec<f32>,
}

/// One peer's generation-keyed params chain. [`WirePlane::encode_params`]
/// reads it to decide full vs delta; the caller commits each successful
/// upload back via [`ParamsChain::commit`] so the chain always points at
/// the newest stored generation.
#[derive(Default)]
pub struct ParamsChain {
    prev: Mutex<Option<PrevParams>>,
}

impl ParamsChain {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record generation `generation`'s stored object and its
    /// receiver-side reconstruction as the next delta base.
    pub fn commit(&self, generation: u64, object: ObjectRef, reconstructed: Vec<f32>) {
        *self.prev.lock().unwrap() =
            Some(PrevParams { generation, object, reconstructed });
    }

    /// Generation the chain currently points at (None before the first
    /// commit).
    pub fn generation(&self) -> Option<u64> {
        self.prev.lock().unwrap().as_ref().map(|p| p.generation)
    }

    /// Re-key the committed base to `generation` without changing the
    /// object or its reconstruction — the shard plane's reuse path: an
    /// unchanged shard ships no new frame, so the base that delta
    /// validity checks against (`base generation + 1 == next`) must
    /// advance with the manifest generation or the next real change
    /// would needlessly resync. No-op before the first commit.
    pub fn rekey(&self, generation: u64) {
        if let Some(p) = self.prev.lock().unwrap().as_mut() {
            p.generation = generation;
        }
    }
}

/// Shared wire-plane state for one cluster run: the two knobs plus the
/// byte/time counters exported as `wire.*` through the
/// `MetricsRegistry`. One instance is shared by every peer's offload and
/// every handler (counters are cluster-wide, like the store's).
pub struct WirePlane {
    compression: Compression,
    params_delta_every: usize,
    seed: u64,
    bytes_raw: AtomicU64,
    bytes_wire: AtomicU64,
    encode_us: AtomicU64,
    decode_us: AtomicU64,
    delta_resyncs: AtomicU64,
}

impl WirePlane {
    pub fn new(compression: Compression, params_delta_every: usize, seed: u64) -> Self {
        Self {
            compression,
            params_delta_every,
            seed,
            bytes_raw: AtomicU64::new(0),
            bytes_wire: AtomicU64::new(0),
            encode_us: AtomicU64::new(0),
            decode_us: AtomicU64::new(0),
            delta_resyncs: AtomicU64::new(0),
        }
    }

    /// A fully disabled plane: both paths byte-identical to the
    /// uncompressed data plane.
    pub fn off() -> Self {
        Self::new(Compression::None, 0, 0)
    }

    pub fn compression(&self) -> Compression {
        self.compression
    }

    pub fn params_delta_every(&self) -> usize {
        self.params_delta_every
    }

    /// Gradient returns are encoded (anything but `none`).
    pub fn grads_on(&self) -> bool {
        self.compression != Compression::None
    }

    /// Params uploads are framed (delta cadence > 0).
    pub fn params_on(&self) -> bool {
        self.params_delta_every > 0
    }

    /// Raw f32 bytes that entered the plane (params + gradients).
    pub fn bytes_raw(&self) -> u64 {
        self.bytes_raw.load(Ordering::Relaxed)
    }

    /// Bytes actually shipped to the store after encoding.
    pub fn bytes_wire(&self) -> u64 {
        self.bytes_wire.load(Ordering::Relaxed)
    }

    /// Wall microseconds spent encoding (params framing + grad parks).
    pub fn encode_us(&self) -> u64 {
        self.encode_us.load(Ordering::Relaxed)
    }

    /// Wall microseconds spent decoding frames and grad parks.
    pub fn decode_us(&self) -> u64 {
        self.decode_us.load(Ordering::Relaxed)
    }

    /// Full-frame resyncs forced by a *missing* previous generation
    /// (restart/eviction) — scheduled cadence fulls and first frames are
    /// not counted.
    pub fn delta_resyncs(&self) -> u64 {
        self.delta_resyncs.load(Ordering::Relaxed)
    }

    /// Inner codec for generation `generation`'s params delta. Seeded by
    /// (run seed, generation) only — never the peer rank — so every
    /// peer's frame bytes are identical and the shared-params dedupe
    /// holds; fresh per call so stochastic codecs start from call 0.
    fn params_codec(&self, generation: u64) -> Box<dyn Codec> {
        codec_for(self.compression, mix(&[self.seed, generation]))
    }

    /// Decode-side codec: every codec's `decode` ignores the seed.
    fn decode_codec(&self) -> Box<dyn Codec> {
        codec_for(self.compression, 0)
    }

    /// Frame params v(`generation`) for upload. Returns the frame bytes
    /// and the receiver-side reconstruction the caller commits to the
    /// chain after storing the frame. Requires [`Self::params_on`].
    pub fn encode_params(
        &self,
        params: &[f32],
        generation: u64,
        chain: &ParamsChain,
        store: &ObjectStore,
    ) -> Result<(Bytes, Vec<f32>)> {
        debug_assert!(self.params_on(), "params path is off");
        let t0 = Instant::now();
        let prev = chain.prev.lock().unwrap();
        // a delta frame is sound only against the *immediately
        // preceding* generation, off the resync cadence, with matching
        // dimensions, whose object is still resolvable by a decoder
        let base = prev.as_ref().filter(|p| {
            p.generation + 1 == generation
                && generation % self.params_delta_every as u64 != 0
                && p.reconstructed.len() == params.len()
        });
        let base = match base {
            Some(p) if store.generation_of(&p.object).is_none() => {
                // the chain's tail is gone (restart, sweep, eviction):
                // resync with a full object instead of corrupting every
                // decode downstream
                self.delta_resyncs.fetch_add(1, Ordering::Relaxed);
                None
            }
            other => other,
        };
        let (frame, reconstructed) = match base {
            Some(p) => {
                let delta: Vec<f32> =
                    params.iter().zip(&p.reconstructed).map(|(a, b)| a - b).collect();
                let codec = self.params_codec(generation);
                let wire = codec.encode(&delta)?;
                // mirror the receiver's (possibly lossy) reconstruction
                // so the next delta is computed against the exact vector
                // every decoder will hold
                let decoded = codec.decode(&wire)?;
                let reconstructed: Vec<f32> = p
                    .reconstructed
                    .iter()
                    .zip(&decoded)
                    .map(|(b, d)| b + d)
                    .collect();
                let ref_wire = p.object.to_wire();
                let mut out = Vec::with_capacity(17 + ref_wire.len() + wire.len());
                out.extend_from_slice(FRAME_MAGIC);
                out.push(KIND_DELTA);
                out.extend_from_slice(&p.generation.to_le_bytes());
                out.extend_from_slice(&(ref_wire.len() as u32).to_le_bytes());
                out.extend_from_slice(&ref_wire);
                out.extend_from_slice(&wire);
                (Bytes::from(out), reconstructed)
            }
            None => {
                let wire = RawCodec.encode(params)?;
                let mut out = Vec::with_capacity(5 + wire.len());
                out.extend_from_slice(FRAME_MAGIC);
                out.push(KIND_FULL);
                out.extend_from_slice(&wire);
                // full frames are lossless: the reconstruction is the
                // params themselves
                (Bytes::from(out), params.to_vec())
            }
        };
        drop(prev);
        self.bytes_raw.fetch_add(params.len() as u64 * 4, Ordering::Relaxed);
        self.bytes_wire.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.encode_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok((frame, reconstructed))
    }

    /// Decoded params view of `r`. With the params path off this is
    /// exactly [`DecodedCache::get_or_decode`]; with it on, the cache
    /// decodes through the frame format, resolving a delta frame's base
    /// generation recursively through the same cache (distinct keys,
    /// strictly older generations — the recursion cannot revisit a key).
    pub fn decode_params(
        &self,
        r: &ObjectRef,
        cache: &DecodedCache,
        store: &ObjectStore,
    ) -> Result<Arc<Vec<f32>>> {
        if !self.params_on() {
            return cache.get_or_decode(r, store);
        }
        cache.get_or_decode_with(r, store, &|bytes| self.decode_frame(bytes, cache, store))
    }

    /// Decode one params frame (the [`DecodedCache`] miss path).
    fn decode_frame(
        &self,
        bytes: &Bytes,
        cache: &DecodedCache,
        store: &ObjectStore,
    ) -> Result<Vec<f32>> {
        if bytes.len() < 5 || &bytes[0..4] != FRAME_MAGIC {
            return Err(Error::Codec(
                "wire plane: params object is not a WPv1 frame".into(),
            ));
        }
        match bytes[4] {
            KIND_FULL => {
                let t0 = Instant::now();
                let out = RawCodec.decode(&Bytes::from(bytes[5..].to_vec()))?;
                self.decode_us
                    .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                Ok(out)
            }
            KIND_DELTA => {
                let body = &bytes[5..];
                if body.len() < 12 {
                    return Err(Error::Codec("wire plane: truncated delta frame".into()));
                }
                let prev_gen = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let ref_len = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
                let rest = &body[12..];
                if rest.len() < ref_len {
                    return Err(Error::Codec("wire plane: truncated delta frame".into()));
                }
                let prev_ref = ObjectRef::from_wire(&rest[..ref_len])?;
                // the base resolves through the same cache: a hit in
                // steady state (the lagged sweep keeps v(e−1) pinned
                // while v(e) is live), a recursive frame decode after a
                // cold start
                let base = self.decode_params(&prev_ref, cache, store).map_err(|e| {
                    Error::Codec(format!(
                        "wire plane: delta frame's base generation {prev_gen} \
                         is unresolvable: {e}"
                    ))
                })?;
                let t0 = Instant::now();
                let delta = self.decode_codec().decode(&Bytes::from(rest[ref_len..].to_vec()))?;
                if delta.len() != base.len() {
                    return Err(Error::Codec(format!(
                        "wire plane: delta dimension {} != base dimension {}",
                        delta.len(),
                        base.len()
                    )));
                }
                let out = base.iter().zip(&delta).map(|(b, d)| b + d).collect();
                self.decode_us
                    .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                Ok(out)
            }
            k => Err(Error::Codec(format!("wire plane: unknown frame kind {k}"))),
        }
    }

    /// Encode one gradient return for parking. Seeded per (run seed,
    /// generation, peer, branch) so no two branches share a quantizer
    /// stream. Requires [`Self::grads_on`].
    pub fn encode_grads(
        &self,
        grads: &[f32],
        generation: u64,
        peer: usize,
        branch: u64,
    ) -> Result<Bytes> {
        debug_assert!(self.grads_on(), "gradient path is off");
        let t0 = Instant::now();
        let codec =
            codec_for(self.compression, mix(&[self.seed, generation, peer as u64, branch]));
        let wire = codec.encode(grads)?;
        self.bytes_raw.fetch_add(grads.len() as u64 * 4, Ordering::Relaxed);
        self.bytes_wire.fetch_add(wire.len() as u64, Ordering::Relaxed);
        self.encode_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(wire)
    }

    /// Decode one parked gradient before the accumulator fold.
    pub fn decode_grads(&self, wire: &Bytes) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let out = self.decode_codec().decode(wire)?;
        self.decode_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Decode raw (unframed) f32 params bytes — the `none` path's
    /// object layout, kept for diagnostics parity.
    pub fn raw_params(bytes: &Bytes) -> Vec<f32> {
        bytes_to_f32s(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::PARAMS_BUCKET;

    fn plane(spec: &str, every: usize) -> WirePlane {
        WirePlane::new(Compression::parse(spec).unwrap(), every, 42)
    }

    /// Integer-valued params so raw delta encode/decode is exact and
    /// equality assertions are meaningful.
    fn params_for(generation: u64, n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32) + (generation as f32) * 3.0).collect()
    }

    fn fixture() -> (Arc<ObjectStore>, DecodedCache) {
        let store = Arc::new(ObjectStore::new());
        store.create_bucket(PARAMS_BUCKET);
        (store, DecodedCache::new(8))
    }

    /// Upload one generation through the plane, committing the chain.
    fn upload(
        plane: &WirePlane,
        chain: &ParamsChain,
        store: &ObjectStore,
        generation: u64,
        params: &[f32],
    ) -> ObjectRef {
        let (frame, recon) =
            plane.encode_params(params, generation, chain, store).unwrap();
        let r = store.put_dedup(PARAMS_BUCKET, frame, generation).unwrap();
        chain.commit(generation, r.clone(), recon);
        r
    }

    #[test]
    fn off_plane_has_no_paths() {
        let p = WirePlane::off();
        assert!(!p.grads_on() && !p.params_on());
        assert_eq!(p.bytes_raw(), 0);
        assert_eq!(p.bytes_wire(), 0);
        assert_eq!(p.delta_resyncs(), 0);
    }

    #[test]
    fn full_then_delta_frames_roundtrip_through_cache() {
        let (store, cache) = fixture();
        let p = plane("none", 4);
        let chain = ParamsChain::new();
        let v1 = params_for(1, 64);
        let r1 = upload(&p, &chain, &store, 1, &v1);
        let frame1 = store.get_ref(&r1).unwrap();
        assert_eq!(&frame1[0..4], FRAME_MAGIC);
        assert_eq!(frame1[4], KIND_FULL);
        assert_eq!(*p.decode_params(&r1, &cache, &store).unwrap(), v1);

        let v2 = params_for(2, 64);
        let r2 = upload(&p, &chain, &store, 2, &v2);
        let frame2 = store.get_ref(&r2).unwrap();
        assert_eq!(frame2[4], KIND_DELTA, "gen 2 off the cadence must be a delta");
        // integer-valued params: raw delta reconstruction is exact
        assert_eq!(*p.decode_params(&r2, &cache, &store).unwrap(), v2);
        assert_eq!(p.delta_resyncs(), 0);
        assert!(p.bytes_wire() > 0 && p.bytes_raw() == 2 * 64 * 4);
    }

    #[test]
    fn rekey_keeps_the_delta_chain_valid_across_a_reuse_gap() {
        // the shard plane's reuse path: generation 2 ships no frame for
        // an unchanged shard; rekeying the committed base to 2 lets
        // generation 3's change delta-encode instead of resyncing
        let (store, cache) = fixture();
        let p = plane("none", 100);
        let chain = ParamsChain::new();
        let v1 = params_for(1, 16);
        upload(&p, &chain, &store, 1, &v1);
        chain.rekey(2); // generation 2 reused the gen-1 object as-is
        assert_eq!(chain.generation(), Some(2));
        let v3 = params_for(3, 16);
        let r3 = upload(&p, &chain, &store, 3, &v3);
        assert_eq!(
            store.get_ref(&r3).unwrap()[4],
            KIND_DELTA,
            "rekeyed base must delta-encode, not resync"
        );
        assert_eq!(*p.decode_params(&r3, &cache, &store).unwrap(), v3);
        assert_eq!(p.delta_resyncs(), 0);
        // rekey before any commit is a no-op
        let fresh = ParamsChain::new();
        fresh.rekey(7);
        assert_eq!(fresh.generation(), None);
    }

    #[test]
    fn delta_base_resolves_recursively_on_cold_cache() {
        let (store, cache) = fixture();
        let p = plane("none", 8);
        let chain = ParamsChain::new();
        let v1 = params_for(1, 32);
        let v2 = params_for(2, 32);
        upload(&p, &chain, &store, 1, &v1);
        let r2 = upload(&p, &chain, &store, 2, &v2);
        // a brand-new cache (cold start): decoding v2's delta frame must
        // recursively decode v1's full frame first
        let cold = DecodedCache::new(8);
        assert_eq!(*p.decode_params(&r2, &cold, &store).unwrap(), v2);
        assert_eq!(cold.misses(), 2, "one miss per frame in the chain");
        // and a second read is a pure hit
        assert_eq!(*p.decode_params(&r2, &cold, &store).unwrap(), v2);
        assert_eq!(cold.hits(), 1);
    }

    #[test]
    fn swept_previous_generation_forces_counted_resync() {
        // satellite regression: a dropped/swept base generation must
        // produce a clean full-object resync, not a silent bad decode
        let (store, cache) = fixture();
        let p = plane("none", 100);
        let chain = ParamsChain::new();
        upload(&p, &chain, &store, 1, &params_for(1, 16));
        let r2 = upload(&p, &chain, &store, 2, &params_for(2, 16));
        assert_eq!(store.get_ref(&r2).unwrap()[4], KIND_DELTA);
        // simulate restart/eviction: gen 2's object disappears
        store.sweep_generation(PARAMS_BUCKET, 2);
        assert!(store.generation_of(&r2).is_none());
        let v3 = params_for(3, 16);
        let r3 = upload(&p, &chain, &store, 3, &v3);
        assert_eq!(
            store.get_ref(&r3).unwrap()[4],
            KIND_FULL,
            "broken chain must resync with a full frame"
        );
        assert_eq!(p.delta_resyncs(), 1);
        assert_eq!(*p.decode_params(&r3, &cache, &store).unwrap(), v3);
    }

    #[test]
    fn cadence_emits_full_frames_every_n_generations() {
        let (store, _cache) = fixture();
        let p = plane("none", 2);
        let chain = ParamsChain::new();
        for generation in 1..=5u64 {
            let r = upload(&p, &chain, &store, generation, &params_for(generation, 8));
            let kind = store.get_ref(&r).unwrap()[4];
            let want = if generation == 1 || generation % 2 == 0 {
                KIND_FULL
            } else {
                KIND_DELTA
            };
            assert_eq!(kind, want, "generation {generation}");
        }
        assert_eq!(p.delta_resyncs(), 0, "cadence fulls are not resyncs");
    }

    #[test]
    fn generation_gap_forces_uncounted_full() {
        let (store, _cache) = fixture();
        let p = plane("none", 100);
        let chain = ParamsChain::new();
        upload(&p, &chain, &store, 1, &params_for(1, 8));
        let r3 = upload(&p, &chain, &store, 3, &params_for(3, 8));
        assert_eq!(store.get_ref(&r3).unwrap()[4], KIND_FULL);
        assert_eq!(p.delta_resyncs(), 0, "a gap is not a broken chain");
    }

    #[test]
    fn synchronous_peers_emit_identical_frames() {
        // the shared-params dedupe depends on frame bytes being
        // rank-independent, lossy inner codec included
        let (store, _cache) = fixture();
        let pa = plane("qsgd:16", 4);
        let pb = plane("qsgd:16", 4);
        let (ca, cb) = (ParamsChain::new(), ParamsChain::new());
        for generation in 1..=3u64 {
            let v = params_for(generation, 128);
            let (fa, ra) = pa.encode_params(&v, generation, &ca, &store).unwrap();
            let (fb, rb) = pb.encode_params(&v, generation, &cb, &store).unwrap();
            assert_eq!(&fa[..], &fb[..], "generation {generation} frames diverge");
            assert_eq!(ra, rb);
            let r = store.put_dedup(PARAMS_BUCKET, fa, generation).unwrap();
            store.put_dedup(PARAMS_BUCKET, fb, generation).unwrap();
            ca.commit(generation, r.clone(), ra);
            cb.commit(generation, r, rb);
        }
    }

    #[test]
    fn lossy_delta_chain_mirrors_receiver_reconstruction() {
        // under a lossy inner codec the decoded view drifts from the
        // true params, but sender and receiver must agree bit-for-bit
        let (store, cache) = fixture();
        let p = plane("qsgd:16", 10);
        let chain = ParamsChain::new();
        for generation in 1..=4u64 {
            let v: Vec<f32> =
                (0..256).map(|i| ((i * 7 + generation as usize * 13) % 97) as f32 * 0.01).collect();
            let r = upload(&p, &chain, &store, generation, &v);
            let decoded = p.decode_params(&r, &cache, &store).unwrap();
            let committed = chain.prev.lock().unwrap();
            assert_eq!(
                *decoded,
                committed.as_ref().unwrap().reconstructed,
                "generation {generation}: receiver and sender views diverge"
            );
        }
    }

    #[test]
    fn grads_roundtrip_and_count_bytes() {
        let p = plane("qsgd:16", 0);
        assert!(p.grads_on() && !p.params_on());
        let grads: Vec<f32> = (0..4096).map(|i| (i as f32) * 1e-3 - 2.0).collect();
        let wire = p.encode_grads(&grads, 3, 1, 7).unwrap();
        let back = p.decode_grads(&wire).unwrap();
        assert_eq!(back.len(), grads.len());
        assert_eq!(p.bytes_raw(), 4096 * 4);
        assert_eq!(p.bytes_wire(), wire.len() as u64);
        // qsgd:16 is 6 bits/elem + 10-byte header: well under a quarter
        assert!(p.bytes_wire() * 4 <= p.bytes_raw());
    }

    #[test]
    fn frame_decode_rejects_garbage() {
        let (store, cache) = fixture();
        let p = plane("none", 4);
        // an unframed (raw f32) object is not a frame
        let raw = store
            .put_dedup(PARAMS_BUCKET, Bytes::from(vec![0u8; 16]), 1)
            .unwrap();
        assert!(p.decode_params(&raw, &cache, &store).is_err());
        // truncated delta body
        let mut bad = FRAME_MAGIC.to_vec();
        bad.push(KIND_DELTA);
        bad.extend_from_slice(&[0u8; 4]);
        let bad = store.put_dedup(PARAMS_BUCKET, Bytes::from(bad), 2).unwrap();
        assert!(p.decode_params(&bad, &cache, &store).is_err());
        // unknown kind
        let mut odd = FRAME_MAGIC.to_vec();
        odd.push(9);
        odd.extend_from_slice(&RawCodec.encode(&[1.0]).unwrap());
        let odd = store.put_dedup(PARAMS_BUCKET, Bytes::from(odd), 3).unwrap();
        assert!(p.decode_params(&odd, &cache, &store).is_err());
    }

    #[test]
    fn seed_mix_separates_streams() {
        assert_ne!(mix(&[1, 2]), mix(&[2, 1]));
        assert_ne!(mix(&[1, 2, 3, 4]), mix(&[1, 2, 3, 5]));
    }
}
