//! Delta compression: transmit the change against the previously-sent
//! vector, encoded with any inner codec ("Delta compression" pointer in
//! the paper's §VI-B). Stateful per direction — sender and receiver each
//! keep their own `DeltaCodec` with mirrored reference state.
//!
//! **Statefulness contract** (cross-epoch audit): the reference is
//! *implicit* — correctness requires encode/decode calls to alternate
//! one-to-one on a single ordered stream, and nothing on the wire says
//! which reference a frame was encoded against. That is fine for the
//! broker exchange path (one FIFO stream per peer pair,
//! [`DeltaCodec::reset`] on reconnect) but unsafe for store-mediated
//! params uploads, where a
//! restarted or cache-evicted reader has no way to detect a desynced
//! reference. The serverless wire plane therefore does **not** use this
//! codec: its params chain ([`crate::compress::WirePlane`]) keys every
//! delta frame by generation and embeds the base object's reference, so
//! a broken chain is detected and resynced with a full object instead
//! of silently decoding against the wrong base.

use crate::util::Bytes;
use std::sync::Mutex;

use super::Codec;
use crate::error::Result;

pub struct DeltaCodec<C: Codec> {
    inner: C,
    /// Last full vector this side has synchronized on.
    reference: Mutex<Option<Vec<f32>>>,
}

impl<C: Codec> DeltaCodec<C> {
    pub fn new(inner: C) -> Self {
        Self { inner, reference: Mutex::new(None) }
    }

    pub fn reset(&self) {
        *self.reference.lock().unwrap() = None;
    }

    /// Whether this side currently holds a synchronized reference —
    /// callers that cannot guarantee the one-to-one stream contract
    /// (see module docs) can check and [`Self::reset`] explicitly.
    pub fn has_reference(&self) -> bool {
        self.reference.lock().unwrap().is_some()
    }
}

impl<C: Codec> Codec for DeltaCodec<C> {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn encode(&self, v: &[f32]) -> Result<Bytes> {
        let mut guard = self.reference.lock().unwrap();
        let delta: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == v.len() => {
                v.iter().zip(prev).map(|(a, b)| a - b).collect()
            }
            _ => v.to_vec(),
        };
        let wire = self.inner.encode(&delta)?;
        // the receiver reconstructs reference + decode(delta); mirror that
        // here (inner may be lossy) so both sides stay in lockstep.
        let decoded_delta = self.inner.decode(&wire)?;
        let new_ref: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == v.len() => {
                prev.iter().zip(&decoded_delta).map(|(p, d)| p + d).collect()
            }
            _ => decoded_delta,
        };
        *guard = Some(new_ref);
        Ok(wire)
    }

    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>> {
        let delta = self.inner.decode(wire)?;
        let mut guard = self.reference.lock().unwrap();
        let out: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == delta.len() => {
                prev.iter().zip(&delta).map(|(p, d)| p + d).collect()
            }
            _ => delta,
        };
        *guard = Some(out.clone());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::RawCodec;

    #[test]
    fn lossless_inner_roundtrips_sequences() {
        let tx = DeltaCodec::new(RawCodec);
        let rx = DeltaCodec::new(RawCodec);
        let seqs = [
            vec![1.0f32, 2.0, 3.0],
            vec![1.5, 2.0, 2.5],
            vec![1.5, 2.0, 2.5],
            vec![-4.0, 0.0, 10.0],
        ];
        for v in &seqs {
            let wire = tx.encode(v).unwrap();
            let out = rx.decode(&wire).unwrap();
            for (a, b) in v.iter().zip(&out) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn repeated_vector_is_cheap_with_sparse_inner() {
        use crate::compress::TopkCodec;
        // after the first send, deltas are ~zero → top-k wire stays tiny
        let tx = DeltaCodec::new(TopkCodec::new(1.0));
        let v: Vec<f32> = (0..256).map(|i| (i as f32).cos()).collect();
        let w1 = tx.encode(&v).unwrap();
        let _ = w1;
        // second identical send: delta is exactly zero
        let tx2 = DeltaCodec::new(RawCodec);
        tx2.encode(&v).unwrap();
        let w2 = tx2.encode(&v).unwrap();
        let decoded = RawCodec.decode(&w2).unwrap();
        assert!(decoded.iter().all(|&d| d.abs() < 1e-6));
    }

    #[test]
    fn reset_clears_reference() {
        let tx = DeltaCodec::new(RawCodec);
        let v = vec![5.0f32; 8];
        assert!(!tx.has_reference());
        tx.encode(&v).unwrap();
        assert!(tx.has_reference());
        tx.reset();
        assert!(!tx.has_reference());
        let wire = tx.encode(&v).unwrap();
        // after reset the full vector is sent, not a zero delta
        let raw = RawCodec.decode(&wire).unwrap();
        assert_eq!(raw, v);
    }

    #[test]
    fn desynced_stream_is_undetectable_on_the_wire() {
        // the audit's pinned-down hazard: a receiver that missed one
        // frame decodes the next one without any error — the wire
        // carries no reference identity. This is why the store-mediated
        // params path uses generation-keyed frames instead.
        let tx = DeltaCodec::new(RawCodec);
        let rx = DeltaCodec::new(RawCodec);
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![2.0f32, 4.0, 6.0];
        let c = vec![3.0f32, 6.0, 9.0];
        rx.decode(&tx.encode(&a).unwrap()).unwrap();
        let _dropped = tx.encode(&b).unwrap();
        let out = rx.decode(&tx.encode(&c).unwrap()).unwrap();
        // decodes "successfully" to the wrong vector
        assert_ne!(out, c);
    }

    #[test]
    fn dimension_change_resets_reference() {
        let tx = DeltaCodec::new(RawCodec);
        tx.encode(&[1.0, 2.0]).unwrap();
        let wire = tx.encode(&[3.0, 4.0, 5.0]).unwrap();
        assert_eq!(RawCodec.decode(&wire).unwrap(), vec![3.0, 4.0, 5.0]);
    }
}
