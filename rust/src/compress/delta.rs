//! Delta compression: transmit the change against the previously-sent
//! vector, encoded with any inner codec ("Delta compression" pointer in
//! the paper's §VI-B). Stateful per direction — sender and receiver each
//! keep their own `DeltaCodec` with mirrored reference state.

use crate::util::Bytes;
use std::sync::Mutex;

use super::Codec;
use crate::error::Result;

pub struct DeltaCodec<C: Codec> {
    inner: C,
    /// Last full vector this side has synchronized on.
    reference: Mutex<Option<Vec<f32>>>,
}

impl<C: Codec> DeltaCodec<C> {
    pub fn new(inner: C) -> Self {
        Self { inner, reference: Mutex::new(None) }
    }

    pub fn reset(&self) {
        *self.reference.lock().unwrap() = None;
    }
}

impl<C: Codec> Codec for DeltaCodec<C> {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn encode(&self, v: &[f32]) -> Result<Bytes> {
        let mut guard = self.reference.lock().unwrap();
        let delta: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == v.len() => {
                v.iter().zip(prev).map(|(a, b)| a - b).collect()
            }
            _ => v.to_vec(),
        };
        let wire = self.inner.encode(&delta)?;
        // the receiver reconstructs reference + decode(delta); mirror that
        // here (inner may be lossy) so both sides stay in lockstep.
        let decoded_delta = self.inner.decode(&wire)?;
        let new_ref: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == v.len() => {
                prev.iter().zip(&decoded_delta).map(|(p, d)| p + d).collect()
            }
            _ => decoded_delta,
        };
        *guard = Some(new_ref);
        Ok(wire)
    }

    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>> {
        let delta = self.inner.decode(wire)?;
        let mut guard = self.reference.lock().unwrap();
        let out: Vec<f32> = match guard.as_ref() {
            Some(prev) if prev.len() == delta.len() => {
                prev.iter().zip(&delta).map(|(p, d)| p + d).collect()
            }
            _ => delta,
        };
        *guard = Some(out.clone());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::RawCodec;

    #[test]
    fn lossless_inner_roundtrips_sequences() {
        let tx = DeltaCodec::new(RawCodec);
        let rx = DeltaCodec::new(RawCodec);
        let seqs = [
            vec![1.0f32, 2.0, 3.0],
            vec![1.5, 2.0, 2.5],
            vec![1.5, 2.0, 2.5],
            vec![-4.0, 0.0, 10.0],
        ];
        for v in &seqs {
            let wire = tx.encode(v).unwrap();
            let out = rx.decode(&wire).unwrap();
            for (a, b) in v.iter().zip(&out) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn repeated_vector_is_cheap_with_sparse_inner() {
        use crate::compress::TopkCodec;
        // after the first send, deltas are ~zero → top-k wire stays tiny
        let tx = DeltaCodec::new(TopkCodec::new(1.0));
        let v: Vec<f32> = (0..256).map(|i| (i as f32).cos()).collect();
        let w1 = tx.encode(&v).unwrap();
        let _ = w1;
        // second identical send: delta is exactly zero
        let tx2 = DeltaCodec::new(RawCodec);
        tx2.encode(&v).unwrap();
        let w2 = tx2.encode(&v).unwrap();
        let decoded = RawCodec.decode(&w2).unwrap();
        assert!(decoded.iter().all(|&d| d.abs() < 1e-6));
    }

    #[test]
    fn reset_clears_reference() {
        let tx = DeltaCodec::new(RawCodec);
        let v = vec![5.0f32; 8];
        tx.encode(&v).unwrap();
        tx.reset();
        let wire = tx.encode(&v).unwrap();
        // after reset the full vector is sent, not a zero delta
        let raw = RawCodec.decode(&wire).unwrap();
        assert_eq!(raw, v);
    }

    #[test]
    fn dimension_change_resets_reference() {
        let tx = DeltaCodec::new(RawCodec);
        tx.encode(&[1.0, 2.0]).unwrap();
        let wire = tx.encode(&[3.0, 4.0, 5.0]).unwrap();
        assert_eq!(RawCodec.decode(&wire).unwrap(), vec![3.0, 4.0, 5.0]);
    }
}
