//! Gradient compression codecs for the exchange path (§III-B.4).
//!
//! The paper adopts QSGD (Alistarh et al., NeurIPS'17) to quantize
//! gradients before RabbitMQ transmission; the discussion section also
//! points to sparsification and delta compression, both provided here.
//!
//! All codecs speak a common wire format framed by [`Codec`]:
//! `encode(&[f32]) -> Bytes` / `decode(&bytes) -> Vec<f32>`; `decode`
//! must accept exactly what `encode` produced (property-tested in
//! `rust/tests/prop_compress.rs`).
//!
//! [`WirePlane`] lifts these codecs into the serverless data plane:
//! delta-framed params uploads and quantized gradient parks through the
//! object store, with `wire.*` byte/time accounting.

mod delta;
mod qsgd;
mod topk;
mod wire;

pub use delta::DeltaCodec;
pub use qsgd::QsgdCodec;
pub use topk::TopkCodec;
pub use wire::{ParamsChain, WirePlane};

use crate::util::Bytes;

use crate::config::Compression;
use crate::error::Result;

/// A gradient codec. Implementations may be lossy (QSGD, top-k) but must
/// be dimension-preserving: `decode(encode(v)).len() == v.len()`.
pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;
    fn encode(&self, v: &[f32]) -> Result<Bytes>;
    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>>;
}

/// Lossless identity codec: raw little-endian f32s.
#[derive(Debug, Default, Clone)]
pub struct RawCodec;

impl Codec for RawCodec {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn encode(&self, v: &[f32]) -> Result<Bytes> {
        let mut out = Vec::with_capacity(4 + v.len() * 4);
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        Ok(Bytes::from(out))
    }

    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>> {
        use crate::error::Error;
        if wire.len() < 4 {
            return Err(Error::Codec("raw: truncated header".into()));
        }
        let n = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        if wire.len() != 4 + n * 4 {
            return Err(Error::Codec(format!(
                "raw: expected {} bytes, got {}",
                4 + n * 4,
                wire.len()
            )));
        }
        Ok(wire[4..]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }
}

/// Build the codec a [`Compression`] config names. `seed` feeds the
/// stochastic quantizer so runs stay reproducible.
pub fn codec_for(compression: Compression, seed: u64) -> Box<dyn Codec> {
    match compression {
        Compression::None => Box::new(RawCodec),
        Compression::Qsgd { s } => Box::new(QsgdCodec::new(s, seed)),
        Compression::Topk { frac } => Box::new(TopkCodec::new(frac)),
    }
}

/// Compression statistics for reporting (fig 5 harness).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionStats {
    pub raw_bytes: usize,
    pub wire_bytes: usize,
}

impl CompressionStats {
    pub fn ratio(&self) -> f64 {
        if self.wire_bytes == 0 {
            return 0.0;
        }
        self.raw_bytes as f64 / self.wire_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let c = RawCodec;
        let wire = c.encode(&v).unwrap();
        assert_eq!(c.decode(&wire).unwrap(), v);
        assert_eq!(wire.len(), 4 + 16);
    }

    #[test]
    fn raw_rejects_corrupt() {
        let c = RawCodec;
        assert!(c.decode(&Bytes::from_static(&[1, 2])).is_err());
        let mut wire = c.encode(&[1.0, 2.0]).unwrap().to_vec();
        wire.pop();
        assert!(c.decode(&Bytes::from(wire)).is_err());
    }

    #[test]
    fn codec_for_dispatch() {
        use crate::config::Compression as C;
        assert_eq!(codec_for(C::None, 0).name(), "raw");
        assert_eq!(codec_for(C::Qsgd { s: 4 }, 0).name(), "qsgd");
        assert_eq!(codec_for(C::Topk { frac: 0.1 }, 0).name(), "topk");
    }

    #[test]
    fn stats_ratio() {
        let s = CompressionStats { raw_bytes: 400, wire_bytes: 100 };
        assert!((s.ratio() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_vector_roundtrips() {
        for codec in [
            codec_for(Compression::None, 1),
            codec_for(Compression::Qsgd { s: 8 }, 1),
            codec_for(Compression::Topk { frac: 0.5 }, 1),
        ] {
            let wire = codec.encode(&[]).unwrap();
            assert_eq!(codec.decode(&wire).unwrap(), Vec::<f32>::new());
        }
    }
}
