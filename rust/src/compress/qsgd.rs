//! QSGD stochastic quantization (Alistarh et al., NeurIPS'17) — the
//! paper's gradient compressor.
//!
//! Semantics match the L1 Pallas kernel (`python/compile/kernels/qsgd.py`)
//! exactly, so the rust codec and the AOT kernel cross-validate on the
//! same inputs (`rust/tests/qsgd_cross_validation.rs`):
//!
//! ```text
//! norm    = ||v||_2
//! level_i = floor(|v_i| / norm * s + u_i),  u_i ~ U[0,1)
//! Q(v_i)  = sgn(v_i) * level_i * norm / s            (unbiased)
//! ```
//!
//! Wire format (little-endian):
//! `u32 n | f32 norm | u8 s | u8 bits | ceil(n*bits/8) packed bytes` where each element is zigzag(sign*level) in `bits = ceil(log2(2s+1))`
//! bits. For s=16 that is 6 bits/element — a 5.3x wire reduction vs f32,
//! on top of which the paper's fig 5 send/recv improvement is computed.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::Bytes;

use super::Codec;
use crate::util::Rng;
use crate::error::{Error, Result};

#[derive(Debug)]
pub struct QsgdCodec {
    s: u8,
    seed: u64,
    calls: AtomicU64,
}

impl QsgdCodec {
    pub fn new(s: u8, seed: u64) -> Self {
        assert!(s >= 1, "QSGD needs at least one level");
        Self { s, seed, calls: AtomicU64::new(0) }
    }

    pub fn levels(&self) -> u8 {
        self.s
    }

    /// Bits per element on the wire.
    pub fn bits_per_elem(&self) -> u32 {
        let vals = 2 * self.s as u32 + 1; // levels in [-s, s]
        32 - (vals - 1).leading_zeros()
    }

    /// Quantize with explicit noise — the deterministic core used by both
    /// `encode` and the kernel cross-validation tests.
    pub fn quantize_with_noise(&self, v: &[f32], u: &[f32]) -> (Vec<i32>, f32) {
        assert_eq!(v.len(), u.len());
        let norm = l2(v);
        if norm <= 0.0 {
            return (vec![0; v.len()], 0.0);
        }
        let s = self.s as f32;
        let q = v
            .iter()
            .zip(u)
            .map(|(&x, &ui)| {
                let level = (x.abs() / norm * s + ui).floor();
                (x.signum() * level) as i32
            })
            .collect();
        (q, norm)
    }

    /// Reconstruct: `q * norm / s`.
    pub fn dequantize(&self, q: &[i32], norm: f32) -> Vec<f32> {
        let scale = norm / self.s as f32;
        q.iter().map(|&l| l as f32 * scale).collect()
    }

}

fn l2(v: &[f32]) -> f32 {
    // f64 accumulation: gradients run to 1e8 elements for VGG-scale specs
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

// ------------------------------------------------------------ bitpack

fn zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

fn unzigzag(v: u32) -> i32 {
    ((v >> 1) as i32) ^ -((v & 1) as i32)
}

fn pack(values: &[i32], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; (values.len() * bits as usize).div_ceil(8)];
    let mut bitpos = 0usize;
    for &v in values {
        let z = zigzag(v) as u64;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        // write up to bits+7 bits spanning <= 5 bytes
        let mut acc = z << off;
        let mut i = 0;
        while acc != 0 || i == 0 {
            if byte + i < out.len() {
                out[byte + i] |= (acc & 0xff) as u8;
            }
            acc >>= 8;
            i += 1;
        }
        bitpos += bits as usize;
    }
    out
}

fn unpack(data: &[u8], n: usize, bits: u32) -> Vec<i32> {
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut acc = 0u64;
        for i in 0..=((off + bits as usize).div_ceil(8)) {
            if byte + i < data.len() {
                acc |= (data[byte + i] as u64) << (8 * i);
            }
        }
        out.push(unzigzag(((acc >> off) & mask) as u32));
        bitpos += bits as usize;
    }
    out
}

impl Codec for QsgdCodec {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    /// Streaming encode: noise -> stochastic level -> zigzag -> bitpack
    /// in ONE pass, no intermediate vectors. At VGG scale (132.9M
    /// elements) the naive three-pass version moves ~1.6 GB of
    /// intermediates through memory; fusing brought encode from 6.0 s to
    /// well under half (EXPERIMENTS.md SSPerf iteration 1).
    fn encode(&self, v: &[f32]) -> Result<Bytes> {
        let norm = l2(v);
        let bits = self.bits_per_elem();
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        let mut rng = Rng::seed_from_u64(self.seed ^ call.wrapping_mul(0x2545F4914F6CDD1D));

        let packed_len = (v.len() * bits as usize).div_ceil(8);
        let mut out = Vec::with_capacity(10 + packed_len + 8);
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(&norm.to_le_bytes());
        out.push(self.s);
        out.push(bits as u8);

        let scale = if norm > 0.0 { self.s as f32 / norm } else { 0.0 };
        // bit accumulator: flush whole bytes as they fill
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        for &x in v {
            let level = (x.abs() * scale + rng.gen_f32()).floor();
            let q = (x.signum() * level) as i32;
            acc |= (zigzag(q) as u64) << nbits;
            nbits += bits;
            while nbits >= 8 {
                out.push((acc & 0xff) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            out.push((acc & 0xff) as u8);
        }
        debug_assert_eq!(out.len(), 10 + packed_len);
        Ok(Bytes::from(out))
    }

    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>> {
        if wire.len() < 10 {
            return Err(Error::Codec("qsgd: truncated header".into()));
        }
        let n = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        let norm = f32::from_le_bytes(wire[4..8].try_into().unwrap());
        let s = wire[8];
        let bits = wire[9] as u32;
        let need = 10 + (n * bits as usize).div_ceil(8);
        if wire.len() != need {
            return Err(Error::Codec(format!(
                "qsgd: expected {need} bytes, got {}",
                wire.len()
            )));
        }
        if s == 0 {
            return Err(Error::Codec("qsgd: s must be >= 1".into()));
        }
        // streaming unpack + dequantize in one pass (no Vec<i32>)
        let scale = norm / s as f32;
        let data = &wire[10..];
        let mask = (1u64 << bits) - 1;
        let mut out = Vec::with_capacity(n);
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut byte = 0usize;
        for _ in 0..n {
            while nbits < bits {
                acc |= (data.get(byte).copied().unwrap_or(0) as u64) << nbits;
                byte += 1;
                nbits += 8;
            }
            let z = (acc & mask) as u32;
            acc >>= bits;
            nbits -= bits;
            out.push(unzigzag(z) as f32 * scale);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecf(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.gen_range_f32(-2.0, 2.0)).collect()
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-17, -1, 0, 1, 16, i32::MIN / 2, i32::MAX / 2] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let values: Vec<i32> = (-16..=16).collect();
        for bits in [6u32, 7, 8, 13] {
            let packed = pack(&values, bits);
            assert_eq!(unpack(&packed, values.len(), bits), values);
        }
    }

    #[test]
    fn bits_per_elem_matches_levels() {
        assert_eq!(QsgdCodec::new(1, 0).bits_per_elem(), 2); // {-1,0,1}
        assert_eq!(QsgdCodec::new(4, 0).bits_per_elem(), 4); // 9 values
        assert_eq!(QsgdCodec::new(16, 0).bits_per_elem(), 6); // 33 values
        assert_eq!(QsgdCodec::new(127, 0).bits_per_elem(), 8); // 255 values
    }

    #[test]
    fn roundtrip_error_bounded() {
        let c = QsgdCodec::new(16, 7);
        let v = vecf(3, 1000);
        let wire = c.encode(&v).unwrap();
        let out = c.decode(&wire).unwrap();
        assert_eq!(out.len(), v.len());
        let norm = l2(&v);
        let bound = norm / 16.0 + 1e-5;
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() <= bound, "{a} vs {b} bound {bound}");
        }
    }

    #[test]
    fn wire_smaller_than_raw() {
        let c = QsgdCodec::new(16, 7);
        let v = vecf(4, 10_000);
        let wire = c.encode(&v).unwrap();
        let raw = 4 * v.len();
        assert!(
            (wire.len() as f64) < raw as f64 / 4.0,
            "wire {} vs raw {raw}",
            wire.len()
        );
    }

    #[test]
    fn unbiased_over_many_encodings() {
        let c = QsgdCodec::new(4, 99);
        let v = vecf(5, 64);
        let reps = 600;
        let mut acc = vec![0f64; v.len()];
        for _ in 0..reps {
            let out = c.decode(&c.encode(&v).unwrap()).unwrap();
            for (a, o) in acc.iter_mut().zip(out) {
                *a += o as f64;
            }
        }
        let norm = l2(&v) as f64;
        let tol = 5.0 * norm / 4.0 / (reps as f64).sqrt();
        for (a, want) in acc.iter().zip(&v) {
            let mean = a / reps as f64;
            assert!(
                (mean - *want as f64).abs() < tol,
                "mean {mean} want {want} tol {tol}"
            );
        }
    }

    #[test]
    fn zero_vector() {
        let c = QsgdCodec::new(8, 1);
        let v = vec![0.0f32; 37];
        let out = c.decode(&c.encode(&v).unwrap()).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn matches_kernel_semantics_with_fixed_noise() {
        // golden check against the formula (mirrors the pallas ref)
        let c = QsgdCodec::new(4, 0);
        let v = [1.0f32, -0.5, 0.25, 0.0];
        let u = [0.0f32, 0.999, 0.5, 0.5];
        let norm = l2(&v);
        let (q, n) = c.quantize_with_noise(&v, &u);
        assert!((n - norm).abs() < 1e-6);
        // |1.0|/norm*4 = 3.49 + 0.0 -> 3;  |-0.5|/norm*4 = 1.74+0.999 -> 2 (neg)
        // |0.25|/norm*4 = 0.87+0.5 -> 1;   0 -> 0
        assert_eq!(q, vec![3, -2, 1, 0]);
    }

    #[test]
    fn decode_rejects_corrupt() {
        let c = QsgdCodec::new(8, 1);
        assert!(c.decode(&Bytes::from_static(&[0u8; 3])).is_err());
        let mut wire = c.encode(&[1.0, 2.0, 3.0]).unwrap().to_vec();
        wire.truncate(wire.len() - 1);
        assert!(c.decode(&Bytes::from(wire)).is_err());
    }
}
