//! Top-k sparsification: keep the `frac` largest-magnitude coordinates
//! (the "model sparsification" direction the paper's §VI-B discussion
//! recommends for communication reduction).
//!
//! Wire format: u32 n | u32 k | k * (u32 index, f32 value).

use crate::util::Bytes;

use super::Codec;
use crate::error::{Error, Result};

#[derive(Debug, Clone)]
pub struct TopkCodec {
    frac: f32,
}

impl TopkCodec {
    pub fn new(frac: f32) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "frac must be in (0,1]");
        Self { frac }
    }

    pub fn k_for(&self, n: usize) -> usize {
        ((n as f64 * self.frac as f64).ceil() as usize).clamp(usize::from(n > 0), n)
    }
}

impl Codec for TopkCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode(&self, v: &[f32]) -> Result<Bytes> {
        let k = if v.is_empty() { 0 } else { self.k_for(v.len()) };
        let mut idx: Vec<u32> = (0..v.len() as u32).collect();
        // partial selection by |value| descending
        if k < v.len() {
            idx.select_nth_unstable_by(k, |&a, &b| {
                v[b as usize]
                    .abs()
                    .partial_cmp(&v[a as usize].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(k);
        }
        idx.sort_unstable(); // deterministic wire, cache-friendly decode
        let mut out = Vec::with_capacity(8 + idx.len() * 8);
        out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        for &i in &idx {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&v[i as usize].to_le_bytes());
        }
        Ok(Bytes::from(out))
    }

    fn decode(&self, wire: &Bytes) -> Result<Vec<f32>> {
        if wire.len() < 8 {
            return Err(Error::Codec("topk: truncated header".into()));
        }
        let n = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        let k = u32::from_le_bytes(wire[4..8].try_into().unwrap()) as usize;
        if wire.len() != 8 + k * 8 {
            return Err(Error::Codec(format!(
                "topk: expected {} bytes, got {}",
                8 + k * 8,
                wire.len()
            )));
        }
        let mut out = vec![0f32; n];
        for chunk in wire[8..].chunks_exact(8) {
            let i = u32::from_le_bytes(chunk[0..4].try_into().unwrap()) as usize;
            let val = f32::from_le_bytes(chunk[4..8].try_into().unwrap());
            if i >= n {
                return Err(Error::Codec(format!("topk: index {i} >= n {n}")));
            }
            out[i] = val;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest() {
        let c = TopkCodec::new(0.25);
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3];
        let out = c.decode(&c.encode(&v).unwrap()).unwrap();
        // k = 2 of 8: -5.0 and 3.0 survive
        assert_eq!(out[1], -5.0);
        assert_eq!(out[3], 3.0);
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn frac_one_is_lossless() {
        let c = TopkCodec::new(1.0);
        let v: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        assert_eq!(c.decode(&c.encode(&v).unwrap()).unwrap(), v);
    }

    #[test]
    fn k_at_least_one() {
        let c = TopkCodec::new(0.0001);
        assert_eq!(c.k_for(10), 1);
        let v = vec![0.0, 7.0, 0.0];
        let out = c.decode(&c.encode(&v).unwrap()).unwrap();
        assert_eq!(out[1], 7.0);
    }

    #[test]
    fn wire_size_scales_with_k() {
        let v: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let small = TopkCodec::new(0.01).encode(&v).unwrap().len();
        let big = TopkCodec::new(0.5).encode(&v).unwrap().len();
        assert!(small < big);
        assert!(small < 4 * v.len() / 10);
    }

    #[test]
    fn decode_rejects_bad_index() {
        let c = TopkCodec::new(0.5);
        let mut wire = c.encode(&[1.0, 2.0]).unwrap().to_vec();
        // corrupt first index to 9
        wire[8..12].copy_from_slice(&9u32.to_le_bytes());
        assert!(c.decode(&Bytes::from(wire)).is_err());
    }
}
