//! Experiment / training configuration (JSON files + CLI overridable).
//!
//! Every runnable surface (CLI, examples, harness drivers, benches) is
//! driven by a [`TrainConfig`]; JSON files under `configs/` (or inline
//! defaults) describe the paper's workloads.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::Json;

/// Which gradient-computation backend a peer uses (the paper's two
/// architectures from §V-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Sequential per-batch gradients on the peer's own EC2 instance.
    #[default]
    Instance,
    /// Per-batch gradients fanned out to Lambda via a Step Functions
    /// dynamic Map state (the paper's contribution).
    Serverless,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "instance" => Ok(Self::Instance),
            "serverless" => Ok(Self::Serverless),
            _ => Err(Error::Config(format!("unknown backend {s:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Instance => "instance",
            Self::Serverless => "serverless",
        }
    }
}

/// How the serverless offload dispatches an epoch's branches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OffloadMode {
    /// Upload everything, execute the Map state, then collect — the
    /// reference implementation of the modeled wall.
    Staged,
    /// Stream each batch through the cluster-wide branch scheduler as
    /// its upload lands; gradients fold in while later batches upload.
    /// Modeled numbers are byte-identical to staged; the measured wall
    /// shows the overlap.
    #[default]
    Pipelined,
    /// Cross-epoch pipelining: epoch e+1's params upload and branch
    /// dispatch happen *before* the epoch-e convergence eval / barrier /
    /// verdict wait, keyed by the generation tag so folds never mix
    /// param versions, and the scratch sweep lags one live generation.
    /// The pool stays fed across the epoch boundary; modeled numbers
    /// remain byte-identical to staged at any `pipeline_depth`.
    CrossEpoch,
}

impl OffloadMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "staged" => Ok(Self::Staged),
            "pipelined" | "pipeline" => Ok(Self::Pipelined),
            "cross-epoch" | "cross_epoch" | "crossepoch" => Ok(Self::CrossEpoch),
            _ => Err(Error::Config(format!("unknown offload mode {s:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Staged => "staged",
            Self::Pipelined => "pipelined",
            Self::CrossEpoch => "cross-epoch",
        }
    }
}

/// What the cluster does when a peer is declared dead mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FailurePolicy {
    /// Fail fast: the first peer failure aborts the whole run — the
    /// pre-membership behavior, and still the default.
    #[default]
    Abort,
    /// A surviving peer re-dispatches the dead peer's batch partition
    /// (the refs are epoch-persistent in the object store, so nothing
    /// is re-uploaded) and publishes gradients on its behalf: the run
    /// completes every epoch with zero lost branches.
    Takeover,
    /// Dead peers leave the exchange: survivors average over the
    /// remaining gradients and the dead partition's branches are lost.
    Drop,
}

impl FailurePolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "abort" => Ok(Self::Abort),
            "takeover" => Ok(Self::Takeover),
            "drop" => Ok(Self::Drop),
            _ => Err(Error::Config(format!("unknown failure policy {s:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Abort => "abort",
            Self::Takeover => "takeover",
            Self::Drop => "drop",
        }
    }
}

/// Synchronisation mode for the gradient exchange (§III-B.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// RabbitMQ barrier queue: all peers finish an epoch together.
    #[default]
    Synchronous,
    /// Consume whatever latest gradients are available (possibly stale).
    Asynchronous,
}

impl SyncMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sync" | "synchronous" => Ok(Self::Synchronous),
            "async" | "asynchronous" => Ok(Self::Asynchronous),
            _ => Err(Error::Config(format!("unknown sync mode {s:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Synchronous => "synchronous",
            Self::Asynchronous => "asynchronous",
        }
    }
}

/// Gradient compression on the exchange path (§III-B.4).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Compression {
    #[default]
    None,
    /// QSGD stochastic quantization with `s` levels (bit-packed wire).
    Qsgd { s: u8 },
    /// Top-k sparsification keeping `frac` of coordinates.
    Topk { frac: f32 },
}

impl Compression {
    /// Parse `"none"`, `"qsgd:16"`, `"topk:0.05"`.
    pub fn parse(s: &str) -> Result<Self> {
        if s == "none" {
            return Ok(Self::None);
        }
        if let Some(levels) = s.strip_prefix("qsgd:") {
            let s: u8 = levels
                .parse()
                .map_err(|_| Error::Config(format!("bad qsgd levels {levels:?}")))?;
            return Ok(Self::Qsgd { s });
        }
        if let Some(frac) = s.strip_prefix("topk:") {
            let frac: f32 = frac
                .parse()
                .map_err(|_| Error::Config(format!("bad topk frac {frac:?}")))?;
            return Ok(Self::Topk { frac });
        }
        Err(Error::Config(format!("unknown compression {s:?}")))
    }

    pub fn to_spec(self) -> String {
        match self {
            Self::None => "none".into(),
            Self::Qsgd { s } => format!("qsgd:{s}"),
            Self::Topk { frac } => format!("topk:{frac}"),
        }
    }
}

/// Full training/experiment configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model key, e.g. `mini_vgg` (real exec) — the perfmodel maps it to
    /// the paper's full-scale architecture for modeled runs.
    pub model: String,
    /// `mnist` or `cifar`.
    pub dataset: String,
    /// Number of peers P.
    pub peers: usize,
    /// Batch size B.
    pub batch_size: usize,
    /// Epoch limit E (convergence detection may stop earlier).
    pub epochs: usize,
    /// SGD learning rate η.
    pub lr: f32,
    /// Samples in the synthetic training set (per cluster, pre-partition).
    pub train_samples: usize,
    /// Samples in the validation set (convergence detection input).
    pub val_samples: usize,
    pub backend: Backend,
    pub sync: SyncMode,
    pub compression: Compression,
    /// EC2 instance type for peers (paper: t2.small/medium/large).
    pub instance_type: String,
    /// Lambda memory (MB) for serverless gradient functions; 0 = derive
    /// from the paper's Table II sizing rule.
    pub lambda_memory_mb: u32,
    /// Per-peer in-flight branch cap: the scheduler admission limit in
    /// pipelined mode, the Map-state wave size in staged mode.
    pub lambda_concurrency: usize,
    /// Round-robin fairness across peer lanes on the cluster scheduler
    /// (false = greedy lowest-rank-first baseline).
    pub sched_fair: bool,
    /// Staged vs pipelined vs cross-epoch serverless dispatch.
    pub offload_mode: OffloadMode,
    /// Cross-epoch window: how many epochs may be in flight on the
    /// scheduler at once (cross-epoch mode only; 1 disables the
    /// pre-dispatch and behaves like pipelined at the boundary).
    /// Synchronous training uses at most 2 — deeper windows are the
    /// hook for stale-tolerant modes.
    pub pipeline_depth: usize,
    /// Entries in the decoded-object cache memoizing params decodes
    /// across Lambda branches (0 disables; each entry is one params
    /// vector).
    pub decode_cache: usize,
    /// Sweep each epoch's store scratch (params, parked gradients) by
    /// generation after the fan-out. `false` keeps it all — a debugging
    /// aid that lets the store grow with the epoch count.
    pub sweep_scratch: bool,
    /// Serverless wire-plane codec for gradient returns (and the inner
    /// codec of params delta frames): `none` keeps the data plane
    /// byte-identical to the uncompressed path.
    pub wire_compression: Compression,
    /// Delta-encode params uploads against the previous generation,
    /// resyncing with a full object every N generations (0 = off, raw
    /// f32 params objects exactly as before; requires `decode_cache > 0`
    /// so a delta frame's base generation stays memoized).
    pub params_delta_every: usize,
    /// Sharded params manifest: `"off"` ships one monolithic params
    /// object (byte-identical to the seed plane), `"layer"` splits on
    /// the AOT manifest's per-layer `params_spec`, a number splits into
    /// that many contiguous near-equal shards. With sharding on, each
    /// generation uploads a small `SPv1` manifest plus only the shards
    /// whose content hash changed; unchanged shards carry the prior
    /// generation's object ref. Requires `decode_cache > 0` so the
    /// handler-side per-shard decodes are memoized.
    pub params_sharding: String,
    /// Worker threads in the FaaS execution fabric (0 = machine size).
    /// Physical concurrency only: the modeled accounting does not move.
    pub exec_threads: usize,
    /// Concurrent PJRT executions the engine allows (0 = machine size,
    /// 1 = fully serialized — the honest single-core timing mode).
    pub exec_slots: usize,
    /// Fused-execution batch: up to this many concurrent gradient
    /// branches holding the same executable + params version coalesce
    /// into one engine dispatch (1 disables fusion). Fusion never
    /// changes the math or the modeled accounting — only the measured
    /// wall moves, shrinking when per-dispatch overhead dominates
    /// (`exec_slots = 1`, many small branches) and costing intra-group
    /// parallelism when slots are plentiful.
    pub exec_batch: usize,
    /// Adaptive exec-batch control plane (`--exec-batch auto`): treat
    /// `exec_batch` as a ceiling and let the scheduler size the live
    /// fused-group target (and its own coalesce burst) from queue
    /// depth / pool utilization. Off by default; the modeled
    /// accounting still never moves — only the measured wall.
    pub exec_batch_auto: bool,
    /// How long a fused-execution group collects members before
    /// dispatching partially filled, in microseconds.
    pub exec_batch_wait_us: u64,
    /// Reaction to a peer declared dead mid-run: abort (fail fast),
    /// takeover (a survivor re-dispatches the dead partition), or drop
    /// (survivors continue without it).
    pub on_peer_failure: FailurePolicy,
    /// How often each live peer publishes a heartbeat on its broker
    /// heartbeat queue, in milliseconds.
    pub heartbeat_interval_ms: u64,
    /// How long a peer's heartbeat may go stale before the membership
    /// table declares it dead, in milliseconds. Also the deadline on
    /// the epoch-barrier wait.
    pub peer_timeout_ms: u64,
    /// k-of-n partial folds: produce the next params from the first
    /// `k` of a peer's n gradient branches (branch-index order, so the
    /// straggler set is deterministic) and account the rest as
    /// stragglers. 0 (the default) folds every branch.
    pub fold_quorum: usize,
    /// Deterministic fault-injection plan (`harness::faults` spec,
    /// e.g. `"kill:peer1@2;delay:peer0.branch3@1:5ms;dup:peer2.branch0@1"`,
    /// or `"rate:kill=0.25,seed=7"`). Empty = no faults.
    pub fault_plan: String,
    /// Lambda invocation attempts per branch (first try + retries).
    pub lambda_retries: u32,
    /// Base of the exponential retry backoff, in milliseconds: attempt
    /// a sleeps `backoff * 2^(a-1)` plus seeded jitter before retrying.
    /// 0 (the default) retries immediately — the pre-backoff behavior.
    /// Measured wall only; the modeled accounting never moves.
    pub retry_backoff_ms: u64,
    /// Store/broker I/O attempts per operation under injected chaos
    /// (first try + retries) — the unified retry policy the offload
    /// uploads, handler gets, and broker publishes all share.
    pub store_retries: u32,
    /// Base of the store/broker retry backoff, in milliseconds (same
    /// exponential-plus-jitter schedule as `retry_backoff_ms`).
    pub store_backoff_ms: u64,
    pub seed: u64,
    /// Where the AOT artifacts live.
    pub artifacts_dir: String,
    /// Early-stopping patience in epochs (0 disables).
    pub early_stop_patience: usize,
    /// ReduceLROnPlateau patience (0 disables).
    pub plateau_patience: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "mini_squeezenet".into(),
            dataset: "mnist".into(),
            peers: 4,
            batch_size: 64,
            epochs: 4,
            lr: 0.05,
            train_samples: 4096,
            val_samples: 256,
            backend: Backend::default(),
            sync: SyncMode::default(),
            compression: Compression::default(),
            instance_type: "t2.medium".into(),
            lambda_memory_mb: 0,
            lambda_concurrency: 64,
            sched_fair: true,
            offload_mode: OffloadMode::default(),
            pipeline_depth: 2,
            decode_cache: 16,
            sweep_scratch: true,
            wire_compression: Compression::None,
            params_delta_every: 0,
            params_sharding: "off".into(),
            exec_threads: 0,
            exec_slots: 0,
            exec_batch: 1,
            exec_batch_auto: false,
            exec_batch_wait_us: 500,
            on_peer_failure: FailurePolicy::default(),
            heartbeat_interval_ms: 250,
            peer_timeout_ms: 30_000,
            fold_quorum: 0,
            fault_plan: String::new(),
            lambda_retries: 3,
            retry_backoff_ms: 0,
            store_retries: 3,
            store_backoff_ms: 0,
            seed: 42,
            artifacts_dir: "artifacts".into(),
            early_stop_patience: 0,
            plateau_patience: 0,
        }
    }
}

impl TrainConfig {
    /// Load from a JSON file; unknown keys are rejected.
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let json = Json::parse_file(path)?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        let obj = json
            .as_obj()
            .ok_or_else(|| Error::Config("config must be a JSON object".into()))?;
        for (key, v) in obj {
            let missing = || Error::Config(format!("bad value for {key:?}"));
            match key.as_str() {
                "model" => cfg.model = v.as_str().ok_or_else(missing)?.into(),
                "dataset" => cfg.dataset = v.as_str().ok_or_else(missing)?.into(),
                "peers" => cfg.peers = v.as_usize().ok_or_else(missing)?,
                "batch_size" => cfg.batch_size = v.as_usize().ok_or_else(missing)?,
                "epochs" => cfg.epochs = v.as_usize().ok_or_else(missing)?,
                "lr" => cfg.lr = v.as_f64().ok_or_else(missing)? as f32,
                "train_samples" => cfg.train_samples = v.as_usize().ok_or_else(missing)?,
                "val_samples" => cfg.val_samples = v.as_usize().ok_or_else(missing)?,
                "backend" => cfg.backend = Backend::parse(v.as_str().ok_or_else(missing)?)?,
                "sync" => cfg.sync = SyncMode::parse(v.as_str().ok_or_else(missing)?)?,
                "compression" => {
                    cfg.compression = Compression::parse(v.as_str().ok_or_else(missing)?)?
                }
                "instance_type" => cfg.instance_type = v.as_str().ok_or_else(missing)?.into(),
                "lambda_memory_mb" => cfg.lambda_memory_mb = v.as_u64().ok_or_else(missing)? as u32,
                "lambda_concurrency" => {
                    cfg.lambda_concurrency = v.as_usize().ok_or_else(missing)?
                }
                "sched_fair" => cfg.sched_fair = v.as_bool().ok_or_else(missing)?,
                "offload_mode" => {
                    cfg.offload_mode = OffloadMode::parse(v.as_str().ok_or_else(missing)?)?
                }
                "pipeline_depth" => cfg.pipeline_depth = v.as_usize().ok_or_else(missing)?,
                "decode_cache" => cfg.decode_cache = v.as_usize().ok_or_else(missing)?,
                "sweep_scratch" => cfg.sweep_scratch = v.as_bool().ok_or_else(missing)?,
                "wire_compression" => {
                    cfg.wire_compression = Compression::parse(v.as_str().ok_or_else(missing)?)?
                }
                "params_delta_every" => {
                    cfg.params_delta_every = v.as_usize().ok_or_else(missing)?
                }
                "params_sharding" => {
                    cfg.params_sharding = v.as_str().ok_or_else(missing)?.to_string()
                }
                "exec_threads" => cfg.exec_threads = v.as_usize().ok_or_else(missing)?,
                "exec_slots" => cfg.exec_slots = v.as_usize().ok_or_else(missing)?,
                "exec_batch" => cfg.exec_batch = v.as_usize().ok_or_else(missing)?,
                "exec_batch_auto" => {
                    cfg.exec_batch_auto = v.as_bool().ok_or_else(missing)?
                }
                "exec_batch_wait_us" => {
                    cfg.exec_batch_wait_us = v.as_u64().ok_or_else(missing)?
                }
                "on_peer_failure" => {
                    cfg.on_peer_failure = FailurePolicy::parse(v.as_str().ok_or_else(missing)?)?
                }
                "heartbeat_interval_ms" => {
                    cfg.heartbeat_interval_ms = v.as_u64().ok_or_else(missing)?
                }
                "peer_timeout_ms" => cfg.peer_timeout_ms = v.as_u64().ok_or_else(missing)?,
                "fold_quorum" => cfg.fold_quorum = v.as_usize().ok_or_else(missing)?,
                "fault_plan" => cfg.fault_plan = v.as_str().ok_or_else(missing)?.into(),
                "lambda_retries" => cfg.lambda_retries = v.as_u64().ok_or_else(missing)? as u32,
                "retry_backoff_ms" => cfg.retry_backoff_ms = v.as_u64().ok_or_else(missing)?,
                "store_retries" => cfg.store_retries = v.as_u64().ok_or_else(missing)? as u32,
                "store_backoff_ms" => cfg.store_backoff_ms = v.as_u64().ok_or_else(missing)?,
                "seed" => cfg.seed = v.as_u64().ok_or_else(missing)?,
                "artifacts_dir" => cfg.artifacts_dir = v.as_str().ok_or_else(missing)?.into(),
                "early_stop_patience" => {
                    cfg.early_stop_patience = v.as_usize().ok_or_else(missing)?
                }
                "plateau_patience" => cfg.plateau_patience = v.as_usize().ok_or_else(missing)?,
                other => return Err(Error::Config(format!("unknown config key {other:?}"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", self.model.as_str())
            .set("dataset", self.dataset.as_str())
            .set("peers", self.peers)
            .set("batch_size", self.batch_size)
            .set("epochs", self.epochs)
            .set("lr", self.lr as f64)
            .set("train_samples", self.train_samples)
            .set("val_samples", self.val_samples)
            .set("backend", self.backend.name())
            .set("sync", self.sync.name())
            .set("compression", self.compression.to_spec())
            .set("instance_type", self.instance_type.as_str())
            .set("lambda_memory_mb", self.lambda_memory_mb as u64)
            .set("lambda_concurrency", self.lambda_concurrency)
            .set("sched_fair", self.sched_fair)
            .set("offload_mode", self.offload_mode.name())
            .set("pipeline_depth", self.pipeline_depth)
            .set("decode_cache", self.decode_cache)
            .set("sweep_scratch", self.sweep_scratch)
            .set("wire_compression", self.wire_compression.to_spec())
            .set("params_delta_every", self.params_delta_every)
            .set("params_sharding", self.params_sharding.as_str())
            .set("exec_threads", self.exec_threads)
            .set("exec_slots", self.exec_slots)
            .set("exec_batch", self.exec_batch)
            .set("exec_batch_auto", self.exec_batch_auto)
            .set("exec_batch_wait_us", self.exec_batch_wait_us)
            .set("on_peer_failure", self.on_peer_failure.name())
            .set("heartbeat_interval_ms", self.heartbeat_interval_ms)
            .set("peer_timeout_ms", self.peer_timeout_ms)
            .set("fold_quorum", self.fold_quorum)
            .set("fault_plan", self.fault_plan.as_str())
            .set("lambda_retries", self.lambda_retries as u64)
            .set("retry_backoff_ms", self.retry_backoff_ms)
            .set("store_retries", self.store_retries as u64)
            .set("store_backoff_ms", self.store_backoff_ms)
            .set("seed", self.seed)
            .set("artifacts_dir", self.artifacts_dir.as_str())
            .set("early_stop_patience", self.early_stop_patience)
            .set("plateau_patience", self.plateau_patience);
        j
    }

    /// Manifest key for the runtime (`<model>_<dataset>`).
    pub fn model_key(&self) -> String {
        format!("{}_{}", self.model, self.dataset)
    }

    pub fn validate(&self) -> Result<()> {
        if self.peers == 0 {
            return Err(Error::Config("peers must be >= 1".into()));
        }
        if self.batch_size == 0 {
            return Err(Error::Config("batch_size must be >= 1".into()));
        }
        if self.train_samples < self.peers * self.batch_size {
            return Err(Error::Config(format!(
                "train_samples={} cannot cover {} peers x batch {}",
                self.train_samples, self.peers, self.batch_size
            )));
        }
        if !(self.lr > 0.0) {
            return Err(Error::Config("lr must be > 0".into()));
        }
        if self.pipeline_depth == 0 {
            return Err(Error::Config("pipeline_depth must be >= 1".into()));
        }
        if self.exec_batch == 0 {
            return Err(Error::Config(
                "exec_batch must be >= 1 (1 disables fusion)".into(),
            ));
        }
        if self.exec_batch_auto && self.exec_batch < 2 {
            return Err(Error::Config(
                "exec_batch_auto needs an exec_batch ceiling >= 2 \
                 (auto mode ramps between 1 and the ceiling)"
                    .into(),
            ));
        }
        if let Compression::Qsgd { s } = self.compression {
            if s < 1 {
                return Err(Error::Config("qsgd s must be >= 1".into()));
            }
        }
        if let Compression::Topk { frac } = self.compression {
            if !(frac > 0.0 && frac <= 1.0) {
                return Err(Error::Config("topk frac must be in (0,1]".into()));
            }
        }
        if let Compression::Qsgd { s } = self.wire_compression {
            if s < 1 {
                return Err(Error::Config("wire qsgd s must be >= 1".into()));
            }
        }
        if let Compression::Topk { frac } = self.wire_compression {
            if !(frac > 0.0 && frac <= 1.0) {
                return Err(Error::Config("wire topk frac must be in (0,1]".into()));
            }
        }
        if self.params_delta_every > 0 && self.decode_cache == 0 {
            return Err(Error::Config(
                "params_delta_every requires decode_cache > 0 — a delta frame's \
                 base generation is reconstructed through the decoded cache"
                    .into(),
            ));
        }
        let shard_spec = crate::store::shard::ShardSpec::parse(&self.params_sharding)?;
        if shard_spec.on() && self.decode_cache == 0 {
            return Err(Error::Config(
                "params_sharding requires decode_cache > 0 — the handler \
                 resolves a shard manifest through the decoded cache"
                    .into(),
            ));
        }
        if self.heartbeat_interval_ms == 0 {
            return Err(Error::Config("heartbeat_interval_ms must be >= 1".into()));
        }
        if self.peer_timeout_ms < self.heartbeat_interval_ms {
            return Err(Error::Config(format!(
                "peer_timeout_ms={} must be >= heartbeat_interval_ms={} — a \
                 timeout shorter than one beat declares every peer dead",
                self.peer_timeout_ms, self.heartbeat_interval_ms
            )));
        }
        if self.lambda_retries == 0 {
            return Err(Error::Config(
                "lambda_retries must be >= 1 (the first attempt counts)".into(),
            ));
        }
        if self.store_retries == 0 {
            return Err(Error::Config(
                "store_retries must be >= 1 (the first attempt counts)".into(),
            ));
        }
        // reject a malformed fault plan up front, not mid-run
        crate::harness::faults::FaultPlanSpec::parse(&self.fault_plan)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = TrainConfig {
            model: "mini_vgg".into(),
            backend: Backend::Serverless,
            sync: SyncMode::Asynchronous,
            compression: Compression::Qsgd { s: 16 },
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.model, "mini_vgg");
        assert_eq!(back.backend, Backend::Serverless);
        assert_eq!(back.sync, SyncMode::Asynchronous);
        assert!(matches!(back.compression, Compression::Qsgd { s: 16 }));
    }

    #[test]
    fn exec_knobs_roundtrip() {
        let cfg = TrainConfig { exec_threads: 8, exec_slots: 1, ..Default::default() };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.exec_threads, 8);
        assert_eq!(back.exec_slots, 1);
        // defaults are 0 = "size to the machine"
        assert_eq!(TrainConfig::default().exec_threads, 0);
        assert_eq!(TrainConfig::default().exec_slots, 0);
    }

    #[test]
    fn exec_batch_knobs_roundtrip() {
        let cfg = TrainConfig {
            exec_batch: 8,
            exec_batch_wait_us: 250,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.exec_batch, 8);
        assert_eq!(back.exec_batch_wait_us, 250);
        // defaults: fusion off, a half-millisecond collect window
        assert_eq!(TrainConfig::default().exec_batch, 1);
        assert_eq!(TrainConfig::default().exec_batch_wait_us, 500);
        // a zero batch can hold no branch at all — config error
        let bad = TrainConfig { exec_batch: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn exec_batch_auto_roundtrips_and_needs_a_ceiling() {
        let cfg = TrainConfig {
            exec_batch: 8,
            exec_batch_auto: true,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.exec_batch_auto);
        assert_eq!(back.exec_batch, 8);
        assert!(!TrainConfig::default().exec_batch_auto);
        // auto with the fusion-disabled ceiling of 1 has no room to
        // ramp: reject instead of silently running unfused forever
        let bad = TrainConfig { exec_batch_auto: true, ..Default::default() };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("exec_batch"), "{err}");
    }

    #[test]
    fn scheduler_knobs_roundtrip() {
        let cfg = TrainConfig {
            sched_fair: false,
            offload_mode: OffloadMode::Staged,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert!(!back.sched_fair);
        assert_eq!(back.offload_mode, OffloadMode::Staged);
        // defaults: fair round-robin, pipelined dispatch
        assert!(TrainConfig::default().sched_fair);
        assert_eq!(TrainConfig::default().offload_mode, OffloadMode::Pipelined);
        assert!(OffloadMode::parse("warp").is_err());
    }

    #[test]
    fn cross_epoch_knobs_roundtrip() {
        let cfg = TrainConfig {
            offload_mode: OffloadMode::CrossEpoch,
            pipeline_depth: 1,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.offload_mode, OffloadMode::CrossEpoch);
        assert_eq!(back.pipeline_depth, 1);
        // default: a two-epoch window (one epoch pre-dispatched)
        assert_eq!(TrainConfig::default().pipeline_depth, 2);
        for spec in ["cross-epoch", "cross_epoch", "crossepoch"] {
            assert_eq!(OffloadMode::parse(spec).unwrap(), OffloadMode::CrossEpoch);
        }
        assert_eq!(OffloadMode::CrossEpoch.name(), "cross-epoch");
        // a zero-depth window cannot hold even the current epoch
        let bad = TrainConfig { pipeline_depth: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn data_plane_knobs_roundtrip() {
        let cfg = TrainConfig {
            decode_cache: 3,
            sweep_scratch: false,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.decode_cache, 3);
        assert!(!back.sweep_scratch);
        // defaults: a small cache, scratch swept every epoch
        assert_eq!(TrainConfig::default().decode_cache, 16);
        assert!(TrainConfig::default().sweep_scratch);
    }

    #[test]
    fn wire_plane_knobs_roundtrip() {
        let cfg = TrainConfig {
            wire_compression: Compression::Qsgd { s: 16 },
            params_delta_every: 4,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert!(matches!(back.wire_compression, Compression::Qsgd { s: 16 }));
        assert_eq!(back.params_delta_every, 4);
        // defaults: the plane is fully off
        assert_eq!(TrainConfig::default().wire_compression, Compression::None);
        assert_eq!(TrainConfig::default().params_delta_every, 0);
        // the wire codec is validated like the exchange codec
        let bad = TrainConfig {
            wire_compression: Compression::Topk { frac: 1.5 },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // a delta chain cannot reconstruct without the decoded cache
        let bad = TrainConfig {
            params_delta_every: 4,
            decode_cache: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn shard_plane_knobs_roundtrip() {
        let cfg = TrainConfig { params_sharding: "layer".into(), ..Default::default() };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.params_sharding, "layer");
        let cfg = TrainConfig { params_sharding: "8".into(), ..Default::default() };
        assert_eq!(
            TrainConfig::from_json(&cfg.to_json()).unwrap().params_sharding,
            "8"
        );
        // default: the plane is off (monolithic params object)
        assert_eq!(TrainConfig::default().params_sharding, "off");
        // bad specs are rejected up front, naming the knob
        let bad = TrainConfig { params_sharding: "banana".into(), ..Default::default() };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("params_sharding"), "{err}");
        let bad = TrainConfig { params_sharding: "0".into(), ..Default::default() };
        assert!(bad.validate().is_err());
        // a shard manifest cannot resolve without the decoded cache
        let bad = TrainConfig {
            params_sharding: "4".into(),
            decode_cache: 0,
            ..Default::default()
        };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("params_sharding"), "{err}");
    }

    #[test]
    fn membership_knobs_roundtrip() {
        let cfg = TrainConfig {
            on_peer_failure: FailurePolicy::Takeover,
            heartbeat_interval_ms: 20,
            peer_timeout_ms: 100,
            fold_quorum: 3,
            fault_plan: "kill:peer1@2".into(),
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.on_peer_failure, FailurePolicy::Takeover);
        assert_eq!(back.heartbeat_interval_ms, 20);
        assert_eq!(back.peer_timeout_ms, 100);
        assert_eq!(back.fold_quorum, 3);
        assert_eq!(back.fault_plan, "kill:peer1@2");
        // defaults: fail fast, full folds, no faults
        let d = TrainConfig::default();
        assert_eq!(d.on_peer_failure, FailurePolicy::Abort);
        assert_eq!(d.fold_quorum, 0);
        assert!(d.fault_plan.is_empty());
        assert!(FailurePolicy::parse("explode").is_err());
        // a timeout shorter than one beat declares everyone dead
        let bad = TrainConfig {
            heartbeat_interval_ms: 500,
            peer_timeout_ms: 100,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // malformed fault plans are a config error, not a mid-run panic
        let bad = TrainConfig { fault_plan: "explode:peer1@2".into(), ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn retry_knobs_roundtrip() {
        let cfg = TrainConfig {
            lambda_retries: 5,
            retry_backoff_ms: 10,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.lambda_retries, 5);
        assert_eq!(back.retry_backoff_ms, 10);
        // defaults match the old hardcoded RetryPolicy
        assert_eq!(TrainConfig::default().lambda_retries, 3);
        assert_eq!(TrainConfig::default().retry_backoff_ms, 0);
        // zero attempts would never invoke at all
        let bad = TrainConfig { lambda_retries: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn store_retry_knobs_roundtrip() {
        let cfg = TrainConfig {
            store_retries: 5,
            store_backoff_ms: 7,
            ..Default::default()
        };
        let back = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.store_retries, 5);
        assert_eq!(back.store_backoff_ms, 7);
        // defaults mirror the branch retry policy's
        assert_eq!(TrainConfig::default().store_retries, 3);
        assert_eq!(TrainConfig::default().store_backoff_ms, 0);
        let bad = TrainConfig { store_retries: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        let j = Json::parse(r#"{"modle": "x"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }

    #[test]
    fn compression_spec_parsing() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert!(matches!(
            Compression::parse("qsgd:8").unwrap(),
            Compression::Qsgd { s: 8 }
        ));
        assert!(matches!(
            Compression::parse("topk:0.1").unwrap(),
            Compression::Topk { .. }
        ));
        assert!(Compression::parse("gzip").is_err());
        assert!(Compression::parse("qsgd:many").is_err());
    }

    #[test]
    fn rejects_zero_peers() {
        let cfg = TrainConfig { peers: 0, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_undersized_dataset() {
        let cfg = TrainConfig {
            train_samples: 16,
            peers: 4,
            batch_size: 64,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_bad_topk() {
        let cfg = TrainConfig {
            compression: Compression::Topk { frac: 0.0 },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn model_key_format() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.model_key(), "mini_squeezenet_mnist");
    }
}
