//! RabbitMQ-like in-process message broker (the paper's Amazon MQ
//! substrate, §III-A / §III-B.3).
//!
//! Semantics reproduced from the paper:
//! - **dedicated per-peer gradient queues** holding a single *persistent*
//!   message: a new gradient *replaces* the previous one
//!   ([`QueueMode::LatestOnly`]);
//! - **consume-without-delete**: peers read every other peer's queue
//!   without removing the message;
//! - **100 MB message cap** (Amazon MQ limit) — larger payloads must go
//!   through the object store and be referenced by UUID;
//! - **synchronization queue**: an append-only queue whose length acts
//!   as the epoch barrier ([`QueueMode::Fifo`]).
//!
//! Fault injection (drop probability, delivery delay) exercises the
//! paper's "temporary disruptions" claim in the integration tests.

mod queue;

pub use queue::{AbortState, Message, Queue, QueueMode, QueueStats};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::error::{Error, Result};

/// Amazon MQ's per-message size cap the paper works around via S3+UUID.
pub const DEFAULT_MESSAGE_CAP: usize = 100 * 1024 * 1024;

/// Broker-wide fault injection knobs (deterministic; see [`Queue`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Drop every Nth publish (0 = never drop).
    pub drop_every: u64,
    /// Artificial delivery delay applied by consumers, in microseconds.
    pub delay_us: u64,
}

/// The broker: a registry of named queues.
pub struct Broker {
    queues: Mutex<HashMap<String, Arc<Queue>>>,
    cap_bytes: usize,
    faults: FaultPlan,
    abort: Arc<AbortState>,
    published: AtomicU64,
    published_bytes: AtomicU64,
}

impl Default for Broker {
    fn default() -> Self {
        Self::new(DEFAULT_MESSAGE_CAP, FaultPlan::default())
    }
}

impl Broker {
    pub fn new(cap_bytes: usize, faults: FaultPlan) -> Self {
        Self {
            queues: Mutex::new(HashMap::new()),
            cap_bytes,
            faults,
            abort: Arc::new(AbortState::default()),
            published: AtomicU64::new(0),
            published_bytes: AtomicU64::new(0),
        }
    }

    /// Abort the run: every consumer blocked on any of this broker's
    /// queues (gradient waits, the epoch barrier) wakes with
    /// [`crate::error::Error::Aborted`]. Idempotent; the first reason
    /// wins. Used by the cluster to fail fast when one peer errors
    /// instead of leaving the rest parked until a timeout.
    pub fn abort(&self, reason: &str) {
        if self.abort.trigger(reason) {
            for q in self.queues.lock().unwrap().values() {
                q.wake_all();
            }
        }
    }

    pub fn is_aborted(&self) -> bool {
        self.abort.is_aborted()
    }

    pub fn abort_reason(&self) -> Option<String> {
        self.abort.reason()
    }

    /// Declare (or fetch) a queue. Mode must match an existing queue.
    pub fn declare(&self, name: &str, mode: QueueMode) -> Result<Arc<Queue>> {
        let mut map = self.queues.lock().unwrap();
        if let Some(q) = map.get(name) {
            if q.mode() != mode {
                return Err(Error::Broker(format!(
                    "queue {name:?} already declared with mode {:?}",
                    q.mode()
                )));
            }
            return Ok(q.clone());
        }
        let q = Arc::new(Queue::new(
            name,
            mode,
            self.cap_bytes,
            self.faults,
            self.abort.clone(),
        ));
        map.insert(name.to_string(), q.clone());
        Ok(q)
    }

    pub fn get(&self, name: &str) -> Result<Arc<Queue>> {
        self.queues
            .lock().unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Broker(format!("unknown queue {name:?}")))
    }

    /// Publish `payload` to `name` (queue must exist).
    pub fn publish(&self, name: &str, msg: Message) -> Result<()> {
        let q = self.get(name)?;
        let bytes = msg.payload.len() as u64;
        q.publish(msg)?;
        self.published.fetch_add(1, Ordering::Relaxed);
        self.published_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    pub fn queue_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.queues.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// (messages, bytes) accepted by the broker so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.published.load(Ordering::Relaxed),
            self.published_bytes.load(Ordering::Relaxed),
        )
    }

    /// Total out-of-epoch-order publishes suppressed across every
    /// LatestOnly queue (see [`QueueStats::stale_drops`]). Zero in a
    /// healthy run — overlapping epochs make it observable, not normal.
    pub fn stale_drops(&self) -> u64 {
        self.queues
            .lock()
            .unwrap()
            .values()
            .map(|q| q.stats().stale_drops)
            .sum()
    }

    /// Conventional queue name for peer `r`'s gradient queue.
    pub fn gradient_queue(r: usize) -> String {
        format!("peer.{r}.gradients")
    }

    /// Conventional name of the epoch-barrier queue.
    pub fn sync_queue() -> String {
        "sync.barrier".to_string()
    }

    /// Conventional queue name for peer `r`'s liveness heartbeats
    /// (LatestOnly: only the freshest beat matters).
    pub fn heartbeat_queue(r: usize) -> String {
        format!("peer.{r}.heartbeat")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Bytes;

    fn msg(payload: &'static [u8]) -> Message {
        Message::new(0, 0, Bytes::from_static(payload))
    }

    #[test]
    fn declare_idempotent_same_mode() {
        let b = Broker::default();
        let q1 = b.declare("a", QueueMode::LatestOnly).unwrap();
        let q2 = b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(Arc::ptr_eq(&q1, &q2));
    }

    #[test]
    fn declare_conflicting_mode_fails() {
        let b = Broker::default();
        b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(b.declare("a", QueueMode::Fifo).is_err());
    }

    #[test]
    fn publish_to_unknown_queue_fails() {
        let b = Broker::default();
        assert!(b.publish("nope", msg(b"x")).is_err());
    }

    #[test]
    fn stats_count_publishes() {
        let b = Broker::default();
        b.declare("a", QueueMode::LatestOnly).unwrap();
        b.publish("a", msg(b"xyz")).unwrap();
        b.publish("a", msg(b"ab")).unwrap();
        let (n, bytes) = b.stats();
        assert_eq!(n, 2);
        assert_eq!(bytes, 5);
    }

    #[test]
    fn message_cap_enforced() {
        let b = Broker::new(4, FaultPlan::default());
        b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(b.publish("a", msg(b"12345")).is_err());
        assert!(b.publish("a", msg(b"1234")).is_ok());
    }

    #[test]
    fn broker_abort_reaches_every_queue() {
        let b = Arc::new(Broker::default());
        b.declare("a", QueueMode::Fifo).unwrap();
        b.declare("b", QueueMode::LatestOnly).unwrap();
        assert!(!b.is_aborted());
        let qa = b.get("a").unwrap();
        let qb = b.get("b").unwrap();
        let wa = std::thread::spawn(move || qa.await_version(1));
        let wb = std::thread::spawn(move || qb.await_epoch(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        b.abort("peer 1 failed: boom");
        b.abort("second reason is ignored");
        assert!(wa.join().unwrap().is_err());
        assert!(wb.join().unwrap().is_err());
        assert_eq!(b.abort_reason().as_deref(), Some("peer 1 failed: boom"));
    }

    #[test]
    fn queue_name_conventions() {
        assert_eq!(Broker::gradient_queue(3), "peer.3.gradients");
        assert_eq!(Broker::sync_queue(), "sync.barrier");
    }
}
