//! RabbitMQ-like in-process message broker (the paper's Amazon MQ
//! substrate, §III-A / §III-B.3).
//!
//! Semantics reproduced from the paper:
//! - **dedicated per-peer gradient queues** holding a single *persistent*
//!   message: a new gradient *replaces* the previous one
//!   ([`QueueMode::LatestOnly`]);
//! - **consume-without-delete**: peers read every other peer's queue
//!   without removing the message;
//! - **100 MB message cap** (Amazon MQ limit) — larger payloads must go
//!   through the object store and be referenced by UUID;
//! - **synchronization queue**: an append-only queue whose length acts
//!   as the epoch barrier ([`QueueMode::Fifo`]).
//!
//! Fault injection (drop probability, delivery delay) exercises the
//! paper's "temporary disruptions" claim in the integration tests.

mod queue;

pub use queue::{AbortState, Message, Queue, QueueMode, QueueStats};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;
use std::sync::RwLock;

use crate::error::{Error, Result};
use crate::harness::faults::{self, BrokerFault, FaultPlan as ChaosPlan};
use crate::util::retry::RetryPolicy;

/// Amazon MQ's per-message size cap the paper works around via S3+UUID.
pub const DEFAULT_MESSAGE_CAP: usize = 100 * 1024 * 1024;

/// Broker-wide fault injection knobs (deterministic; see [`Queue`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Drop every Nth publish (0 = never drop).
    pub drop_every: u64,
    /// Artificial delivery delay applied by consumers, in microseconds.
    pub delay_us: u64,
}

/// The armed publish-side chaos hook: scheduled drop/delay faults plus
/// the retry policy drops are absorbed under.
#[derive(Clone)]
struct ChaosHook {
    plan: Arc<ChaosPlan>,
    retry: RetryPolicy,
}

/// The broker: a registry of named queues.
///
/// When a fault plan schedules broker faults, [`Broker::arm_chaos`]
/// turns on the publish hook: a scoped peer's publish can be dropped
/// (re-published under the shared retry policy, counted in
/// `broker.retries`) or delayed (measured time only). Unarmed, the
/// publish path is byte-identical to the pre-chaos broker.
pub struct Broker {
    queues: Mutex<HashMap<String, Arc<Queue>>>,
    cap_bytes: usize,
    faults: FaultPlan,
    abort: Arc<AbortState>,
    published: AtomicU64,
    published_bytes: AtomicU64,
    /// Injected-fault hook; `None` (default) is the untouched path.
    chaos: RwLock<Option<ChaosHook>>,
    /// Re-publish attempts forced by injected drops.
    chaos_retries: AtomicU64,
}

impl Default for Broker {
    fn default() -> Self {
        Self::new(DEFAULT_MESSAGE_CAP, FaultPlan::default())
    }
}

impl Broker {
    pub fn new(cap_bytes: usize, faults: FaultPlan) -> Self {
        Self {
            queues: Mutex::new(HashMap::new()),
            cap_bytes,
            faults,
            abort: Arc::new(AbortState::default()),
            published: AtomicU64::new(0),
            published_bytes: AtomicU64::new(0),
            chaos: RwLock::new(None),
            chaos_retries: AtomicU64::new(0),
        }
    }

    /// Arm the publish-side chaos hook (injected drops/delays scoped by
    /// [`crate::harness::faults::FaultScope`], drops absorbed under
    /// `retry`).
    pub fn arm_chaos(&self, plan: Arc<ChaosPlan>, retry: RetryPolicy) {
        *self.chaos.write().unwrap() = Some(ChaosHook { plan, retry });
    }

    /// Re-publish attempts forced by injected drops.
    pub fn chaos_retries(&self) -> u64 {
        self.chaos_retries.load(Ordering::Relaxed)
    }

    /// Abort the run: every consumer blocked on any of this broker's
    /// queues (gradient waits, the epoch barrier) wakes with
    /// [`crate::error::Error::Aborted`]. Idempotent; the first reason
    /// wins. Used by the cluster to fail fast when one peer errors
    /// instead of leaving the rest parked until a timeout.
    pub fn abort(&self, reason: &str) {
        if self.abort.trigger(reason) {
            for q in self.queues.lock().unwrap().values() {
                q.wake_all();
            }
        }
    }

    pub fn is_aborted(&self) -> bool {
        self.abort.is_aborted()
    }

    pub fn abort_reason(&self) -> Option<String> {
        self.abort.reason()
    }

    /// Declare (or fetch) a queue. Mode must match an existing queue.
    pub fn declare(&self, name: &str, mode: QueueMode) -> Result<Arc<Queue>> {
        let mut map = self.queues.lock().unwrap();
        if let Some(q) = map.get(name) {
            if q.mode() != mode {
                return Err(Error::Broker(format!(
                    "queue {name:?} already declared with mode {:?}",
                    q.mode()
                )));
            }
            return Ok(q.clone());
        }
        let q = Arc::new(Queue::new(
            name,
            mode,
            self.cap_bytes,
            self.faults,
            self.abort.clone(),
        ));
        map.insert(name.to_string(), q.clone());
        Ok(q)
    }

    pub fn get(&self, name: &str) -> Result<Arc<Queue>> {
        self.queues
            .lock().unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Broker(format!("unknown queue {name:?}")))
    }

    /// Publish `payload` to `name` (queue must exist). With the chaos
    /// hook armed, scheduled drop faults for the calling thread's
    /// (rank, epoch) scope make the delivery fail and be re-published
    /// under the retry policy — a drop is only *lost* once the policy
    /// is exhausted, which is exactly the at-least-once delivery story
    /// the paper's MQ substrate gives real deployments.
    pub fn publish(&self, name: &str, msg: Message) -> Result<()> {
        let hook = self.chaos.read().unwrap().clone();
        if let (Some(h), Some((rank, epoch))) = (hook, faults::current_fault_scope()) {
            let mut dropped = 0u32;
            while let Some(fault) = h.plan.take_broker_fault(rank, epoch) {
                match fault {
                    BrokerFault::Delay(us) => {
                        std::thread::sleep(std::time::Duration::from_micros(us));
                    }
                    BrokerFault::Drop => {
                        dropped += 1;
                        if dropped >= h.retry.max_attempts {
                            return Err(Error::Broker(format!(
                                "injected publish drop on {name:?}: {} attempts \
                                 exhausted",
                                h.retry.max_attempts
                            )));
                        }
                        self.chaos_retries.fetch_add(1, Ordering::Relaxed);
                        let delay = h.retry.backoff_delay(dropped);
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                    }
                }
            }
        }
        let q = self.get(name)?;
        let bytes = msg.payload.len() as u64;
        q.publish(msg)?;
        self.published.fetch_add(1, Ordering::Relaxed);
        self.published_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    pub fn queue_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.queues.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// (messages, bytes) accepted by the broker so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.published.load(Ordering::Relaxed),
            self.published_bytes.load(Ordering::Relaxed),
        )
    }

    /// Total out-of-epoch-order publishes suppressed across every
    /// LatestOnly queue (see [`QueueStats::stale_drops`]). Zero in a
    /// healthy run — overlapping epochs make it observable, not normal.
    pub fn stale_drops(&self) -> u64 {
        self.queues
            .lock()
            .unwrap()
            .values()
            .map(|q| q.stats().stale_drops)
            .sum()
    }

    /// Conventional queue name for peer `r`'s gradient queue.
    pub fn gradient_queue(r: usize) -> String {
        format!("peer.{r}.gradients")
    }

    /// Conventional name of the epoch-barrier queue.
    pub fn sync_queue() -> String {
        "sync.barrier".to_string()
    }

    /// Conventional queue name for peer `r`'s liveness heartbeats
    /// (LatestOnly: only the freshest beat matters).
    pub fn heartbeat_queue(r: usize) -> String {
        format!("peer.{r}.heartbeat")
    }

    /// Conventional name of the membership join-announce queue: joining
    /// peers publish their rank here and the leader admits them at the
    /// next epoch boundary (Fifo: announcements are never lost).
    pub fn join_queue() -> String {
        "membership.join".to_string()
    }

    /// Conventional queue name for the admit message the leader sends
    /// back to joining peer `r` (warm-start params ref + start epoch).
    pub fn join_admit_queue(r: usize) -> String {
        format!("membership.join.admit.{r}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Bytes;

    fn msg(payload: &'static [u8]) -> Message {
        Message::new(0, 0, Bytes::from_static(payload))
    }

    #[test]
    fn declare_idempotent_same_mode() {
        let b = Broker::default();
        let q1 = b.declare("a", QueueMode::LatestOnly).unwrap();
        let q2 = b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(Arc::ptr_eq(&q1, &q2));
    }

    #[test]
    fn declare_conflicting_mode_fails() {
        let b = Broker::default();
        b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(b.declare("a", QueueMode::Fifo).is_err());
    }

    #[test]
    fn publish_to_unknown_queue_fails() {
        let b = Broker::default();
        assert!(b.publish("nope", msg(b"x")).is_err());
    }

    #[test]
    fn stats_count_publishes() {
        let b = Broker::default();
        b.declare("a", QueueMode::LatestOnly).unwrap();
        b.publish("a", msg(b"xyz")).unwrap();
        b.publish("a", msg(b"ab")).unwrap();
        let (n, bytes) = b.stats();
        assert_eq!(n, 2);
        assert_eq!(bytes, 5);
    }

    #[test]
    fn message_cap_enforced() {
        let b = Broker::new(4, FaultPlan::default());
        b.declare("a", QueueMode::LatestOnly).unwrap();
        assert!(b.publish("a", msg(b"12345")).is_err());
        assert!(b.publish("a", msg(b"1234")).is_ok());
    }

    #[test]
    fn broker_abort_reaches_every_queue() {
        let b = Arc::new(Broker::default());
        b.declare("a", QueueMode::Fifo).unwrap();
        b.declare("b", QueueMode::LatestOnly).unwrap();
        assert!(!b.is_aborted());
        let qa = b.get("a").unwrap();
        let qb = b.get("b").unwrap();
        let wa = std::thread::spawn(move || qa.await_version(1));
        let wb = std::thread::spawn(move || qb.await_epoch(1));
        std::thread::sleep(std::time::Duration::from_millis(10));
        b.abort("peer 1 failed: boom");
        b.abort("second reason is ignored");
        assert!(wa.join().unwrap().is_err());
        assert!(wb.join().unwrap().is_err());
        assert_eq!(b.abort_reason().as_deref(), Some("peer 1 failed: boom"));
    }

    #[test]
    fn queue_name_conventions() {
        assert_eq!(Broker::gradient_queue(3), "peer.3.gradients");
        assert_eq!(Broker::sync_queue(), "sync.barrier");
        assert_eq!(Broker::join_queue(), "membership.join");
        assert_eq!(Broker::join_admit_queue(4), "membership.join.admit.4");
    }

    #[test]
    fn armed_broker_drop_is_republished_and_counted() {
        use crate::harness::faults::{FaultPlanSpec, FaultScope};
        let b = Broker::default();
        b.declare("a", QueueMode::Fifo).unwrap();
        let plan = Arc::new(
            FaultPlanSpec::parse("brokerdrop:peer1@1;brokerdelay:peer1@1:0ms")
                .unwrap()
                .resolve(4, 2)
                .unwrap(),
        );
        b.arm_chaos(plan.clone(), RetryPolicy::configured(3, 0, 0));
        // Unscoped publishes never see faults.
        b.publish("a", msg(b"x")).unwrap();
        let _scope = FaultScope::enter(1, 1);
        b.publish("a", msg(b"y")).unwrap();
        assert_eq!(b.chaos_retries(), 1);
        assert_eq!(plan.broker_faults_fired(), 2);
        let (n, _) = b.stats();
        assert_eq!(n, 2);
    }

    #[test]
    fn armed_broker_drop_exhausts_single_attempt_policy() {
        use crate::harness::faults::{FaultPlanSpec, FaultScope};
        let b = Broker::default();
        b.declare("a", QueueMode::Fifo).unwrap();
        let plan = Arc::new(
            FaultPlanSpec::parse("brokerdrop:peer2@1")
                .unwrap()
                .resolve(4, 2)
                .unwrap(),
        );
        b.arm_chaos(plan, RetryPolicy::configured(1, 0, 0));
        let _scope = FaultScope::enter(2, 1);
        let err = b.publish("a", msg(b"x")).unwrap_err();
        assert!(err.to_string().contains("injected publish drop"));
        let (n, _) = b.stats();
        assert_eq!(n, 0);
    }
}
