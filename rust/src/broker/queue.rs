//! A single broker queue: latest-gradient (replace) or FIFO (barrier).
//!
//! Blocking semantics (peers are OS threads): consumers park on a
//! condvar and are woken by publishes — no busy polling on the exchange
//! path.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::broker::FaultPlan;
use crate::error::{Error, Result};
use crate::util::Bytes;

/// Broker-wide abort flag shared by every queue. When a peer fails, the
/// cluster triggers it so peers parked on a gradient queue or the epoch
/// barrier wake with [`Error::Aborted`] instead of waiting for a message
/// that will never come.
#[derive(Default)]
pub struct AbortState {
    flag: AtomicBool,
    reason: Mutex<Option<String>>,
}

impl AbortState {
    /// Raise the flag; the first reason wins. Returns whether this call
    /// set it (callers then wake the sleepers).
    pub fn trigger(&self, reason: &str) -> bool {
        let mut r = self.reason.lock().unwrap();
        if self.flag.load(Ordering::SeqCst) {
            return false;
        }
        *r = Some(reason.to_string());
        self.flag.store(true, Ordering::SeqCst);
        true
    }

    pub fn is_aborted(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    pub fn reason(&self) -> Option<String> {
        self.reason.lock().unwrap().clone()
    }

    /// The error blocked consumers surface.
    pub fn error(&self) -> Error {
        Error::Aborted(self.reason().unwrap_or_else(|| "unknown reason".into()))
    }
}

/// Queue behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueMode {
    /// Holds one persistent message; publishing replaces it (the paper's
    /// dedicated gradient queue).
    LatestOnly,
    /// Append-only; length is observable (the paper's sync barrier).
    Fifo,
}

/// A broker message. `epoch` carries Algorithm 1's epoch counter so
/// synchronous consumers can wait for the *right* gradient, and
/// asynchronous consumers can detect staleness.
#[derive(Debug, Clone)]
pub struct Message {
    pub sender: usize,
    pub epoch: u64,
    pub payload: Bytes,
}

impl Message {
    pub fn new(sender: usize, epoch: u64, payload: Bytes) -> Self {
        Self { sender, epoch, payload }
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct QueueStats {
    pub publishes: u64,
    pub drops: u64,
    pub consumes: u64,
    /// LatestOnly publishes suppressed because a *newer* epoch's message
    /// was already resident — out-of-epoch-order completions (possible
    /// once epochs overlap in cross-epoch offload mode) must never
    /// roll a gradient queue backwards.
    pub stale_drops: u64,
}

struct Inner {
    latest: Option<Message>,
    fifo: VecDeque<Message>,
    /// Accepted-publish counter (monotone).
    version: u64,
}

/// See [`QueueMode`]. All consumption is non-destructive (`peek`-style),
/// matching the paper's "access and consume gradient messages from all
/// other queues without deleting them".
pub struct Queue {
    name: String,
    mode: QueueMode,
    cap: usize,
    faults: FaultPlan,
    abort: Arc<AbortState>,
    inner: Mutex<Inner>,
    cond: Condvar,
    stats_publishes: AtomicU64,
    stats_drops: AtomicU64,
    stats_consumes: AtomicU64,
    stats_stale_drops: AtomicU64,
}

impl Queue {
    pub(crate) fn new(
        name: &str,
        mode: QueueMode,
        cap: usize,
        faults: FaultPlan,
        abort: Arc<AbortState>,
    ) -> Self {
        Self {
            name: name.to_string(),
            mode,
            cap,
            faults,
            abort,
            inner: Mutex::new(Inner { latest: None, fifo: VecDeque::new(), version: 0 }),
            cond: Condvar::new(),
            stats_publishes: AtomicU64::new(0),
            stats_drops: AtomicU64::new(0),
            stats_consumes: AtomicU64::new(0),
            stats_stale_drops: AtomicU64::new(0),
        }
    }

    /// Wake every consumer parked on this queue (abort propagation).
    /// The lock round-trip orders the wake after the abort flag: a
    /// consumer either sees the flag before sleeping or is woken here.
    pub(crate) fn wake_all(&self) {
        let _guard = self.inner.lock().unwrap();
        self.cond.notify_all();
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn mode(&self) -> QueueMode {
        self.mode
    }

    /// Accepted-publish counter. For FIFO queues this equals the queue
    /// length (nothing dequeues), which is exactly the barrier predicate.
    pub fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    pub fn stats(&self) -> QueueStats {
        QueueStats {
            publishes: self.stats_publishes.load(Ordering::Relaxed),
            drops: self.stats_drops.load(Ordering::Relaxed),
            consumes: self.stats_consumes.load(Ordering::Relaxed),
            stale_drops: self.stats_stale_drops.load(Ordering::Relaxed),
        }
    }

    /// Publish; replaces in LatestOnly mode, appends in Fifo mode.
    ///
    /// LatestOnly ordering guard: a message carrying an *older* epoch
    /// than the resident one is suppressed (counted in
    /// [`QueueStats::stale_drops`]) rather than replacing it. Epoch
    /// completions can arrive out of order once cross-epoch offload
    /// overlaps epochs; replacing a fresh gradient with a stale one
    /// would silently poison every consumer that polls `peek_latest`.
    /// Equal epochs still replace (a re-publish is a refresh, not a
    /// regression).
    pub fn publish(&self, msg: Message) -> Result<()> {
        if msg.payload.len() > self.cap {
            return Err(Error::MessageTooLarge { size: msg.payload.len(), cap: self.cap });
        }
        let n = self.stats_publishes.fetch_add(1, Ordering::Relaxed) + 1;
        if self.faults.drop_every > 0 && n % self.faults.drop_every == 0 {
            // injected loss: accepted but never delivered
            self.stats_drops.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        {
            let mut inner = self.inner.lock().unwrap();
            match self.mode {
                QueueMode::LatestOnly => {
                    if inner.latest.as_ref().is_some_and(|cur| cur.epoch > msg.epoch) {
                        self.stats_stale_drops.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    inner.latest = Some(msg);
                }
                QueueMode::Fifo => inner.fifo.push_back(msg),
            }
            inner.version += 1;
        }
        self.cond.notify_all();
        Ok(())
    }

    /// Non-destructive read of the current persistent message.
    pub fn peek_latest(&self) -> Option<Message> {
        self.stats_consumes.fetch_add(1, Ordering::Relaxed);
        let inner = self.inner.lock().unwrap();
        match self.mode {
            QueueMode::LatestOnly => inner.latest.clone(),
            QueueMode::Fifo => inner.fifo.back().cloned(),
        }
    }

    /// FIFO length (LatestOnly: 0 or 1).
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        match self.mode {
            QueueMode::LatestOnly => usize::from(inner.latest.is_some()),
            QueueMode::Fifo => inner.fifo.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all entries (barrier bookkeeping / tests).
    pub fn snapshot(&self) -> Vec<Message> {
        let inner = self.inner.lock().unwrap();
        match self.mode {
            QueueMode::LatestOnly => inner.latest.iter().cloned().collect(),
            QueueMode::Fifo => inner.fifo.iter().cloned().collect(),
        }
    }

    /// Remove everything (the paper drains the sync queue between epochs).
    pub fn purge(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.latest = None;
        inner.fifo.clear();
    }

    /// Block until a message with `epoch >= min_epoch` is available
    /// (sync-mode consumer: "WaitUntilReceptionDone"). Applies the
    /// injected delivery delay. Errors with [`Error::Aborted`] if the
    /// run is aborted while waiting — a failed peer must not leave the
    /// others parked forever.
    pub fn await_epoch(&self, min_epoch: u64) -> Result<Message> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if self.abort.is_aborted() {
                return Err(self.abort.error());
            }
            let hit = match self.mode {
                QueueMode::LatestOnly => inner.latest.as_ref(),
                QueueMode::Fifo => inner.fifo.back(),
            }
            .filter(|m| m.epoch >= min_epoch)
            .cloned();
            if let Some(m) = hit {
                self.stats_consumes.fetch_add(1, Ordering::Relaxed);
                drop(inner);
                self.delay();
                return Ok(m);
            }
            inner = self.cond.wait(inner).unwrap();
        }
    }

    /// `await_epoch` with a timeout; `Ok(None)` on expiry, an
    /// [`Error::Aborted`] if the run aborts first. The membership
    /// plane's waiting loops use this so a consumer parked on a dead
    /// peer's queue can periodically reap stale heartbeats instead of
    /// waiting forever for a message that will never come.
    pub fn await_epoch_timeout(
        &self,
        min_epoch: u64,
        timeout: Duration,
    ) -> Result<Option<Message>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if self.abort.is_aborted() {
                return Err(self.abort.error());
            }
            let hit = match self.mode {
                QueueMode::LatestOnly => inner.latest.as_ref(),
                QueueMode::Fifo => inner.fifo.back(),
            }
            .filter(|m| m.epoch >= min_epoch)
            .cloned();
            if let Some(m) = hit {
                self.stats_consumes.fetch_add(1, Ordering::Relaxed);
                drop(inner);
                self.delay();
                return Ok(Some(m));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _res) = self.cond.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Block until the accepted-publish counter reaches `count`
    /// (barrier predicate). Errors with [`Error::Aborted`] on abort.
    pub fn await_version(&self, count: u64) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        while inner.version < count {
            if self.abort.is_aborted() {
                return Err(self.abort.error());
            }
            inner = self.cond.wait(inner).unwrap();
        }
        Ok(())
    }

    /// `await_version` with a timeout; `Ok(false)` on timeout, an
    /// [`Error::Aborted`] if the run aborts first.
    pub fn await_version_timeout(&self, count: u64, timeout: Duration) -> Result<bool> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        while inner.version < count {
            if self.abort.is_aborted() {
                return Err(self.abort.error());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            let (guard, res) = self.cond.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
            if res.timed_out() && inner.version < count && !self.abort.is_aborted() {
                return Ok(false);
            }
        }
        Ok(true)
    }

    fn delay(&self) {
        if self.faults.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.faults.delay_us));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn q(mode: QueueMode) -> Queue {
        Queue::new("t", mode, 1024, FaultPlan::default(), Arc::new(AbortState::default()))
    }

    fn q_with_abort(mode: QueueMode, abort: Arc<AbortState>) -> Queue {
        Queue::new("t", mode, 1024, FaultPlan::default(), abort)
    }

    fn msg(sender: usize, epoch: u64, data: &'static [u8]) -> Message {
        Message::new(sender, epoch, Bytes::from_static(data))
    }

    #[test]
    fn latest_only_replaces() {
        let q = q(QueueMode::LatestOnly);
        q.publish(msg(0, 0, b"old")).unwrap();
        q.publish(msg(0, 1, b"new")).unwrap();
        assert_eq!(q.len(), 1);
        assert_eq!(&q.peek_latest().unwrap().payload[..], b"new");
    }

    #[test]
    fn fifo_appends_and_counts() {
        let q = q(QueueMode::Fifo);
        for e in 0..5 {
            q.publish(msg(e, e as u64, b"x")).unwrap();
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.version(), 5);
        assert_eq!(q.snapshot().len(), 5);
    }

    #[test]
    fn latest_only_rejects_out_of_epoch_order_publish() {
        // out-of-order completion accounting: an older epoch's gradient
        // must never replace a newer one on a LatestOnly queue
        let lq = q(QueueMode::LatestOnly);
        lq.publish(msg(0, 2, b"fresh")).unwrap();
        lq.publish(msg(0, 1, b"stale")).unwrap();
        assert_eq!(&lq.peek_latest().unwrap().payload[..], b"fresh");
        assert_eq!(lq.stats().stale_drops, 1);
        assert_eq!(lq.version(), 1, "a suppressed publish is not accepted");
        // an equal epoch is a refresh, not a regression
        lq.publish(msg(0, 2, b"refresh")).unwrap();
        assert_eq!(&lq.peek_latest().unwrap().payload[..], b"refresh");
        assert_eq!(lq.stats().stale_drops, 1);
        assert_eq!(lq.version(), 2);
        // FIFO queues (the barrier) are append-only and never suppress
        let f = q(QueueMode::Fifo);
        f.publish(msg(0, 2, b"a")).unwrap();
        f.publish(msg(0, 1, b"b")).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f.stats().stale_drops, 0);
    }

    #[test]
    fn peek_is_nondestructive() {
        let q = q(QueueMode::LatestOnly);
        q.publish(msg(1, 3, b"grad")).unwrap();
        for _ in 0..3 {
            assert!(q.peek_latest().is_some());
        }
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn purge_empties() {
        let q = q(QueueMode::Fifo);
        q.publish(msg(0, 0, b"x")).unwrap();
        q.purge();
        assert!(q.is_empty());
    }

    #[test]
    fn fault_drop_every() {
        let q = Queue::new(
            "t",
            QueueMode::Fifo,
            1024,
            FaultPlan { drop_every: 2, delay_us: 0 },
            Arc::new(AbortState::default()),
        );
        for e in 0..6 {
            q.publish(msg(0, e, b"x")).unwrap();
        }
        // publishes 2, 4, 6 dropped
        assert_eq!(q.len(), 3);
        assert_eq!(q.stats().drops, 3);
    }

    #[test]
    fn await_epoch_wakes_on_publish() {
        let q = Arc::new(q(QueueMode::LatestOnly));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.await_epoch(2));
        q.publish(msg(0, 1, b"stale")).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        q.publish(msg(0, 2, b"fresh")).unwrap();
        let m = waiter.join().unwrap().unwrap();
        assert_eq!(m.epoch, 2);
        assert_eq!(&m.payload[..], b"fresh");
    }

    #[test]
    fn await_version_is_barrier() {
        let q = Arc::new(q(QueueMode::Fifo));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.await_version(3));
        for e in 0..3 {
            q.publish(msg(e, 0, b"done")).unwrap();
        }
        waiter.join().unwrap().unwrap();
        assert_eq!(q.version(), 3);
    }

    #[test]
    fn await_epoch_timeout_expires_then_delivers() {
        let q = q(QueueMode::LatestOnly);
        // nothing published: expiry, not a hang
        assert!(q
            .await_epoch_timeout(1, Duration::from_millis(20))
            .unwrap()
            .is_none());
        // a stale epoch does not satisfy the wait
        q.publish(msg(0, 1, b"old")).unwrap();
        assert!(q
            .await_epoch_timeout(2, Duration::from_millis(20))
            .unwrap()
            .is_none());
        q.publish(msg(0, 2, b"fresh")).unwrap();
        let m = q
            .await_epoch_timeout(2, Duration::from_millis(20))
            .unwrap()
            .unwrap();
        assert_eq!(&m.payload[..], b"fresh");
    }

    #[test]
    fn abort_unblocks_await_epoch_timeout() {
        let abort = Arc::new(AbortState::default());
        let q = q_with_abort(QueueMode::LatestOnly, abort.clone());
        abort.trigger("boom");
        assert!(q.await_epoch_timeout(1, Duration::from_millis(10)).is_err());
    }

    #[test]
    fn await_version_timeout_expires() {
        let q = q(QueueMode::Fifo);
        assert!(!q.await_version_timeout(1, Duration::from_millis(20)).unwrap());
        q.publish(msg(0, 0, b"x")).unwrap();
        assert!(q.await_version_timeout(1, Duration::from_millis(20)).unwrap());
    }

    #[test]
    fn dropped_publish_does_not_bump_version() {
        let q = Queue::new(
            "t",
            QueueMode::Fifo,
            1024,
            FaultPlan { drop_every: 1, delay_us: 0 },
            Arc::new(AbortState::default()),
        );
        q.publish(msg(0, 0, b"x")).unwrap();
        assert_eq!(q.version(), 0);
        assert!(!q.await_version_timeout(1, Duration::from_millis(10)).unwrap());
    }

    #[test]
    fn abort_unblocks_await_epoch() {
        let abort = Arc::new(AbortState::default());
        let q = Arc::new(q_with_abort(QueueMode::LatestOnly, abort.clone()));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.await_epoch(1));
        std::thread::sleep(Duration::from_millis(10));
        assert!(abort.trigger("peer 0 failed"));
        q.wake_all();
        let err = waiter.join().unwrap().unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Aborted(_)),
            "expected Aborted, got {err}"
        );
        assert!(err.to_string().contains("peer 0 failed"), "{err}");
    }

    #[test]
    fn abort_unblocks_await_version() {
        let abort = Arc::new(AbortState::default());
        let q = Arc::new(q_with_abort(QueueMode::Fifo, abort.clone()));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.await_version(5));
        std::thread::sleep(Duration::from_millis(10));
        abort.trigger("boom");
        q.wake_all();
        assert!(waiter.join().unwrap().is_err());
        // timed variant errors too, rather than reporting a timeout
        assert!(q.await_version_timeout(5, Duration::from_millis(10)).is_err());
    }

    #[test]
    fn abort_first_reason_wins() {
        let abort = AbortState::default();
        assert!(!abort.is_aborted());
        assert!(abort.trigger("first"));
        assert!(!abort.trigger("second"));
        assert_eq!(abort.reason().as_deref(), Some("first"));
    }
}
