//! Full-scale specs of the paper's three model architectures, with the
//! calibration constants derived from the paper's tables (see the
//! module docs in `perfmodel`).

/// The paper's §IV-B architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PaperModel {
    /// VGG-11, 132.9 M params — the expensive workload (t2.large).
    Vgg11,
    /// MobileNetV3-Small, ~2.5 M params (t2.medium).
    MobilenetV3Small,
    /// SqueezeNet 1.1, ~1.2 M params (t2.medium).
    Squeezenet11,
}

impl PaperModel {
    /// Map a mini-model key (the runtime artifacts) to its full-scale
    /// paper counterpart for cloud extrapolation.
    pub fn from_key(key: &str) -> Option<Self> {
        if key.contains("vgg") {
            Some(Self::Vgg11)
        } else if key.contains("mobilenet") {
            Some(Self::MobilenetV3Small)
        } else if key.contains("squeezenet") {
            Some(Self::Squeezenet11)
        } else {
            None
        }
    }
}

/// Full-scale spec + calibration anchors.
#[derive(Debug, Clone, PartialEq)]
pub struct PaperModelSpec {
    pub kind: PaperModel,
    pub name: &'static str,
    /// Trainable parameters (paper §IV-B).
    pub params: u64,
    /// Per-sample gradient time on t2.large at large batch, ms
    /// (VGG anchored on Tables II/III; others scaled by the Table I
    /// per-batch ratios, instance factors normalized out).
    pub base_ms_per_sample: f64,
    /// Batch-amortized overhead constant `c` in (1 + c/B).
    pub batch_overhead: f64,
    /// Lambda sizing rule: resident base MB…
    pub lambda_base_mb: f64,
    /// …plus MB per sample of activation memory.
    pub lambda_mb_per_sample: f64,
    /// The instance type the paper settled on for this model (§IV-C).
    pub paper_instance: &'static str,
}

impl PaperModelSpec {
    /// Uncompressed f32 gradient size on the wire.
    pub fn gradient_bytes(&self) -> usize {
        self.params as usize * 4
    }
}

/// Calibrated catalog (see `perfmodel` module docs for derivations).
pub const PAPER_MODELS: &[PaperModelSpec] = &[
    PaperModelSpec {
        kind: PaperModel::Vgg11,
        name: "vgg11",
        params: 132_900_000,
        base_ms_per_sample: 16.17,
        batch_overhead: 40.0,
        lambda_base_mb: 1520.0,
        lambda_mb_per_sample: 2.81,
        paper_instance: "t2.large",
    },
    PaperModelSpec {
        kind: PaperModel::MobilenetV3Small,
        name: "mobilenet_v3_small",
        // Table I ratio vs VGG: 59.44 / 208.7 per-sample => 0.285
        params: 2_500_000,
        base_ms_per_sample: 4.61,
        batch_overhead: 40.0,
        lambda_base_mb: 430.0,
        lambda_mb_per_sample: 0.55,
        paper_instance: "t2.medium",
    },
    PaperModelSpec {
        kind: PaperModel::Squeezenet11,
        name: "squeezenet1.1",
        // Table I ratio vs VGG: 29.86 / 208.7 => 0.143
        params: 1_200_000,
        base_ms_per_sample: 2.31,
        batch_overhead: 40.0,
        lambda_base_mb: 400.0,
        lambda_mb_per_sample: 0.40,
        paper_instance: "t2.medium",
    },
];

/// Fetch a spec by kind.
pub fn paper_model(kind: PaperModel) -> &'static PaperModelSpec {
    PAPER_MODELS.iter().find(|s| s.kind == kind).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_key_maps_minis() {
        assert_eq!(PaperModel::from_key("mini_vgg_mnist"), Some(PaperModel::Vgg11));
        assert_eq!(
            PaperModel::from_key("mini_mobilenet_cifar"),
            Some(PaperModel::MobilenetV3Small)
        );
        assert_eq!(
            PaperModel::from_key("mini_squeezenet_mnist"),
            Some(PaperModel::Squeezenet11)
        );
        assert_eq!(PaperModel::from_key("resnet"), None);
    }

    #[test]
    fn paper_param_counts() {
        assert_eq!(paper_model(PaperModel::Vgg11).params, 132_900_000);
        assert!(paper_model(PaperModel::MobilenetV3Small).params < 3_000_000);
        assert!(paper_model(PaperModel::Squeezenet11).params < 1_500_000);
    }

    #[test]
    fn gradient_bytes_vgg_is_531mb() {
        let b = paper_model(PaperModel::Vgg11).gradient_bytes();
        assert_eq!(b, 531_600_000);
    }

    #[test]
    fn paper_instances() {
        assert_eq!(paper_model(PaperModel::Vgg11).paper_instance, "t2.large");
        assert_eq!(
            paper_model(PaperModel::Squeezenet11).paper_instance,
            "t2.medium"
        );
    }
}
