//! Analytic cloud-performance model calibrated to the paper's
//! measurements — the substitute for the authors' AWS testbed.
//!
//! The testbed here executes *mini* models on CPU-PJRT; the paper's
//! tables/figures are about full-scale VGG-11 / MobileNetV3-Small /
//! SqueezeNet-1.1 on t2 instances and Lambda. This module carries the
//! paper's own measurements as calibration anchors and exposes the
//! time model every cloud-scale harness driver uses:
//!
//! **Instance compute** (calibrated on Tables II/III, VGG-11/t2.large):
//!     per_sample_ms(B) = base_ms * (1 + c/B) / cpu_factor(instance)
//! with `base_ms = 16.17`, `c = 40` reproducing 258 s (B=1024) … 394.8 s
//! (B=64) per 15 000-sample partition within 2 %.
//!
//! **Lambda compute** (calibrated on Table II): lambda CPU share scales
//! with memory (AWS allocates ~1 vCPU per 1769 MB); an efficiency factor
//! 0.34 vs EC2 absorbs the container/IO overhead the paper observed.
//!
//! **Communication** (calibrated on Table I, VGG-11 send 7.38 s / recv
//! 15.55 s with 3 remote peers): effective send bandwidth 72 MB/s,
//! per-queue receive bandwidth 102.6 MB/s.
//!
//! Known paper inconsistency (soundness note): Table I's per-batch
//! compute time (104.37 s / 500-sample batch) implies ~12x slower
//! per-sample throughput than Tables II/III imply. Each harness driver
//! anchors on *its own* table; EXPERIMENTS.md discusses the conflict.

mod specs;

pub use specs::{paper_model, PaperModel, PaperModelSpec, PAPER_MODELS};

use std::time::Duration;

use crate::cloud::InstanceType;

/// vCPUs AWS grants a Lambda per MB of memory (full vCPU at 1769 MB).
pub const LAMBDA_MB_PER_VCPU: f64 = 1769.0;
/// Lambda-vs-EC2 compute efficiency (calibrated, see module docs).
pub const LAMBDA_EFFICIENCY: f64 = 0.34;
/// Modeled Lambda cold start (PyTorch-on-ARM image).
pub const LAMBDA_COLD_START: Duration = Duration::from_millis(2500);
/// Effective gradient publish bandwidth (bytes/s), Table I calibration.
pub const SEND_BW: f64 = 72.0e6;
/// Effective per-queue consume bandwidth (bytes/s).
pub const RECV_BW: f64 = 102.6e6;
/// Fixed per-message broker latency.
pub const MSG_LATENCY: Duration = Duration::from_millis(8);
/// Effective single-stream object-store PUT bandwidth (bytes/s) from
/// inside a Lambda (S3-class storage; the wire plane's park path).
pub const STORE_PUT_BW: f64 = 100.0e6;
/// Effective single-stream object-store GET bandwidth (bytes/s).
pub const STORE_GET_BW: f64 = 150.0e6;
/// Fixed per-request store latency (time to first byte).
pub const STORE_REQ_LATENCY: Duration = Duration::from_millis(12);

/// Per-sample gradient-computation time on an EC2 instance.
pub fn instance_per_sample(spec: &PaperModelSpec, inst: &InstanceType, batch: usize) -> Duration {
    let ms = spec.base_ms_per_sample * (1.0 + spec.batch_overhead / batch as f64)
        / inst.cpu_factor();
    Duration::from_secs_f64(ms / 1e3)
}

/// One batch on an EC2 instance.
pub fn instance_batch_time(spec: &PaperModelSpec, inst: &InstanceType, batch: usize) -> Duration {
    instance_per_sample(spec, inst, batch) * batch as u32
}

/// Sequential partition pass on an EC2 instance (the paper's
/// "without serverless" architecture): nbatches x batch time.
pub fn instance_partition_time(
    spec: &PaperModelSpec,
    inst: &InstanceType,
    batch: usize,
    nbatches: usize,
) -> Duration {
    instance_batch_time(spec, inst, batch) * nbatches as u32
}

/// Lambda CPU factor relative to t2.large for a given memory size.
pub fn lambda_cpu_factor(memory_mb: u32) -> f64 {
    (memory_mb as f64 / LAMBDA_MB_PER_VCPU) / 2.0 * LAMBDA_EFFICIENCY
}

/// One batch inside a Lambda sized at `memory_mb` (excludes cold start;
/// the fan-out scheduler adds it to wall time).
pub fn lambda_batch_time(spec: &PaperModelSpec, memory_mb: u32, batch: usize) -> Duration {
    let ms = spec.base_ms_per_sample * (1.0 + spec.batch_overhead / batch as f64)
        / lambda_cpu_factor(memory_mb);
    Duration::from_secs_f64(ms * batch as f64 / 1e3)
}

/// The paper's Table II Lambda sizing rule ("memory size was set to
/// match the minimal functional requirements"): a model-resident base
/// plus per-sample activation memory. Calibrated on VGG-11
/// (1520 MB + 2.81 MB/sample reproduces 1700/1800/2800/4400 MB).
pub fn lambda_memory_for(spec: &PaperModelSpec, batch: usize) -> u32 {
    let mb = spec.lambda_base_mb + spec.lambda_mb_per_sample * batch as f64;
    // round up to 100MB like an operator would
    ((mb / 100.0).ceil() * 100.0) as u32
}

/// Time to publish one (possibly compressed) gradient to the broker.
pub fn send_time(gradient_bytes: usize, compression_ratio: f64) -> Duration {
    let wire = gradient_bytes as f64 / compression_ratio.max(1e-9);
    MSG_LATENCY + Duration::from_secs_f64(wire / SEND_BW)
}

/// Time to consume gradients from `remote_peers` queues.
pub fn recv_time(gradient_bytes: usize, remote_peers: usize, compression_ratio: f64) -> Duration {
    let wire = gradient_bytes as f64 / compression_ratio.max(1e-9);
    MSG_LATENCY * remote_peers as u32
        + Duration::from_secs_f64(wire * remote_peers as f64 / RECV_BW)
}

/// Modeled time to park `wire_bytes` in the object store (a gradient
/// return or params upload). Fed by the wire plane's bytes-on-wire:
/// compression moves this transfer term, never the compute terms.
pub fn store_put_time(wire_bytes: usize) -> Duration {
    STORE_REQ_LATENCY + Duration::from_secs_f64(wire_bytes as f64 / STORE_PUT_BW)
}

/// Modeled time to read `wire_bytes` back from the object store.
pub fn store_get_time(wire_bytes: usize) -> Duration {
    STORE_REQ_LATENCY + Duration::from_secs_f64(wire_bytes as f64 / STORE_GET_BW)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud;

    fn vgg() -> &'static PaperModelSpec {
        paper_model(PaperModel::Vgg11)
    }

    fn close(d: Duration, want_s: f64, tol: f64) -> bool {
        (d.as_secs_f64() - want_s).abs() / want_s < tol
    }

    #[test]
    fn table3_instance_anchor_times() {
        // Table III: VGG-11, MNIST, t2.large, 15 000-sample partition
        let large = cloud::instance("t2.large").unwrap();
        let cases = [(1024usize, 15usize, 258.0f64), (512, 30, 278.4), (128, 118, 330.4), (64, 235, 394.8)];
        for (b, n, want) in cases {
            let got = instance_partition_time(vgg(), large, b, n);
            // B=1024/64 anchor exactly; the paper's 512/128 rows sit ~4%
            // above the (1 + c/B) trend the other rows fix.
            assert!(close(got, want, 0.05), "B={b}: got {:?} want {want}s", got);
        }
    }

    #[test]
    fn table2_lambda_anchor_times() {
        // Table II: per-batch Lambda times, calibrated within ~25 %
        let cases = [
            (1024usize, 4400u32, 41.2f64),
            (512, 2800, 28.1),
            (128, 1800, 12.9),
            (64, 1700, 10.5),
        ];
        for (b, mem, want) in cases {
            let got = lambda_batch_time(vgg(), mem, b);
            assert!(
                close(got, want, 0.30),
                "B={b} mem={mem}: got {:?} want {want}s",
                got
            );
        }
    }

    #[test]
    fn table2_lambda_memory_sizing() {
        let cases = [(1024usize, 4400u32), (512, 2800), (128, 1800), (64, 1700)];
        for (b, want) in cases {
            let got = lambda_memory_for(vgg(), b);
            assert!(
                (got as f64 - want as f64).abs() / want as f64 <= 0.10,
                "B={b}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn fig3_headline_improvement_shape() {
        // 4 workers, B=64: serverless wall (parallel lambdas) vs
        // sequential instance — the paper reports 97.34 % improvement.
        let large = cloud::instance("t2.large").unwrap();
        let nbatches = 235;
        let seq = instance_partition_time(vgg(), large, 64, nbatches);
        let mem = lambda_memory_for(vgg(), 64);
        let lam = lambda_batch_time(vgg(), mem, 64) + LAMBDA_COLD_START;
        let improvement = 1.0 - lam.as_secs_f64() / seq.as_secs_f64();
        assert!(
            improvement > 0.95,
            "improvement {improvement} should be ~0.97"
        );
    }

    #[test]
    fn table1_send_recv_anchor() {
        // Table I, VGG-11: send 7.38 s, recv 15.55 s across 3 peers
        let bytes = vgg().gradient_bytes();
        assert!(close(send_time(bytes, 1.0), 7.38, 0.05));
        assert!(close(recv_time(bytes, 3, 1.0), 15.55, 0.05));
    }

    #[test]
    fn compression_shrinks_comm() {
        let bytes = vgg().gradient_bytes();
        let plain = send_time(bytes, 1.0);
        let comp = send_time(bytes, 5.33);
        assert!(comp < plain);
        let ratio = plain.as_secs_f64() / comp.as_secs_f64();
        assert!(ratio > 4.0 && ratio < 5.5, "ratio {ratio}");
    }

    #[test]
    fn store_transfer_latency_floor_and_scaling() {
        // zero bytes still pays the request latency
        assert_eq!(store_put_time(0), STORE_REQ_LATENCY);
        assert_eq!(store_get_time(0), STORE_REQ_LATENCY);
        // gets are faster than puts for the same payload
        assert!(store_get_time(1_000_000) < store_put_time(1_000_000));
        // a qsgd:16-sized park (18.75% of raw) beats the dense park
        let dense = store_put_time(1_000_004);
        let quant = store_put_time(187_510);
        assert!(quant < dense);
        let saved = dense.as_secs_f64() - quant.as_secs_f64();
        // the savings are pure transfer: (1_000_004 - 187_510) / PUT_BW
        assert!((saved - 812_494.0 / STORE_PUT_BW).abs() < 1e-9);
    }

    #[test]
    fn smaller_models_are_faster() {
        let large = cloud::instance("t2.large").unwrap();
        let sq = instance_batch_time(paper_model(PaperModel::Squeezenet11), large, 64);
        let mb = instance_batch_time(paper_model(PaperModel::MobilenetV3Small), large, 64);
        let vg = instance_batch_time(vgg(), large, 64);
        assert!(sq < mb && mb < vg);
    }

    #[test]
    fn lambda_memory_monotone_in_batch() {
        for m in PAPER_MODELS {
            let spec = paper_model(m.kind);
            assert!(lambda_memory_for(spec, 64) < lambda_memory_for(spec, 1024));
        }
    }
}
