//! Dataset preprocessing (§III-B.1): min-max scaling, standardization,
//! l2 normalization — applied before partitioning/upload in the paper.

/// Scale features into `[0, 1]` (no-op on constant data).
pub fn minmax_scale(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in x.iter() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = hi - lo;
    if span <= f32::EPSILON {
        return;
    }
    for v in x.iter_mut() {
        *v = (*v - lo) / span;
    }
}

/// Zero mean, unit variance (population std; no-op on constant data).
pub fn standardize(x: &mut [f32]) {
    let n = x.len();
    if n == 0 {
        return;
    }
    let mean = x.iter().sum::<f32>() / n as f32;
    let var = x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n as f32;
    let std = var.sqrt();
    if std <= f32::EPSILON {
        return;
    }
    for v in x.iter_mut() {
        *v = (*v - mean) / std;
    }
}

/// Scale the whole buffer to unit l2 norm (no-op on the zero vector).
pub fn normalize_l2(x: &mut [f32]) {
    let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
    if norm <= f32::EPSILON {
        return;
    }
    for v in x.iter_mut() {
        *v /= norm;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_bounds() {
        let mut x = vec![-3.0, 0.0, 7.0, 2.0];
        minmax_scale(&mut x);
        assert_eq!(x[0], 0.0);
        assert_eq!(x[2], 1.0);
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn minmax_constant_noop() {
        let mut x = vec![5.0; 4];
        minmax_scale(&mut x);
        assert_eq!(x, vec![5.0; 4]);
    }

    #[test]
    fn standardize_moments() {
        let mut x: Vec<f32> = (0..100).map(|i| i as f32).collect();
        standardize(&mut x);
        let mean = x.iter().sum::<f32>() / 100.0;
        let var = x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 100.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn l2_norm_is_one() {
        let mut x = vec![3.0, 4.0];
        normalize_l2(&mut x);
        let n = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_vectors_survive() {
        let mut x = vec![0.0; 8];
        normalize_l2(&mut x);
        standardize(&mut x);
        minmax_scale(&mut x);
        assert_eq!(x, vec![0.0; 8]);
    }
}
