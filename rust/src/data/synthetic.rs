//! Deterministic class-separable image generator (MNIST/CIFAR-shaped).

use super::Dataset;
use crate::util::Rng;

/// Which real dataset's *shape* the synthetic set mimics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28x28x1, 10 classes (MNIST).
    Mnist,
    /// 32x32x3, 10 classes (CIFAR-10).
    Cifar,
}

impl DatasetKind {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "mnist" => Some(Self::Mnist),
            "cifar" => Some(Self::Cifar),
            _ => None,
        }
    }

    pub fn dims(self) -> (usize, usize, usize) {
        match self {
            Self::Mnist => (28, 28, 1),
            Self::Cifar => (32, 32, 3),
        }
    }

    pub fn nclass(self) -> usize {
        10
    }
}

/// Generator: per-class smooth prototypes + Gaussian noise.
///
/// Prototypes are low-frequency (sums of a few random 2-D cosines) so
/// classes occupy distinct smooth manifolds a small CNN can separate;
/// noise std 0.15 keeps Bayes error low but non-zero.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    kind: DatasetKind,
    seed: u64,
    noise_std: f32,
    /// Prototype seed — defaults to `seed`. A validation set shares the
    /// training set's prototypes (same classes!) but different noise:
    /// `SyntheticDataset::new(kind, val_seed).with_prototype_seed(train_seed)`.
    proto_seed: Option<u64>,
}

impl SyntheticDataset {
    pub fn new(kind: DatasetKind, seed: u64) -> Self {
        Self { kind, seed, noise_std: 0.15, proto_seed: None }
    }

    pub fn with_noise(mut self, std: f32) -> Self {
        self.noise_std = std;
        self
    }

    /// Share another dataset's class prototypes (e.g. train/val splits).
    pub fn with_prototype_seed(mut self, seed: u64) -> Self {
        self.proto_seed = Some(seed);
        self
    }

    fn prototypes(&self) -> Vec<Vec<f32>> {
        let (h, w, c) = self.kind.dims();
        let nclass = self.kind.nclass();
        let mut rng = Rng::seed_from_u64(self.proto_seed.unwrap_or(self.seed) ^ 0x70726f746f);
        (0..nclass)
            .map(|_| {
                // 3 random cosine components per channel
                let mut img = vec![0f32; h * w * c];
                for ch in 0..c {
                    for _ in 0..3 {
                        let fx = rng.gen_range_f32(0.5, 3.0) * std::f32::consts::PI;
                        let fy = rng.gen_range_f32(0.5, 3.0) * std::f32::consts::PI;
                        let phase = rng.gen_range_f32(0.0, std::f32::consts::TAU);
                        let amp = rng.gen_range_f32(0.2, 0.5);
                        for yy in 0..h {
                            for xx in 0..w {
                                let v = amp
                                    * (fx * xx as f32 / w as f32
                                        + fy * yy as f32 / h as f32
                                        + phase)
                                        .cos();
                                img[(yy * w + xx) * c + ch] += v;
                            }
                        }
                    }
                }
                img
            })
            .collect()
    }

    /// Generate `n` labeled samples (labels round-robin so every
    /// partition sees every class).
    pub fn generate(&self, n: usize) -> Dataset {
        let (h, w, c) = self.kind.dims();
        let nclass = self.kind.nclass();
        let protos = self.prototypes();
        let mut rng = Rng::seed_from_u64(self.seed);
        let elems = h * w * c;
        let mut x = Vec::with_capacity(n * elems);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = (i % nclass) as i32;
            let proto = &protos[label as usize];
            for &p in proto.iter() {
                x.push(p + self.noise_std * rng.gen_normal());
            }
            y.push(label);
        }
        Dataset { x, y, h, w, c, nclass }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(20);
        let b = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(20);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn seeds_differ() {
        let a = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(20);
        let b = SyntheticDataset::new(DatasetKind::Mnist, 2).generate(20);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn shapes_match_kind() {
        let d = SyntheticDataset::new(DatasetKind::Cifar, 3).generate(5);
        assert_eq!((d.h, d.w, d.c), (32, 32, 3));
        assert_eq!(d.x.len(), 5 * 32 * 32 * 3);
        assert_eq!(d.y.len(), 5);
    }

    #[test]
    fn labels_cover_all_classes() {
        let d = SyntheticDataset::new(DatasetKind::Mnist, 4).generate(30);
        for cls in 0..10 {
            assert!(d.y.contains(&cls), "class {cls} missing");
        }
    }

    #[test]
    fn prototype_seed_shares_classes() {
        // same prototypes, different noise
        let train = SyntheticDataset::new(DatasetKind::Mnist, 1).generate(10);
        let val = SyntheticDataset::new(DatasetKind::Mnist, 99)
            .with_prototype_seed(1)
            .generate(10);
        assert_ne!(train.x, val.x, "noise must differ");
        // class-0 samples from each set are closer than cross-class
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        let same_class = dist(train.image(0), val.image(0));
        let diff_class = dist(train.image(0), val.image(1));
        assert!(same_class < diff_class);
    }

    #[test]
    fn classes_are_separable() {
        // mean intra-class distance must be well below inter-class
        let d = SyntheticDataset::new(DatasetKind::Mnist, 5).generate(100);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        // samples 0 and 10 share class 0; 0 and 1 differ
        let intra = dist(d.image(0), d.image(10));
        let inter = dist(d.image(0), d.image(1));
        assert!(
            intra < inter,
            "intra {intra} should be < inter {inter}"
        );
    }
}
