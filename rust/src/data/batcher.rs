//! Epoch batching: "Randomly partition the subset D_r into m batches of
//! size B" (Algorithm 1). Deterministic per (seed, epoch).

use super::Dataset;
use crate::util::Rng;

/// One training batch in the layout the AOT grad artifact expects:
/// `x` is `[b, h, w, c]` f32, `y` is `[b]` i32.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub size: usize,
}

/// Shuffling batcher over a peer's partition. Trailing samples that do
/// not fill a batch are dropped (the AOT artifacts are shape-specialized,
/// exactly like a `drop_last=True` PyTorch dataloader).
#[derive(Debug)]
pub struct Batcher {
    batch_size: usize,
    seed: u64,
}

impl Batcher {
    pub fn new(batch_size: usize, seed: u64) -> Self {
        assert!(batch_size > 0, "batch_size must be positive");
        Self { batch_size, seed }
    }

    /// Number of full batches an epoch over `data` yields.
    pub fn num_batches(&self, data: &Dataset) -> usize {
        data.len() / self.batch_size
    }

    /// Materialize the shuffled batches for `epoch`.
    pub fn epoch_batches(&self, data: &Dataset, epoch: usize) -> Vec<Batch> {
        let mut idx: Vec<usize> = (0..data.len()).collect();
        let mut rng =
            Rng::seed_from_u64(self.seed ^ (epoch as u64).wrapping_mul(0x9e3779b9));
        rng.shuffle(&mut idx);
        let elems = data.sample_elems();
        idx.chunks_exact(self.batch_size)
            .map(|chunk| {
                let mut x = Vec::with_capacity(self.batch_size * elems);
                let mut y = Vec::with_capacity(self.batch_size);
                for &i in chunk {
                    x.extend_from_slice(data.image(i));
                    y.push(data.y[i]);
                }
                Batch { x, y, size: self.batch_size }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, SyntheticDataset};

    fn data(n: usize) -> Dataset {
        SyntheticDataset::new(DatasetKind::Mnist, 9).generate(n)
    }

    #[test]
    fn batch_shapes() {
        let d = data(50);
        let b = Batcher::new(16, 1);
        let batches = b.epoch_batches(&d, 0);
        assert_eq!(batches.len(), 3); // 50/16, drop_last
        for batch in &batches {
            assert_eq!(batch.y.len(), 16);
            assert_eq!(batch.x.len(), 16 * d.sample_elems());
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let d = data(64);
        let b = Batcher::new(32, 1);
        let e0 = b.epoch_batches(&d, 0);
        let e1 = b.epoch_batches(&d, 1);
        assert_ne!(e0[0].y, e1[0].y, "different epochs must reshuffle");
        // but the same epoch is reproducible
        let e0b = b.epoch_batches(&d, 0);
        assert_eq!(e0[0].y, e0b[0].y);
    }

    #[test]
    fn every_sample_used_once_per_epoch() {
        let d = data(48);
        let b = Batcher::new(16, 7);
        let batches = b.epoch_batches(&d, 3);
        let mut seen: Vec<i32> = batches.iter().flat_map(|b| b.y.clone()).collect();
        seen.sort_unstable();
        let mut want = d.y.clone();
        want.sort_unstable();
        assert_eq!(seen, want);
    }

    #[test]
    fn num_batches_matches() {
        let d = data(100);
        assert_eq!(Batcher::new(30, 0).num_batches(&d), 3);
        assert_eq!(Batcher::new(100, 0).num_batches(&d), 1);
        assert_eq!(Batcher::new(101, 0).num_batches(&d), 0);
    }
}
