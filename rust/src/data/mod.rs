//! Synthetic MNIST/CIFAR-like datasets, preprocessing, partitioning and
//! batching (the paper's §III-B.1 pipeline).
//!
//! The paper trains on MNIST and CIFAR-10; this testbed has neither the
//! downloads nor the need for them — every experiment measures time /
//! cost / communication / convergence *dynamics*, which depend on tensor
//! shapes and learnability, not on the specific pixels. The generator
//! emits a deterministic, class-separable dataset: each class gets a
//! smooth random prototype image and samples are prototype + Gaussian
//! noise, so small CNNs genuinely learn (loss falls, accuracy rises) —
//! exercised end-to-end in `examples/e2e_train.rs`.

mod batcher;
mod preprocess;
mod synthetic;

pub use batcher::{Batch, Batcher};
pub use preprocess::{minmax_scale, normalize_l2, standardize};
pub use synthetic::{DatasetKind, SyntheticDataset};

use crate::error::{Error, Result};

/// An in-memory dataset: row-major NHWC images + int labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `[n, h, w, c]` flattened f32 pixels.
    pub x: Vec<f32>,
    /// `n` class ids.
    pub y: Vec<i32>,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub nclass: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample_elems(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow sample `i` as a pixel slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.sample_elems();
        &self.x[i * n..(i + 1) * n]
    }

    /// Split into `p` equal unique partitions (paper: "Load a unique
    /// partition of data D_r"). Remainder samples go to the last peers.
    pub fn partition(&self, p: usize) -> Result<Vec<Dataset>> {
        if p == 0 || p > self.len() {
            return Err(Error::Data(format!(
                "cannot partition {} samples into {} peers",
                self.len(),
                p
            )));
        }
        let base = self.len() / p;
        let rem = self.len() % p;
        let elems = self.sample_elems();
        let mut out = Vec::with_capacity(p);
        let mut start = 0usize;
        for r in 0..p {
            let take = base + usize::from(r >= p - rem);
            out.push(Dataset {
                x: self.x[start * elems..(start + take) * elems].to_vec(),
                y: self.y[start..start + take].to_vec(),
                h: self.h,
                w: self.w,
                c: self.c,
                nclass: self.nclass,
            });
            start += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        SyntheticDataset::new(DatasetKind::Mnist, 7).generate(103)
    }

    #[test]
    fn partition_covers_all_samples() {
        let d = tiny();
        let parts = d.partition(4).unwrap();
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, d.len());
        // sizes differ by at most 1
        let sizes: Vec<_> = parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn partition_preserves_bytes() {
        let d = tiny();
        let parts = d.partition(3).unwrap();
        let rebuilt_x: Vec<f32> =
            parts.iter().flat_map(|p| p.x.iter().copied()).collect();
        let rebuilt_y: Vec<i32> =
            parts.iter().flat_map(|p| p.y.iter().copied()).collect();
        assert_eq!(rebuilt_x, d.x);
        assert_eq!(rebuilt_y, d.y);
    }

    #[test]
    fn partition_rejects_degenerate() {
        let d = tiny();
        assert!(d.partition(0).is_err());
        assert!(d.partition(d.len() + 1).is_err());
    }

    #[test]
    fn image_slices_are_disjoint_views() {
        let d = tiny();
        assert_eq!(d.image(0).len(), d.sample_elems());
        assert_eq!(d.image(1).len(), d.sample_elems());
    }
}
