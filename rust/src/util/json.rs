//! Minimal JSON: a value model, a recursive-descent parser and a writer.
//!
//! Used for `artifacts/manifest.json` (produced by the python AOT path),
//! experiment configs, and machine-readable experiment reports. Supports
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path hint (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------------------------------------------- construction

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    // -------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        Json::parse(&std::fs::read_to_string(path)?)
    }

    // -------------------------------------------------------- writing

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {other:?} at byte {}",
                self.i
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| Error::Json(e.to_string()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number {text:?}: {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::Json("truncated \\u".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|e| Error::Json(e.to_string()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| Error::Json(e.to_string()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| Error::Json(e.to_string()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or ] got {other:?} at byte {}",
                        self.i
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected , or }} got {other:?} at byte {}",
                        self.i
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"models":{"m":{"n":42,"arr":[1.5,true,"s"]}},"v":1}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let out = Json::Str("tab\there".into()).to_string();
        assert_eq!(out, r#""tab\there""#);
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("name", "exp1").set("count", 3usize).set("ok", true);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("count").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version": 1, "models": {"mini_vgg_mnist": {
            "param_count": 98442, "input": [28, 28, 1],
            "artifacts": {"grad": {"64": "g.hlo.txt"}}}}}"#;
        let j = Json::parse(text).unwrap();
        let m = j.get("models").unwrap().get("mini_vgg_mnist").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_usize(), Some(98442));
        assert_eq!(m.get("input").unwrap().as_arr().unwrap()[0].as_usize(), Some(28));
    }
}
