//! Shared retry/backoff policy for every transient-failure site.
//!
//! One [`RetryPolicy`] now drives three planes:
//!
//! - the Step-Functions-style branch invocations (`--lambda-retries` /
//!   `--retry-backoff-ms`, the PR-1 knobs — their exhaustion semantics
//!   are unchanged and regression-tested);
//! - [`crate::store::ObjectStore`] puts/gets under injected store
//!   faults (`--store-retries` / `--store-backoff-ms`);
//! - [`crate::broker::Broker`] publishes under injected drop faults
//!   (same store knobs — one I/O policy, two substrates).
//!
//! The policy is a pure value: attempts, exponential backoff base, and
//! a seeded jitter hash. Backoff sleeps are *measured* time only — the
//! modeled walls (paper-table mode) never include them, which is what
//! keeps a disarmed chaos run byte-identical to the plain path.

use std::time::Duration;

use crate::error::{Error, Result};

/// Retry policy for transient failures (Step Functions' `Retry`, the
/// S3 SDK's exponential backoff).
///
/// The default (3 attempts, no backoff) matches the policy that was
/// hardcoded before the knobs existed, so default runs are unchanged.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts (the first try counts; minimum 1).
    pub max_attempts: u32,
    /// Base sleep before the first retry; attempt `k` waits
    /// `backoff * 2^(k-1)` plus seeded jitter. Measured time only —
    /// modeled walls never include backoff sleeps.
    pub backoff: Duration,
    /// Seed for the deterministic jitter (same seed → same delays).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 3, backoff: Duration::ZERO, jitter_seed: 0 }
    }
}

impl RetryPolicy {
    /// Policy from the config knobs, with a per-peer jitter seed so
    /// colliding retries from different peers decorrelate.
    pub fn configured(max_attempts: u32, backoff_ms: u64, jitter_seed: u64) -> Self {
        Self {
            max_attempts: max_attempts.max(1),
            backoff: Duration::from_millis(backoff_ms),
            jitter_seed,
        }
    }

    /// Sleep owed before retry attempt `attempt` (1-based over
    /// retries): exponential base plus jitter in `[0, base/2]`.
    pub fn backoff_delay(&self, attempt: u32) -> Duration {
        if self.backoff.is_zero() || attempt == 0 {
            return Duration::ZERO;
        }
        let base = self.backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(10));
        let half = base.as_nanos() as u64 / 2;
        let jitter = if half == 0 {
            0
        } else {
            jitter_hash(self.jitter_seed ^ u64::from(attempt)) % (half + 1)
        };
        base + Duration::from_nanos(jitter)
    }

    /// Run `op` under this policy: up to `max_attempts` tries, sleeping
    /// the backoff between them. `on_retry` is called once per *extra*
    /// attempt (the retry accounting hook — `store.retries`,
    /// `broker.retries`); the final error is returned verbatim when
    /// every attempt fails, preserving the PR-1 exhaustion semantics.
    pub fn run<T>(
        &self,
        mut op: impl FnMut() -> Result<T>,
        mut on_retry: impl FnMut(),
    ) -> Result<T> {
        let mut last: Option<Error> = None;
        for attempt in 0..self.max_attempts.max(1) {
            if attempt > 0 {
                on_retry();
                let delay = self.backoff_delay(attempt);
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
            }
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| Error::Runtime("retry loop ran zero attempts".into())))
    }
}

/// splitmix64 — a tiny stateless hash for deterministic retry jitter.
fn jitter_hash(state: u64) -> u64 {
    let mut z = state.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_historical_policy() {
        let p = RetryPolicy::default();
        assert_eq!(p.max_attempts, 3);
        assert!(p.backoff.is_zero());
        assert!(p.backoff_delay(1).is_zero());
    }

    #[test]
    fn configured_clamps_to_one_attempt() {
        let p = RetryPolicy::configured(0, 0, 0);
        assert_eq!(p.max_attempts, 1);
    }

    #[test]
    fn backoff_grows_exponentially_with_bounded_jitter() {
        let p = RetryPolicy::configured(5, 8, 42);
        let d1 = p.backoff_delay(1);
        let d3 = p.backoff_delay(3);
        assert!(d1 >= Duration::from_millis(8) && d1 <= Duration::from_millis(12));
        assert!(d3 >= Duration::from_millis(32) && d3 <= Duration::from_millis(48));
        // deterministic: same policy, same attempt, same delay
        assert_eq!(d3, p.backoff_delay(3));
    }

    #[test]
    fn run_retries_then_succeeds_and_counts() {
        let p = RetryPolicy::configured(3, 0, 0);
        let mut fails = 2;
        let mut retries = 0u64;
        let out = p
            .run(
                || {
                    if fails > 0 {
                        fails -= 1;
                        Err(Error::Store("transient".into()))
                    } else {
                        Ok(7u32)
                    }
                },
                || retries += 1,
            )
            .unwrap();
        assert_eq!(out, 7);
        assert_eq!(retries, 2, "two extra attempts beyond the first");
    }

    #[test]
    fn run_exhaustion_returns_last_error() {
        let p = RetryPolicy::configured(2, 0, 0);
        let mut retries = 0u64;
        let err = p
            .run(|| Err::<(), _>(Error::Store("still down".into())), || retries += 1)
            .unwrap_err();
        assert!(err.to_string().contains("still down"));
        assert_eq!(retries, 1);
    }
}
