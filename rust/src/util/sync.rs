//! Small synchronization primitives the std library lacks.

use std::sync::{Condvar, Mutex};

/// Counting semaphore (Mutex + Condvar). Used to bound concurrent PJRT
/// executions in the engine and in-flight branches of a FaaS fan-out.
pub struct Semaphore {
    permits: Mutex<usize>,
    available: Condvar,
}

impl Semaphore {
    /// A semaphore with `permits` slots (clamped to at least 1).
    pub fn new(permits: usize) -> Self {
        Self { permits: Mutex::new(permits.max(1)), available: Condvar::new() }
    }

    /// Block until a permit is free; the guard releases it on drop.
    pub fn acquire(&self) -> SemaphorePermit<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.available.wait(p).unwrap();
        }
        *p -= 1;
        SemaphorePermit { sem: self }
    }
}

/// RAII permit from [`Semaphore::acquire`].
pub struct SemaphorePermit<'a> {
    sem: &'a Semaphore,
}

impl Drop for SemaphorePermit<'_> {
    fn drop(&mut self) {
        *self.sem.permits.lock().unwrap() += 1;
        self.sem.available.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn semaphore_bounds_concurrency() {
        let sem = Arc::new(Semaphore::new(2));
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let sem = sem.clone();
                let live = live.clone();
                let peak = peak.clone();
                std::thread::spawn(move || {
                    let _slot = sem.acquire();
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(5));
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn zero_permits_clamps_to_one() {
        let sem = Semaphore::new(0);
        let _slot = sem.acquire(); // must not deadlock
    }
}
