//! Cheap-to-clone immutable byte buffer (stand-in for `bytes::Bytes`).
//!
//! Broker messages and stored objects are shared across peers/threads;
//! cloning must be O(1) so the hot gradient-exchange path never copies
//! payloads.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Immutable, reference-counted byte buffer.
#[derive(Clone, Default)]
pub struct Bytes {
    data: Arc<[u8]>,
}

impl Bytes {
    pub fn new() -> Self {
        Self { data: Arc::from(&[][..]) }
    }

    pub fn from_static(s: &'static [u8]) -> Self {
        Self { data: Arc::from(s) }
    }

    pub fn copy_from_slice(s: &[u8]) -> Self {
        Self { data: Arc::from(s) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.data.to_vec()
    }
}

impl Deref for Bytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.data
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        &self.data
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Self {
        Self { data: Arc::from(v.into_boxed_slice()) }
    }
}

impl From<&[u8]> for Bytes {
    fn from(s: &[u8]) -> Self {
        Self::copy_from_slice(s)
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Self) -> bool {
        self.data[..] == other.data[..]
    }
}

impl Eq for Bytes {}

impl fmt::Debug for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Bytes({} bytes)", self.len())
    }
}

/// Pack a `f32` slice into little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Unpack little-endian bytes into `f32`s (length must be a multiple of 4).
pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_is_shallow() {
        let b = Bytes::from(vec![1u8, 2, 3]);
        let c = b.clone();
        assert_eq!(b, c);
        assert_eq!(Arc::strong_count(&b.data), 2);
    }

    #[test]
    fn roundtrip_f32() {
        let v = vec![1.5f32, -0.25, f32::MAX, 0.0];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&v)), v);
    }

    #[test]
    fn deref_and_slice() {
        let b = Bytes::from_static(b"hello");
        assert_eq!(&b[..2], b"he");
        assert_eq!(b.len(), 5);
        assert!(!b.is_empty());
    }
}
