//! In-tree replacements for the usual ecosystem crates — this build is
//! fully offline, so the crate carries its own byte buffer, PRNG and
//! JSON implementation (each small, tested, and tailored to what the
//! system actually needs).

pub mod bytes;
pub mod json;
pub mod retry;
pub mod rng;
pub mod sync;

pub use bytes::Bytes;
pub use json::Json;
pub use retry::RetryPolicy;
pub use rng::Rng;
pub use sync::{Semaphore, SemaphorePermit};
