//! Deterministic PRNG (xoshiro256** seeded via splitmix64) — the crate's
//! only randomness source, so every experiment is reproducible from the
//! config seed.

/// xoshiro256** (Blackman & Vigna). Not cryptographic; plenty for
/// shuffling, synthetic data and QSGD's stochastic rounding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut x = seed;
        Self {
            s: [
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Uniform usize in [0, n) — n must be > 0. Uses rejection-free
    /// multiply-shift (tiny bias acceptable for shuffling).
    pub fn gen_below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f32().max(1e-7);
        let u2 = self.gen_f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_below(i + 1);
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.gen_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn mean_is_half() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.gen_f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gen_below_in_range() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            assert!(r.gen_below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
