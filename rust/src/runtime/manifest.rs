//! Artifact manifest: the contract between the python AOT path and the
//! rust runtime (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::Json;

/// One model entry: shapes + artifact file names.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// `<model>_<dataset>` key.
    pub key: String,
    pub model: String,
    pub dataset: String,
    pub param_count: usize,
    /// (h, w, c).
    pub input: (usize, usize, usize),
    pub nclass: usize,
    /// batch size -> grad artifact file.
    pub grad: BTreeMap<usize, String>,
    /// batch size -> stacking factor k -> stacked grad artifact taking k
    /// micro-batches and returning per-branch (losses\[k\], grads\[k, P\])
    /// with no cross-lane reduction (manifest schema v2; empty for v1).
    pub grad_stacked: BTreeMap<usize, BTreeMap<usize, String>>,
    /// batch size -> no-pallas ablation grad artifact.
    pub grad_nopallas: BTreeMap<usize, String>,
    /// batch size -> eval artifact file.
    pub eval: BTreeMap<usize, String>,
    pub update: String,
    /// Raw little-endian f32 initial parameters.
    pub init_params: String,
    /// Per-layer `(name, element count)` slices of the packed params
    /// vector, in pack order — the shard-plane layout source for
    /// `--params-sharding layer`. Empty when the compiler did not emit
    /// per-layer shapes (older artifacts); layer sharding then errors
    /// actionably instead of guessing.
    pub params_spec: Vec<(String, usize)>,
}

/// Parse `[{"name": ..., "size": N, ...}, ...]` (per-layer params
/// slices; `shape`/`offset` are informational and ignored here).
fn params_spec(json: &Json) -> Result<Vec<(String, usize)>> {
    let Some(arr) = json.as_arr() else {
        return Err(Error::Json("params_spec must be an array".into()));
    };
    let mut out = Vec::with_capacity(arr.len());
    for entry in arr {
        let name = entry
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::Json("params_spec name must be a string".into()))?
            .to_string();
        let size = entry
            .req("size")?
            .as_usize()
            .ok_or_else(|| Error::Json("params_spec size must be an integer".into()))?;
        out.push((name, size));
    }
    Ok(out)
}

/// The QSGD kernel artifact pair (rust<->kernel cross-validation).
#[derive(Debug, Clone)]
pub struct QsgdEntry {
    pub n: usize,
    pub s: u8,
    pub encode: String,
    pub decode: String,
}

/// Newest manifest schema this runtime understands.
pub const MANIFEST_VERSION: u64 = 2;

/// Parsed manifest plus its directory (file names resolve against it).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Schema version the artifacts were written with (1 if absent).
    pub version: u64,
    pub models: BTreeMap<String, ModelEntry>,
    pub qsgd: QsgdEntry,
}

fn batch_map(json: &Json) -> Result<BTreeMap<usize, String>> {
    let mut out = BTreeMap::new();
    if let Some(obj) = json.as_obj() {
        for (k, v) in obj {
            let b: usize = k
                .parse()
                .map_err(|_| Error::Json(format!("bad batch key {k:?}")))?;
            let file = v
                .as_str()
                .ok_or_else(|| Error::Json("artifact path must be a string".into()))?;
            out.insert(b, file.to_string());
        }
    }
    Ok(out)
}

/// Parse `{"<batch>": {"<k>": "file", ...}, ...}` (schema v2 grad_stacked).
fn stacked_map(json: &Json) -> Result<BTreeMap<usize, BTreeMap<usize, String>>> {
    let mut out = BTreeMap::new();
    if let Some(obj) = json.as_obj() {
        for (k, v) in obj {
            let b: usize = k
                .parse()
                .map_err(|_| Error::Json(format!("bad batch key {k:?}")))?;
            out.insert(b, batch_map(v)?);
        }
    }
    Ok(out)
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let json = Json::parse_file(&path)?;
        let version = json.get("version").and_then(Json::as_u64).unwrap_or(1);
        if version > MANIFEST_VERSION {
            return Err(Error::Runtime(format!(
                "manifest schema v{version} is newer than this runtime \
                 supports (v{MANIFEST_VERSION}) — rebuild artifacts or \
                 update the runtime"
            )));
        }
        let mut models = BTreeMap::new();
        for (key, m) in json
            .req("models")?
            .as_obj()
            .ok_or_else(|| Error::Json("models must be an object".into()))?
        {
            let input = m.req("input")?;
            let dims = input
                .as_arr()
                .ok_or_else(|| Error::Json("input must be an array".into()))?;
            if dims.len() != 3 {
                return Err(Error::Json("input must be [h, w, c]".into()));
            }
            let arts = m.req("artifacts")?;
            models.insert(
                key.clone(),
                ModelEntry {
                    key: key.clone(),
                    model: m.req("model")?.as_str().unwrap_or_default().to_string(),
                    dataset: m.req("dataset")?.as_str().unwrap_or_default().to_string(),
                    param_count: m
                        .req("param_count")?
                        .as_usize()
                        .ok_or_else(|| Error::Json("param_count".into()))?,
                    input: (
                        dims[0].as_usize().unwrap_or(0),
                        dims[1].as_usize().unwrap_or(0),
                        dims[2].as_usize().unwrap_or(0),
                    ),
                    nclass: m.req("nclass")?.as_usize().unwrap_or(10),
                    grad: batch_map(arts.req("grad")?)?,
                    grad_stacked: arts
                        .get("grad_stacked")
                        .map(stacked_map)
                        .transpose()?
                        .unwrap_or_default(),
                    grad_nopallas: arts
                        .get("grad_nopallas")
                        .map(batch_map)
                        .transpose()?
                        .unwrap_or_default(),
                    eval: batch_map(arts.req("eval")?)?,
                    update: arts
                        .req("update")?
                        .as_str()
                        .ok_or_else(|| Error::Json("update".into()))?
                        .to_string(),
                    init_params: m
                        .req("init_params")?
                        .as_str()
                        .ok_or_else(|| Error::Json("init_params".into()))?
                        .to_string(),
                    params_spec: m
                        .get("params_spec")
                        .map(params_spec)
                        .transpose()?
                        .unwrap_or_default(),
                },
            );
        }
        let q = json.req("qsgd")?;
        let qsgd = QsgdEntry {
            n: q.req("n")?.as_usize().unwrap_or(0),
            s: q.req("s")?.as_u64().unwrap_or(16) as u8,
            encode: q.req("encode")?.as_str().unwrap_or_default().to_string(),
            decode: q.req("decode")?.as_str().unwrap_or_default().to_string(),
        };
        Ok(Self { dir, version, models, qsgd })
    }

    pub fn model(&self, key: &str) -> Result<&ModelEntry> {
        self.models.get(key).ok_or_else(|| {
            Error::Runtime(format!(
                "model {key:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn resolve(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

impl ModelEntry {
    /// Grad artifact path for a batch size.
    pub fn grad_for(&self, batch: usize) -> Result<&str> {
        self.grad
            .get(&batch)
            .map(String::as_str)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "{}: no grad artifact for batch {} (have {:?})",
                    self.key,
                    batch,
                    self.grad.keys().collect::<Vec<_>>()
                ))
            })
    }

    /// Batch sizes with grad artifacts, ascending.
    pub fn grad_batches(&self) -> Vec<usize> {
        self.grad.keys().copied().collect()
    }

    /// Stacked grad artifact for a batch size and stacking factor k.
    pub fn grad_stacked_for(&self, batch: usize, k: usize) -> Result<&str> {
        self.grad_stacked
            .get(&batch)
            .and_then(|m| m.get(&k))
            .map(String::as_str)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "{}: no stacked grad artifact for batch {} x{} (have {:?})",
                    self.key,
                    batch,
                    k,
                    self.stacked_ks(batch)
                ))
            })
    }

    /// Available stacking factors for a batch size, ascending — empty on
    /// v1 manifests (no stacked artifacts), which disables the stacked
    /// fast path without erroring.
    pub fn stacked_ks(&self, batch: usize) -> Vec<usize> {
        self.grad_stacked
            .get(&batch)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2,
      "grad_batches": [16, 64],
      "stack_factors": [4, 8],
      "eval_batches": [64, 256],
      "models": {
        "mini_vgg_mnist": {
          "model": "mini_vgg", "dataset": "mnist",
          "param_count": 98442, "input": [28, 28, 1], "nclass": 10,
          "artifacts": {
            "grad": {"16": "g16.hlo.txt", "64": "g64.hlo.txt"},
            "grad_stacked": {"16": {"4": "g16x4.hlo.txt", "8": "g16x8.hlo.txt"}},
            "grad_nopallas": {"64": "g64np.hlo.txt"},
            "eval": {"64": "e64.hlo.txt"},
            "update": "u.hlo.txt"
          },
          "params_spec": [],
          "init_params": "p.f32"
        }
      },
      "qsgd": {"n": 4096, "s": 16, "encode": "qe.hlo.txt", "decode": "qd.hlo.txt"}
    }"#;

    fn write_sample(dir: &Path) {
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test1");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("mini_vgg_mnist").unwrap();
        assert_eq!(e.param_count, 98442);
        assert_eq!(e.input, (28, 28, 1));
        assert_eq!(e.grad_for(64).unwrap(), "g64.hlo.txt");
        assert_eq!(e.grad_batches(), vec![16, 64]);
        assert!(e.grad_for(128).is_err());
        assert_eq!(m.qsgd.s, 16);
        assert!(m.resolve("g64.hlo.txt").ends_with("g64.hlo.txt"));
    }

    #[test]
    fn stacked_schema_roundtrips() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test_stacked");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 2);
        let e = m.model("mini_vgg_mnist").unwrap();
        assert_eq!(e.stacked_ks(16), vec![4, 8]);
        assert_eq!(e.grad_stacked_for(16, 4).unwrap(), "g16x4.hlo.txt");
        assert_eq!(e.grad_stacked_for(16, 8).unwrap(), "g16x8.hlo.txt");
        // batch 64 has no stacked artifacts: discovery is empty, lookup
        // errors with the available factors named
        assert!(e.stacked_ks(64).is_empty());
        assert!(e.grad_stacked_for(64, 4).is_err());
        assert!(e.grad_stacked_for(16, 2).is_err());
    }

    #[test]
    fn v1_manifest_without_stacked_artifacts_still_loads() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let v1 = SAMPLE
            .replace("\"version\": 2", "\"version\": 1")
            .replace(
                "\"grad_stacked\": {\"16\": {\"4\": \"g16x4.hlo.txt\", \"8\": \"g16x8.hlo.txt\"}},",
                "",
            );
        std::fs::write(dir.join("manifest.json"), v1).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 1);
        let e = m.model("mini_vgg_mnist").unwrap();
        assert!(e.stacked_ks(16).is_empty());
    }

    #[test]
    fn future_schema_is_rejected_actionably() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test_future");
        std::fs::create_dir_all(&dir).unwrap();
        let future = SAMPLE.replace("\"version\": 2", "\"version\": 3");
        std::fs::write(dir.join("manifest.json"), future).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("schema v3"), "{err}");
    }

    #[test]
    fn params_spec_parses_layer_sizes_in_pack_order() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let with_spec = SAMPLE.replace(
            "\"params_spec\": []",
            r#""params_spec": [
              {"name": "conv1/kernel", "shape": [3, 3, 1, 8], "offset": 0, "size": 72},
              {"name": "conv1/bias", "shape": [8], "offset": 72, "size": 8},
              {"name": "dense/kernel", "shape": [1568, 10], "offset": 80, "size": 15680}
            ]"#,
        );
        std::fs::write(dir.join("manifest.json"), with_spec).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("mini_vgg_mnist").unwrap();
        assert_eq!(
            e.params_spec,
            vec![
                ("conv1/kernel".to_string(), 72),
                ("conv1/bias".to_string(), 8),
                ("dense/kernel".to_string(), 15680),
            ]
        );
        // the committed SAMPLE's empty spec and a v1 manifest without
        // the key both load as "no per-layer shapes"
        let dir2 = std::env::temp_dir().join("p2pless_manifest_test_spec_empty");
        std::fs::create_dir_all(&dir2).unwrap();
        write_sample(&dir2);
        assert!(Manifest::load(&dir2)
            .unwrap()
            .model("mini_vgg_mnist")
            .unwrap()
            .params_spec
            .is_empty());
        // malformed entries are rejected, not defaulted
        let dir3 = std::env::temp_dir().join("p2pless_manifest_test_spec_bad");
        std::fs::create_dir_all(&dir3).unwrap();
        let bad = SAMPLE
            .replace("\"params_spec\": []", "\"params_spec\": [{\"name\": \"x\"}]");
        std::fs::write(dir3.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir3).is_err());
    }

    #[test]
    fn unknown_model_errors() {
        let dir = std::env::temp_dir().join("p2pless_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let dir = std::env::temp_dir().join("p2pless_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
