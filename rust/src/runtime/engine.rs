//! PJRT engine: loads HLO-text artifacts, compiles them once, executes
//! them from the coordinator hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{ExecBatcher, FuseKey, StackedRun, DEFAULT_EXEC_BATCH_WAIT};
use crate::error::{Error, Result};
use crate::util::sync::Semaphore;

/// Compiled executable wrapper.
///
/// SAFETY: the PJRT C API is documented thread-safe (the CPU client
/// serializes internally), and this crate additionally bounds concurrent
/// `execute` calls through the [`Engine`]'s execution semaphore. The
/// `xla` crate omits Send/Sync only because its wrappers hold raw
/// pointers.
pub struct Executable(xla::PjRtLoadedExecutable);
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

struct Client(xla::PjRtClient);
unsafe impl Send for Client {}
unsafe impl Sync for Client {}

/// One execution's timing split: the PJRT run itself, and the time
/// spent waiting for an execution slot. Callers that bill compute time
/// (the FaaS gradient handler) must exclude the queue wait — a real
/// per-environment Lambda never pays another invocation's queue.
#[derive(Debug, Clone, Copy)]
pub struct ExecTiming {
    pub exec: Duration,
    pub queue_wait: Duration,
}

/// One artifact's compile slot: the inner mutex is held across the
/// compile itself, so concurrent loaders of the same key block on the
/// *slot* (not the whole cache) and exactly one of them compiles.
type CompileSlot = Arc<Mutex<Option<Arc<Executable>>>>;

/// Process-wide PJRT client + compiled-executable cache.
///
/// Concurrent executions are bounded by a configurable semaphore
/// (`exec_slots`): the default sizes it to the machine so parallel
/// fan-out (worker-pool Lambda branches, multi-peer clusters) really
/// overlaps, while `exec_slots = 1` reproduces the fully-serialized
/// behaviour that keeps per-grad-step wall measurements honest for the
/// paper tables.
pub struct Engine {
    client: Client,
    cache: Mutex<HashMap<String, CompileSlot>>,
    exec_sem: Semaphore,
    exec_slots: usize,
    batcher: ExecBatcher,
    compile_ms: Mutex<HashMap<String, u64>>,
    compiles: AtomicU64,
}

impl Engine {
    /// Engine with `exec_slots` sized to the machine (fusion off).
    pub fn new() -> Result<Self> {
        Self::with_slots(0)
    }

    /// Engine with an explicit concurrent-execution bound; `0` sizes it
    /// to `available_parallelism`, `1` serializes every execution.
    /// Execution fusion stays off (`exec_batch = 1`).
    pub fn with_slots(slots: usize) -> Result<Self> {
        Self::with_exec_batching(slots, 1, DEFAULT_EXEC_BATCH_WAIT)
    }

    /// Engine with both the execution-slot bound and the fused-batch
    /// knobs: up to `exec_batch` concurrent same-key [`Self::run_fused`]
    /// callers coalesce into one dispatch, each group collecting for at
    /// most `batch_wait`. `exec_batch <= 1` disables fusion.
    pub fn with_exec_batching(
        slots: usize,
        exec_batch: usize,
        batch_wait: Duration,
    ) -> Result<Self> {
        let slots = if slots == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            slots
        };
        Ok(Self {
            client: Client(xla::PjRtClient::cpu()?),
            cache: Mutex::new(HashMap::new()),
            exec_sem: Semaphore::new(slots),
            exec_slots: slots,
            batcher: ExecBatcher::new(exec_batch, batch_wait),
            compile_ms: Mutex::new(HashMap::new()),
            compiles: AtomicU64::new(0),
        })
    }

    /// The concurrent-execution bound this engine was built with.
    pub fn exec_slots(&self) -> usize {
        self.exec_slots
    }

    /// The fused-batch size this engine was built with (`1` = fusion
    /// off). This is the *ceiling*; see
    /// [`Self::exec_batch_effective`] for the live target.
    pub fn exec_batch(&self) -> usize {
        self.batcher.max()
    }

    /// The live fused-group size target (`1..=exec_batch()`). Equal to
    /// the ceiling unless an adaptive controller retargeted it.
    pub fn exec_batch_effective(&self) -> usize {
        self.batcher.effective()
    }

    /// Retarget the live fused-group size (clamped to
    /// `1..=exec_batch()`). Driven by the `--exec-batch auto`
    /// controller in `faas::scheduler`; groups already collecting
    /// finish at their original size.
    pub fn set_exec_batch_effective(&self, n: usize) {
        self.batcher.set_effective(n);
    }

    /// The fused-group collect window this engine was built with
    /// (irrelevant while `exec_batch() == 1`).
    pub fn exec_batch_wait(&self) -> Duration {
        self.batcher.wait()
    }

    /// `(batched_execs, fused_branches)`: fused dispatches performed
    /// and total branches that rode them. Monotonic for the life of the
    /// engine — callers that report per-run numbers (the trainer)
    /// snapshot and diff.
    pub fn batch_stats(&self) -> (u64, u64) {
        (self.batcher.batched_execs(), self.batcher.fused_branches())
    }

    /// `(stacked_execs, pad_waste)`: fused groups that ran as ONE
    /// stacked XLA execution, and pad lanes executed-and-discarded to
    /// reach an available stacking factor. Monotonic like
    /// [`Self::batch_stats`].
    pub fn stacked_stats(&self) -> (u64, u64) {
        (self.batcher.stacked_execs(), self.batcher.pad_waste())
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Load + compile an HLO text file (cached by absolute path).
    ///
    /// Concurrency contract: each artifact compiles **exactly once**.
    /// Two threads missing the cache for the same key used to both
    /// compile it (wasted seconds of XLA work, and the second insert
    /// silently dropped the first executable); now a per-key slot is
    /// claimed under the cache lock and the compile happens under the
    /// slot's own lock, so racing loaders block on the slot and reuse
    /// the winner's executable. A failed load leaves the slot empty for
    /// a later retry.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref();
        let key = path.to_string_lossy().to_string();
        let slot: CompileSlot = self
            .cache
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_default()
            .clone();
        let mut compiled = slot.lock().unwrap();
        if let Some(exe) = &*compiled {
            return Ok(exe.clone());
        }
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(Executable(self.client.0.compile(&comp)?));
        self.compiles.fetch_add(1, Ordering::Relaxed);
        self.compile_ms
            .lock()
            .unwrap()
            .insert(key, t0.elapsed().as_millis() as u64);
        *compiled = Some(exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs; unpacks the single tuple output into
    /// its elements. Returns (outputs, timing). The timing separates the
    /// execution itself from the slot queue wait, which callers must not
    /// bill as compute.
    pub fn run(
        &self,
        exe: &Executable,
        inputs: &[xla::Literal],
    ) -> Result<(Vec<xla::Literal>, ExecTiming)> {
        let t_wait = Instant::now();
        let _slot = self.exec_sem.acquire();
        let queue_wait = t_wait.elapsed();
        let t0 = Instant::now();
        let parts = execute_literals(exe, inputs)?;
        let elapsed = t0.elapsed();
        Ok((parts, ExecTiming { exec: elapsed, queue_wait }))
    }

    /// [`Self::run`], but eligible for execution fusion: concurrent
    /// callers whose `key` matches (same executable, same shapes, same
    /// params version) coalesce into one engine dispatch — one slot
    /// acquisition, the group's literals executed back-to-back — with
    /// outputs split back per caller. Takes the inputs by value (they
    /// may cross to the group leader's thread) and hands them back so
    /// cached packings survive the call. With `exec_batch <= 1` this is
    /// exactly [`Self::run`].
    ///
    /// The per-caller timing keeps billing honest: `exec` is the
    /// caller's own sub-execution, `queue_wait` is the collect window +
    /// slot wait + the other members' turns — all in-process artifacts
    /// the FaaS layer excludes from billed time.
    pub fn run_fused(
        &self,
        exe: &Arc<Executable>,
        inputs: Vec<xla::Literal>,
        key: FuseKey,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)> {
        self.run_fused_stacked(exe, inputs, key, |_| Ok(None))
    }

    /// [`Self::run_fused`] with a stacked fast path: once the group
    /// leader holds the slot it offers every member's inputs to
    /// `stacked` (see [`ExecBatcher::run_stacked`]); if that reports a
    /// completed stacked XLA execution the whole group finishes from
    /// it, otherwise members execute back-to-back as before. With the
    /// live batch target at 1 this is exactly [`Self::run`] — no
    /// grouping, no stacking.
    pub fn run_fused_stacked<S>(
        &self,
        exe: &Arc<Executable>,
        inputs: Vec<xla::Literal>,
        key: FuseKey,
        stacked: S,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        S: Fn(&[&[xla::Literal]]) -> Result<StackedRun>,
    {
        if self.batcher.effective() <= 1 {
            let (parts, timing) = self.run(exe, &inputs)?;
            return Ok((parts, inputs, timing));
        }
        self.batcher.run_stacked(
            key,
            inputs,
            &self.exec_sem,
            |ins| execute_literals(exe, ins),
            stacked,
        )
    }

    /// Total number of compiled executables resident.
    pub fn cached_executables(&self) -> usize {
        // snapshot the slots under the cache lock, then inspect them
        // without it: holding the cache lock while locking every slot
        // could stall behind a loader that holds its slot across a slow
        // XLA compile — and with it every other `load` in the process.
        // A slot whose lock is busy is mid-compile, i.e. not resident
        // yet, so `try_lock` misses count as absent.
        let slots: Vec<CompileSlot> =
            self.cache.lock().unwrap().values().cloned().collect();
        slots
            .iter()
            .filter(|slot| slot.try_lock().map(|c| c.is_some()).unwrap_or(false))
            .count()
    }

    /// Number of XLA compiles actually performed (the compile-once
    /// contract: stays equal to the distinct artifact count no matter
    /// how many threads race on [`Self::load`]).
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Compile-time log (path -> ms), for EXPERIMENTS.md.
    pub fn compile_times_ms(&self) -> Vec<(String, u64)> {
        let mut v: Vec<_> = self
            .compile_ms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &ms)| (k.clone(), ms))
            .collect();
        v.sort();
        v
    }
}

/// One raw PJRT dispatch: execute `inputs`, sync the single tuple
/// output back to host, unpack it. Shared by the direct path
/// ([`Engine::run`]) and the fused path (where the group leader calls
/// it once per member under a single execution slot).
pub(crate) fn execute_literals(
    exe: &Executable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.0.execute::<xla::Literal>(inputs)?;
    let out = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| Error::Runtime("executable produced no output".into()))?
        .to_literal_sync()?;
    // AOT artifacts are lowered with return_tuple=True.
    Ok(out.to_tuple()?)
}

/// Pack an f32 slice as a rank-N literal.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {:?} wants {} elems, got {}",
            dims,
            n,
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Pack an i32 slice as a rank-N literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {:?} wants {} elems, got {}",
            dims,
            n,
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract a scalar f32 from a literal (loss outputs).
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::Runtime("empty literal".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_packing_validates_shape() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[3]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[4]).is_err());
    }

    // Engine integration tests (real PJRT) live in rust/tests/ — they
    // need the artifacts directory built by `make artifacts`.
}
