//! PJRT engine: loads HLO-text artifacts, compiles them once, executes
//! them from the coordinator hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Compiled executable wrapper.
///
/// SAFETY: the PJRT C API is documented thread-safe (the CPU client
/// serializes internally), and this crate additionally serializes every
/// `execute` through [`Engine::exec_lock`]. The `xla` crate omits
/// Send/Sync only because its wrappers hold raw pointers.
pub struct Executable(xla::PjRtLoadedExecutable);
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

struct Client(xla::PjRtClient);
unsafe impl Send for Client {}
unsafe impl Sync for Client {}

/// Process-wide PJRT client + compiled-executable cache.
///
/// All executions are serialized through a mutex: the CPU PJRT client is
/// single-device here, and serializing keeps wall-time measurements of
/// individual grad steps honest on the 1-core testbed.
pub struct Engine {
    client: Client,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    exec_lock: Mutex<()>,
    compile_ms: Mutex<HashMap<String, u64>>,
}

impl Engine {
    pub fn new() -> Result<Self> {
        Ok(Self {
            client: Client(xla::PjRtClient::cpu()?),
            cache: Mutex::new(HashMap::new()),
            exec_lock: Mutex::new(()),
            compile_ms: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Load + compile an HLO text file (cached by absolute path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref();
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(Executable(self.client.0.compile(&comp)?));
        self.compile_ms
            .lock()
            .unwrap()
            .insert(key.clone(), t0.elapsed().as_millis() as u64);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs; unpacks the single tuple output into
    /// its elements. Returns (outputs, execution wall time).
    pub fn run(
        &self,
        exe: &Executable,
        inputs: &[xla::Literal],
    ) -> Result<(Vec<xla::Literal>, Duration)> {
        let _guard = self.exec_lock.lock().unwrap();
        let t0 = Instant::now();
        let result = exe.0.execute::<xla::Literal>(inputs)?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Runtime("executable produced no output".into()))?
            .to_literal_sync()?;
        let elapsed = t0.elapsed();
        // AOT artifacts are lowered with return_tuple=True.
        let parts = out.to_tuple()?;
        Ok((parts, elapsed))
    }

    /// Total number of compiled executables resident.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Compile-time log (path -> ms), for EXPERIMENTS.md.
    pub fn compile_times_ms(&self) -> Vec<(String, u64)> {
        let mut v: Vec<_> = self
            .compile_ms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &ms)| (k.clone(), ms))
            .collect();
        v.sort();
        v
    }
}

/// Pack an f32 slice as a rank-N literal.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {:?} wants {} elems, got {}",
            dims,
            n,
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Pack an i32 slice as a rank-N literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::Runtime(format!(
            "literal shape {:?} wants {} elems, got {}",
            dims,
            n,
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract a scalar f32 from a literal (loss outputs).
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::Runtime("empty literal".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_packing_validates_shape() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[3]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[4]).is_err());
    }

    // Engine integration tests (real PJRT) live in rust/tests/ — they
    // need the artifacts directory built by `make artifacts`.
}
