//! Engine-level execution batcher: coalesces concurrent [`Engine::run`]
//! callers holding the *same executable, compatible input shapes and the
//! same params version* into one fused engine dispatch.
//!
//! Motivation ("Towards Demystifying Serverless ML Training", SPIRT):
//! per-invocation compute overhead dominates serverless training at
//! scale. Our hot path paid it N times per epoch — N branches against
//! the same params version meant N slot acquisitions, N worker wakeups
//! and N independent PJRT dispatches serialized through `exec_slots`.
//! The batcher turns those into one *fused run*: callers enqueue
//! `(inputs, reply channel)` under a [`FuseKey`]; the first caller
//! becomes the group **leader** and collects up to `--exec-batch`
//! members within the `--exec-batch-wait-us` window (closing early the
//! moment the group fills); the leader then acquires a single execution
//! slot and drives every member's literals through the executable
//! back-to-back, splitting the outputs back per caller.
//!
//! ## The byte-identity contract
//!
//! Fusion must never change the math or the modeled accounting:
//!
//! - **gradient/loss folds** — each member executes on *its own*
//!   literals (its own back-to-back turn, or its own lane of a stacked
//!   execution); nothing is summed or averaged across members, so every
//!   caller receives its own outputs exactly as an unbatched run would
//!   compute them. Members are grouped strictly by
//!   [`FuseKey`] (executable identity + batch/param shapes + params
//!   version), so cross-generation branches — whose inputs come from
//!   different params versions — can never share a group;
//! - **modeled wall / billed / cost** — each member's [`ExecTiming`]
//!   reports its *own* sub-execution as `exec` and everything else
//!   (group collect wait, slot wait, the other members' turns) as
//!   `queue_wait`, which the FaaS billing path already excludes as an
//!   in-process artifact. Modeled numbers therefore stay byte-identical
//!   at any `--exec-batch`; only the *measured* wall moves.
//!
//! ## What "fused" means here — two execution strategies
//!
//! A fused dispatch is one *engine* dispatch: one slot acquisition, one
//! worker wakeup chain. How the group's literals then execute depends
//! on what the artifact manifest offers:
//!
//! - **Stacked (one XLA execution).** When a `grad_stacked_{B}x{k}`
//!   artifact covers the group ([`run_stacked`]'s `stacked` closure
//!   returns per-member outputs), the leader packs every member's
//!   micro-batch into one stacked literal and the whole group runs as
//!   literally ONE XLA execution. The stacked artifacts are lowered
//!   with **per-branch** loss/gradient outputs — `k` independent lanes,
//!   no cross-lane reduction — so the outputs split back per caller
//!   exactly as the sequential path would produce them. Groups smaller
//!   than the nearest available `k` are padded by replicating a real
//!   member's lane (pad lanes execute and are discarded; the waste is
//!   counted in [`pad_waste`]).
//! - **Back-to-back (fallback).** When no stacked artifact fits — v1
//!   manifests, mixed-size groups, singleton groups — the members'
//!   literals execute back-to-back on the leader's thread under the one
//!   slot, amortizing the per-dispatch costs only.
//!
//! Stacking attacks the execution itself, not just its scheduling: XLA
//! sees the `k` lanes at once and can overlap/vectorize across them,
//! where the fallback still pays `k` full executions. With
//! `--exec-slots` at machine size and heavy branches, a fused group
//! still serializes under its single slot while other slots idle —
//! which is why the knob defaults to off and the bench pins
//! `--exec-slots 1` for the comparison.
//!
//! ## The adaptive effective batch
//!
//! `--exec-batch N` is a *ceiling*: [`set_effective`] (driven by the
//! `--exec-batch auto` controller in `faas::scheduler`) retargets the
//! live group size anywhere in `1..=max` from queue-depth/utilization
//! signals without rebuilding the engine. Groups forming after a
//! retarget use the new size; a group mid-collect finishes at the size
//! it started with.
//!
//! [`run_stacked`]: ExecBatcher::run_stacked
//! [`pad_waste`]: ExecBatcher::pad_waste
//! [`set_effective`]: ExecBatcher::set_effective
//!
//! ## Liveness
//!
//! The leader never waits while holding an execution slot, followers
//! never hold one at all, and the collect wait is bounded by the window
//! — so the worst case under starved concurrency (fewer concurrent
//! same-key callers than `--exec-batch`) is a window's delay per group,
//! never a deadlock. A leader that dies mid-group drops its members'
//! reply channels, which surfaces as an error on their side rather than
//! a hang. Effective fill is bounded by how many same-key branches are
//! actually concurrent: `min(--exec-batch, --exec-threads, per-peer
//! admission cap)`.
//!
//! [`Engine::run`]: super::Engine::run

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::engine::{ExecTiming, Executable};
use crate::error::{Error, Result};
use crate::util::sync::Semaphore;

/// Default collect window: long enough for a worker-pool wave of
/// same-epoch branches to meet in the batcher, short enough to be
/// invisible next to a PJRT gradient execution.
pub const DEFAULT_EXEC_BATCH_WAIT: Duration = Duration::from_micros(500);

/// Fusion group key: only callers agreeing on every field may share a
/// fused dispatch.
///
/// `exe` (the compiled executable's address) already implies the full
/// input signature — artifacts are shape-specialized — but the logical
/// batch size and param count are kept as an explicit shape-compat
/// guard, and `version` carries the params generation so branches of
/// different param versions (overlapping epochs in cross-epoch mode)
/// never fuse.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FuseKey {
    /// Executable identity (stable: the engine caches executables for
    /// the life of the process).
    pub exe: usize,
    /// Logical batch size the artifact is specialized to.
    pub batch: usize,
    /// Parameter vector length.
    pub params: usize,
    /// Params version (the offload generation tag).
    pub version: u64,
}

impl FuseKey {
    pub fn for_exe(exe: &Arc<Executable>, batch: usize, params: usize, version: u64) -> Self {
        Self { exe: Arc::as_ptr(exe) as usize, batch, params, version }
    }
}

/// Owned input/output literals crossing threads between a follower and
/// its group leader.
///
/// SAFETY: mirrors [`Executable`]'s rationale — PJRT literals are
/// host-side buffers whose wrappers omit `Send` only because they hold
/// raw pointers. Each literal vector has exactly one owner at any time:
/// a follower moves its inputs into the group under the group mutex,
/// the leader takes them, executes, and moves them (plus the outputs)
/// back through the reply channel.
struct LitVec(Vec<xla::Literal>);
unsafe impl Send for LitVec {}

/// What a leader sends each member back: outputs, the member's own
/// input literals (returned so callers can re-use cached packings), and
/// the member's own sub-execution duration.
type MemberReply = Result<(LitVec, LitVec, Duration)>;

/// What a [`run_stacked`] `stacked` closure reports back to the leader:
/// `Some((per_member_outputs, stacked_wall, k))` when the whole group
/// ran as one stacked XLA execution of padded factor `k` (outputs in
/// member-view order: leader first, then members in arrival order), or
/// `None` when no stacked artifact fits and the group must fall back to
/// the back-to-back path.
///
/// [`run_stacked`]: ExecBatcher::run_stacked
pub type StackedRun = Option<(Vec<Vec<xla::Literal>>, Duration, usize)>;

struct Member {
    inputs: LitVec,
    reply: SyncSender<MemberReply>,
}

struct GroupState {
    members: Vec<Member>,
    /// Set once the leader has taken the members: late arrivals must
    /// start a fresh group instead of enqueueing into a dead one.
    closed: bool,
}

struct Group {
    state: Mutex<GroupState>,
    /// Signalled when the group fills; the leader parks here.
    filled: Condvar,
}

impl Group {
    fn new() -> Self {
        Self {
            state: Mutex::new(GroupState { members: Vec::new(), closed: false }),
            filled: Condvar::new(),
        }
    }
}

enum Role {
    /// This caller opened the group; its own inputs ride along.
    Leader(Arc<Group>, Vec<xla::Literal>),
    /// This caller enqueued into an open group; the reply arrives here.
    Follower(Receiver<MemberReply>),
}

/// The coalescing core. Owned by the [`Engine`]; exposed publicly so
/// benches and tests can exercise the grouping machinery with synthetic
/// execution closures (no artifacts needed).
///
/// [`Engine`]: super::Engine
pub struct ExecBatcher {
    max: usize,
    /// Live group-size target, `1..=max`. Fixed `--exec-batch N` keeps
    /// it at `max`; the `auto` controller retargets it at runtime.
    effective: AtomicUsize,
    wait: Duration,
    groups: Mutex<HashMap<FuseKey, Arc<Group>>>,
    batched_execs: AtomicU64,
    fused_branches: AtomicU64,
    /// Fused groups that ran as ONE stacked XLA execution.
    stacked_execs: AtomicU64,
    /// Pad lanes executed-and-discarded across all stacked runs.
    pad_waste: AtomicU64,
}

impl ExecBatcher {
    /// `max` members per fused run (`<= 1` disables fusion at the
    /// engine level — [`Engine::run_fused`] then takes the plain path);
    /// `wait` bounds how long a leader collects before dispatching a
    /// partial group.
    ///
    /// [`Engine::run_fused`]: super::Engine::run_fused
    pub fn new(max: usize, wait: Duration) -> Self {
        let max = max.max(1);
        Self {
            max,
            effective: AtomicUsize::new(max),
            wait,
            groups: Mutex::new(HashMap::new()),
            batched_execs: AtomicU64::new(0),
            fused_branches: AtomicU64::new(0),
            stacked_execs: AtomicU64::new(0),
            pad_waste: AtomicU64::new(0),
        }
    }

    /// Maximum members per fused run (the `--exec-batch` ceiling).
    pub fn max(&self) -> usize {
        self.max
    }

    /// The live group-size target (`1..=max`).
    pub fn effective(&self) -> usize {
        self.effective.load(Ordering::Relaxed)
    }

    /// Retarget the live group size, clamped to `1..=max`. Groups that
    /// form after this call collect to the new target; a group already
    /// collecting finishes at the size it started with.
    pub fn set_effective(&self, n: usize) {
        self.effective.store(n.clamp(1, self.max), Ordering::Relaxed);
    }

    /// The collect window.
    pub fn wait(&self) -> Duration {
        self.wait
    }

    /// Fused dispatches performed (each group run counts once, whatever
    /// its fill).
    pub fn batched_execs(&self) -> u64 {
        self.batched_execs.load(Ordering::Relaxed)
    }

    /// Total branches that went through fused dispatches.
    pub fn fused_branches(&self) -> u64 {
        self.fused_branches.load(Ordering::Relaxed)
    }

    /// Fused groups that ran as ONE stacked XLA execution (subset of
    /// [`batched_execs`](Self::batched_execs)).
    pub fn stacked_execs(&self) -> u64 {
        self.stacked_execs.load(Ordering::Relaxed)
    }

    /// Total pad lanes executed-and-discarded by stacked runs whose
    /// group was smaller than the nearest available stacking factor.
    pub fn pad_waste(&self) -> u64 {
        self.pad_waste.load(Ordering::Relaxed)
    }

    /// Join (or lead) the fused run for `key`. Blocks until this
    /// caller's inputs have executed; returns `(outputs, inputs back,
    /// timing)` — `timing.exec` is this caller's own sub-execution,
    /// `timing.queue_wait` everything else (collect window, slot wait,
    /// other members' turns).
    ///
    /// `exec` runs one input list against the shared executable; only
    /// the *leader's* closure is ever invoked (for every member), which
    /// is sound because the key pins the executable identity.
    pub fn run<E>(
        &self,
        key: FuseKey,
        inputs: Vec<xla::Literal>,
        sem: &Semaphore,
        exec: E,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        E: Fn(&[xla::Literal]) -> Result<Vec<xla::Literal>>,
    {
        // no stacked strategy: every group takes the back-to-back path
        self.run_stacked(key, inputs, sem, exec, |_| Ok(None))
    }

    /// Like [`run`](Self::run), with a stacked fast path: once the
    /// group is closed and the slot held, the leader offers every
    /// member's input slice (its own first, then members in arrival
    /// order) to `stacked`. If it returns per-member outputs, the whole
    /// group completes from that ONE stacked XLA execution; on `None`
    /// the members execute back-to-back through `exec` as before. A
    /// `stacked` error fails the entire group — every member's data
    /// rode the one dispatch.
    pub fn run_stacked<E, S>(
        &self,
        key: FuseKey,
        inputs: Vec<xla::Literal>,
        sem: &Semaphore,
        exec: E,
        stacked: S,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        E: Fn(&[xla::Literal]) -> Result<Vec<xla::Literal>>,
        S: Fn(&[&[xla::Literal]]) -> Result<StackedRun>,
    {
        let t_start = Instant::now();
        match self.enlist(key, inputs) {
            Role::Follower(rx) => match rx.recv() {
                Ok(Ok((outs, ins, exec))) => {
                    let queue_wait = t_start.elapsed().saturating_sub(exec);
                    Ok((outs.0, ins.0, ExecTiming { exec, queue_wait }))
                }
                Ok(Err(e)) => Err(e),
                // the leader died between taking the group and replying
                // (a panic inside the handler stack): fail this branch
                // loudly instead of hanging — the FaaS retry policy owns
                // what happens next
                Err(_) => Err(Error::Runtime(
                    "fused execution leader vanished before replying".into(),
                )),
            },
            Role::Leader(group, own) => {
                self.lead(key, group, own, t_start, sem, exec, stacked)
            }
        }
    }

    /// Become a follower of an open group, or the leader of a fresh one.
    fn enlist(&self, key: FuseKey, inputs: Vec<xla::Literal>) -> Role {
        let target = self.effective();
        let mut groups = self.groups.lock().unwrap();
        if let Some(group) = groups.get(&key) {
            let group = group.clone();
            // lock order is always map -> group
            let mut st = group.state.lock().unwrap();
            // joinable iff still open and there is room left beside the
            // leader: total occupancy is members + 1
            if !st.closed && st.members.len() + 2 <= target {
                let (tx, rx) = sync_channel(1);
                st.members.push(Member { inputs: LitVec(inputs), reply: tx });
                let full = st.members.len() + 1 >= target;
                drop(st);
                drop(groups);
                if full {
                    group.filled.notify_all();
                }
                return Role::Follower(rx);
            }
            // closed (leader already collecting) or full (leader not
            // yet woken): fall through and replace it — the old
            // leader's cleanup is pointer-checked, so it will not
            // remove the replacement
        }
        let fresh = Arc::new(Group::new());
        groups.insert(key, fresh.clone());
        Role::Leader(fresh, inputs)
    }

    /// Leader phase: collect members until full or the window expires,
    /// close the group, then run everyone under one execution slot —
    /// as one stacked XLA execution when `stacked` covers the group,
    /// back-to-back through `exec` otherwise.
    #[allow(clippy::too_many_arguments)]
    fn lead<E, S>(
        &self,
        key: FuseKey,
        group: Arc<Group>,
        own_inputs: Vec<xla::Literal>,
        t_start: Instant,
        sem: &Semaphore,
        exec: E,
        stacked: S,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        E: Fn(&[xla::Literal]) -> Result<Vec<xla::Literal>>,
        S: Fn(&[&[xla::Literal]]) -> Result<StackedRun>,
    {
        // collect: park on the condvar until the group fills or the
        // window runs out (no lock held besides the group's own, and
        // no execution slot — a starved group can never block the
        // engine). The target is snapshotted: a concurrent retarget
        // applies to the next group, not one mid-collect.
        let target = self.effective();
        let deadline = Instant::now() + self.wait;
        {
            let mut st = group.state.lock().unwrap();
            while st.members.len() + 1 < target {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) =
                    group.filled.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        // close: retire the group from the map (unless a racing joiner
        // already replaced a full group with a fresh one), then take
        // the members. Joiners that slipped in between the wake-up and
        // this close are included — the close is what makes the member
        // set final.
        let members = {
            let mut groups = self.groups.lock().unwrap();
            if let Some(current) = groups.get(&key) {
                if Arc::ptr_eq(current, &group) {
                    groups.remove(&key);
                }
            }
            let mut st = group.state.lock().unwrap();
            st.closed = true;
            std::mem::take(&mut st.members)
        };

        // fused dispatch: one execution slot for the whole group
        let _slot = sem.acquire();
        self.batched_execs.fetch_add(1, Ordering::Relaxed);
        self.fused_branches
            .fetch_add(1 + members.len() as u64, Ordering::Relaxed);

        // stacked fast path: offer the whole group (leader's inputs
        // first, members in arrival order) as one stacked execution
        let views: Vec<&[xla::Literal]> = std::iter::once(own_inputs.as_slice())
            .chain(members.iter().map(|m| m.inputs.0.as_slice()))
            .collect();
        match stacked(&views) {
            Ok(Some((mut outs, stacked_wall, k))) if outs.len() == views.len() => {
                drop(views);
                let group_size = 1 + members.len();
                self.stacked_execs.fetch_add(1, Ordering::Relaxed);
                self.pad_waste
                    .fetch_add(k.saturating_sub(group_size) as u64, Ordering::Relaxed);
                // billing: one stacked execution of k lanes is split as
                // an equal per-lane share — each member's `exec` covers
                // exactly its own lane's slice of the one execution, so
                // the group's summed billed time never exceeds the real
                // stacked wall. Everything else (collect window, slot
                // wait, pad lanes' share) stays in queue_wait, which
                // the FaaS billing path excludes.
                let share = stacked_wall / k.max(1) as u32;
                let member_outs = outs.split_off(1);
                for (Member { inputs, reply }, m_outs) in
                    members.into_iter().zip(member_outs)
                {
                    let _ = reply.send(Ok((LitVec(m_outs), inputs, share)));
                }
                let own_outs = outs.pop().expect("leader lane output");
                let queue_wait = t_start.elapsed().saturating_sub(share);
                return Ok((own_outs, own_inputs, ExecTiming { exec: share, queue_wait }));
            }
            Ok(Some((outs, _, _))) => {
                drop(views);
                let msg = format!(
                    "stacked execution returned {} member outputs for a \
                     group of {}",
                    outs.len(),
                    1 + members.len()
                );
                for Member { reply, .. } in members {
                    let _ = reply.send(Err(Error::Runtime(msg.clone())));
                }
                return Err(Error::Runtime(msg));
            }
            Err(e) => {
                drop(views);
                // the whole group rode the one stacked dispatch: fail
                // every member with the same cause
                let msg = format!("stacked execution failed: {e}");
                for Member { reply, .. } in members {
                    let _ = reply.send(Err(Error::Runtime(msg.clone())));
                }
                return Err(e);
            }
            Ok(None) => drop(views),
        }

        // back-to-back fallback: the leader's own turn first, then
        // every member in arrival order; each turn is timed
        // individually so billing stays per-branch
        let t0 = Instant::now();
        let own_result = exec(&own_inputs);
        let own_exec = t0.elapsed();
        for Member { inputs, reply } in members {
            let t0 = Instant::now();
            let result = exec(&inputs.0);
            let exec_dur = t0.elapsed();
            // a receiver can only be gone if the follower's thread died
            let _ = reply
                .send(result.map(|outs| (LitVec(outs), inputs, exec_dur)));
        }
        let outs = own_result?;
        // the leader's queue_wait is computed exactly like a follower's:
        // everything that is not its own turn — collect window, slot
        // wait, AND the member turns it served — is a fusion artifact.
        // Snapshotting before the member loop would leak the other
        // members' executions into the leader's billed handler time.
        let queue_wait = t_start.elapsed().saturating_sub(own_exec);
        Ok((outs, own_inputs, ExecTiming { exec: own_exec, queue_wait }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::literal_f32;
    use std::sync::Barrier;

    /// A deterministic synthetic "execution": reads the single rank-1
    /// f32 input and returns `[2x + 1]` — pure data movement through
    /// the batcher, bitwise checkable.
    fn double_plus_one(ins: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let v = ins[0].to_vec::<f32>()?;
        let out: Vec<f32> = v.iter().map(|x| 2.0 * x + 1.0).collect();
        Ok(vec![literal_f32(&out, &[out.len() as i64])?])
    }

    fn key(version: u64) -> FuseKey {
        FuseKey { exe: 0xDEAD, batch: 4, params: 8, version }
    }

    fn input(seed: f32) -> Vec<xla::Literal> {
        vec![literal_f32(&[seed, seed + 0.25, seed * 3.0, -seed], &[4]).unwrap()]
    }

    /// Run `n` concurrent callers of `version_of(i)` through one
    /// batcher; returns per-caller output bits.
    fn fan_in(
        batcher: &Arc<ExecBatcher>,
        n: usize,
        version_of: impl Fn(usize) -> u64 + Copy + Send + 'static,
    ) -> Vec<Vec<u32>> {
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let batcher = batcher.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let inputs = input(i as f32);
                    let want_back: Vec<u32> = inputs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    barrier.wait();
                    let (outs, ins, _timing) = batcher
                        .run(key(version_of(i)), inputs, &sem, double_plus_one)
                        .unwrap();
                    // the caller's own literals come back for re-use
                    let got_back: Vec<u32> = ins[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    assert_eq!(got_back, want_back, "inputs must round-trip");
                    outs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn expected(i: usize) -> Vec<u32> {
        let seed = i as f32;
        [seed, seed + 0.25, seed * 3.0, -seed]
            .iter()
            .map(|x| (2.0 * x + 1.0f32).to_bits())
            .collect()
    }

    #[test]
    fn full_group_fuses_into_one_dispatch() {
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(500)));
        let got = fan_in(&b, 8, |_| 7);
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i), "member {i} got someone else's output");
        }
        assert_eq!(b.batched_execs(), 1, "8 callers at batch 8 = one fused run");
        assert_eq!(b.fused_branches(), 8);
    }

    #[test]
    fn cross_version_callers_never_fuse() {
        // two params versions, four callers each: exactly two groups,
        // never a mixed one — the cross-generation contract
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let got = fan_in(&b, 8, |i| (i % 2) as u64);
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i));
        }
        assert_eq!(
            b.batched_execs(),
            2,
            "4+4 callers of two versions must form exactly two fused runs"
        );
        assert_eq!(b.fused_branches(), 8);
    }

    #[test]
    fn window_expiry_dispatches_partial_group() {
        // a lone caller cannot fill the group: the window bounds its
        // wait and the singleton still executes
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(5)));
        let got = fan_in(&b, 1, |_| 1);
        assert_eq!(got[0], expected(0));
        assert_eq!(b.batched_execs(), 1);
        assert_eq!(b.fused_branches(), 1);
    }

    #[test]
    fn sequential_callers_form_sequential_groups() {
        // no concurrency: each call leads its own group (fill 1) —
        // correctness never depends on arrival luck
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(1)));
        let sem = Semaphore::new(1);
        for i in 0..3usize {
            let (outs, _, _) = b
                .run(key(9), input(i as f32), &sem, double_plus_one)
                .unwrap();
            let bits: Vec<u32> = outs[0]
                .to_vec::<f32>()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(bits, expected(i));
        }
        assert_eq!(b.batched_execs(), 3);
        assert_eq!(b.fused_branches(), 3);
    }

    #[test]
    fn billed_exec_is_one_turn_for_every_member_including_the_leader() {
        // 4 callers, each turn ~20 ms: every caller's `exec` must cover
        // its own turn only — the rest of the group's work lands in
        // queue_wait, which billing excludes. A leader that billed its
        // members' turns would report ~80 ms here (regression: its
        // queue_wait used to be snapshotted before the member loop).
        const TURN_MS: u64 = 20;
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let b = b.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let t0 = Instant::now();
                    let (_, _, timing) = b
                        .run(key(11), input(i as f32), &sem, |ins| {
                            std::thread::sleep(Duration::from_millis(TURN_MS));
                            double_plus_one(ins)
                        })
                        .unwrap();
                    (timing, t0.elapsed())
                })
            })
            .collect();
        for h in handles {
            let (timing, wall) = h.join().unwrap();
            // what the FaaS layer would bill is the caller's handler
            // wall minus the reported queue_wait — it must stay ~one
            // turn (generous slack, but far below the 3-extra-turns a
            // leaked group would add)
            let billed = wall.saturating_sub(timing.queue_wait);
            assert!(
                billed < Duration::from_millis(3 * TURN_MS),
                "a member would bill more than its own turn: {billed:?} \
                 (wall {wall:?}, queue_wait {:?})",
                timing.queue_wait
            );
            assert!(
                timing.exec < Duration::from_millis(3 * TURN_MS),
                "a member's own-execution report exceeds its turn: {:?}",
                timing.exec
            );
        }
        assert_eq!(b.batched_execs(), 1);
    }

    #[test]
    fn member_error_is_delivered_to_that_member_only() {
        // an exec failure for one member's inputs must not poison the
        // others: encode "fail" as a NaN marker the closure rejects
        let b = Arc::new(ExecBatcher::new(2, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(2));
        let spawn = |poison: bool| {
            let b = b.clone();
            let sem = sem.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let inputs = if poison {
                    vec![literal_f32(&[f32::NAN], &[1]).unwrap()]
                } else {
                    input(1.0)
                };
                barrier.wait();
                b.run(key(3), inputs, &sem, |ins| {
                    let v = ins[0].to_vec::<f32>()?;
                    if v.iter().any(|x| x.is_nan()) {
                        return Err(Error::Runtime("poisoned member".into()));
                    }
                    double_plus_one(ins)
                })
                .map(|(outs, _, _)| outs[0].to_vec::<f32>().unwrap())
            })
        };
        let ok = spawn(false);
        let bad = spawn(true);
        let results = [ok.join().unwrap(), bad.join().unwrap()];
        let (oks, errs): (Vec<_>, Vec<_>) = results.into_iter().partition(|r| r.is_ok());
        assert_eq!(oks.len(), 1, "the healthy member must succeed");
        assert_eq!(errs.len(), 1, "the poisoned member must fail alone");
        assert!(errs[0].as_ref().unwrap_err().to_string().contains("poisoned"));
    }

    /// A synthetic stacked strategy: computes every lane's `[2x + 1]`
    /// in one "execution" padded to `k` lanes, reporting a fixed wall.
    fn stack_to(k: usize, views: &[&[xla::Literal]]) -> Result<StackedRun> {
        let mut outs = Vec::with_capacity(views.len());
        for v in views {
            outs.push(double_plus_one(v)?);
        }
        Ok(Some((outs, Duration::from_millis(8), k.max(views.len()))))
    }

    /// Like [`fan_in`], but through [`ExecBatcher::run_stacked`] with a
    /// shared stacked strategy (all callers use one version).
    fn fan_in_stacked(
        batcher: &Arc<ExecBatcher>,
        n: usize,
        stacked: impl Fn(&[&[xla::Literal]]) -> Result<StackedRun> + Copy + Send + 'static,
    ) -> Vec<Vec<u32>> {
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let batcher = batcher.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let inputs = input(i as f32);
                    let want_back: Vec<u32> = inputs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    barrier.wait();
                    let (outs, ins, _timing) = batcher
                        .run_stacked(key(5), inputs, &sem, double_plus_one, stacked)
                        .unwrap();
                    let got_back: Vec<u32> = ins[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    assert_eq!(got_back, want_back, "inputs must round-trip");
                    outs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn full_group_runs_as_one_stacked_execution() {
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let got = fan_in_stacked(&b, 4, |v| stack_to(4, v));
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i), "lane {i} got someone else's output");
        }
        assert_eq!(b.batched_execs(), 1, "one engine dispatch");
        assert_eq!(b.stacked_execs(), 1, "one stacked XLA execution");
        assert_eq!(b.fused_branches(), 4);
        assert_eq!(b.pad_waste(), 0, "an exact-fit stack pads nothing");
    }

    #[test]
    fn padded_stacked_execution_counts_its_waste() {
        // three callers padded into an 8-lane stack: the group still
        // completes as one stacked execution, every member gets its own
        // lane back, and the 5 dead lanes show up in the counter
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(40)));
        let got = fan_in_stacked(&b, 3, |v| stack_to(8, v));
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i));
        }
        assert_eq!(b.stacked_execs(), 1);
        assert_eq!(b.pad_waste(), 5, "8-lane stack over a group of 3 wastes 5");
    }

    #[test]
    fn declined_stack_falls_back_to_back_to_back() {
        // a strategy with no fitting artifact (mixed batch sizes, v1
        // manifest) declines with None: the group must still complete
        // bit-identically through the per-member fallback
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let got = fan_in_stacked(&b, 4, |_| Ok(None));
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i));
        }
        assert_eq!(b.batched_execs(), 1, "still one fused dispatch");
        assert_eq!(b.stacked_execs(), 0, "a declined stack is not counted");
        assert_eq!(b.pad_waste(), 0);
    }

    #[test]
    fn stacked_error_fails_the_whole_group() {
        // every member's data rode the one stacked dispatch, so a
        // stacked failure must surface to all of them — no member may
        // silently retry on half-executed state
        let b = Arc::new(ExecBatcher::new(2, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(2));
        let spawn = |i: usize| {
            let b = b.clone();
            let sem = sem.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                b.run_stacked(key(6), input(i as f32), &sem, double_plus_one, |_| {
                    Err(Error::Runtime("stack blew up".into()))
                })
                .map(|_| ())
            })
        };
        let (a, c) = (spawn(0), spawn(1));
        for r in [a.join().unwrap(), c.join().unwrap()] {
            let e = r.unwrap_err();
            assert!(e.to_string().contains("stack blew up"), "{e}");
        }
        assert_eq!(b.stacked_execs(), 0, "a failed stack is not a stacked exec");
    }

    #[test]
    fn stacked_arity_mismatch_is_rejected_not_misdelivered() {
        // a strategy that loses a lane must error out loudly — zipping
        // short would hand members someone else's outputs
        let b = Arc::new(ExecBatcher::new(2, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(2));
        let spawn = |i: usize| {
            let b = b.clone();
            let sem = sem.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                b.run_stacked(key(7), input(i as f32), &sem, double_plus_one, |views| {
                    let mut outs = Vec::new();
                    for v in &views[..views.len() - 1] {
                        outs.push(double_plus_one(v)?);
                    }
                    Ok(Some((outs, Duration::from_millis(1), views.len())))
                })
                .map(|_| ())
            })
        };
        let (a, c) = (spawn(0), spawn(1));
        for r in [a.join().unwrap(), c.join().unwrap()] {
            let e = r.unwrap_err();
            assert!(e.to_string().contains("member outputs"), "{e}");
        }
    }

    #[test]
    fn stacked_billing_is_an_equal_per_lane_share() {
        // a 10 ms stacked execution of 2 lanes bills each member
        // exactly 5 ms: the group's summed billed time never exceeds
        // the one real stacked wall
        let b = Arc::new(ExecBatcher::new(2, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(2));
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let b = b.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let (_, _, timing) = b
                        .run_stacked(key(12), input(i as f32), &sem, double_plus_one, |v| {
                            stack_to(2, v).map(|r| {
                                r.map(|(outs, _, k)| (outs, Duration::from_millis(10), k))
                            })
                        })
                        .unwrap();
                    timing
                })
            })
            .collect();
        for h in handles {
            let timing = h.join().unwrap();
            assert_eq!(timing.exec, Duration::from_millis(5));
        }
        assert_eq!(b.stacked_execs(), 1);
    }

    #[test]
    fn effective_target_resizes_groups_and_clamps() {
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(500)));
        assert_eq!(b.effective(), 8, "effective starts at the ceiling");
        b.set_effective(2);
        assert_eq!(b.effective(), 2);
        // four callers at target 2 pair into exactly two stacked groups
        let got = fan_in_stacked(&b, 4, |v| stack_to(2, v));
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i));
        }
        assert_eq!(b.batched_execs(), 2);
        assert_eq!(b.stacked_execs(), 2);
        assert_eq!(b.pad_waste(), 0);
        // retargets clamp into [1, max]
        b.set_effective(0);
        assert_eq!(b.effective(), 1);
        b.set_effective(99);
        assert_eq!(b.effective(), 8);
    }
}
