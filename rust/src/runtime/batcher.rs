//! Engine-level execution batcher: coalesces concurrent [`Engine::run`]
//! callers holding the *same executable, compatible input shapes and the
//! same params version* into one fused engine dispatch.
//!
//! Motivation ("Towards Demystifying Serverless ML Training", SPIRT):
//! per-invocation compute overhead dominates serverless training at
//! scale. Our hot path paid it N times per epoch — N branches against
//! the same params version meant N slot acquisitions, N worker wakeups
//! and N independent PJRT dispatches serialized through `exec_slots`.
//! The batcher turns those into one *fused run*: callers enqueue
//! `(inputs, reply channel)` under a [`FuseKey`]; the first caller
//! becomes the group **leader** and collects up to `--exec-batch`
//! members within the `--exec-batch-wait-us` window (closing early the
//! moment the group fills); the leader then acquires a single execution
//! slot and drives every member's literals through the executable
//! back-to-back, splitting the outputs back per caller.
//!
//! ## The byte-identity contract
//!
//! Fusion must never change the math or the modeled accounting:
//!
//! - **gradient/loss folds** — each member executes on *its own*
//!   literals against the shared executable; nothing is summed or
//!   averaged across members, so every caller receives bit-identical
//!   outputs to an unbatched run. Members are grouped strictly by
//!   [`FuseKey`] (executable identity + batch/param shapes + params
//!   version), so cross-generation branches — whose inputs come from
//!   different params versions — can never share a group;
//! - **modeled wall / billed / cost** — each member's [`ExecTiming`]
//!   reports its *own* sub-execution as `exec` and everything else
//!   (group collect wait, slot wait, the other members' turns) as
//!   `queue_wait`, which the FaaS billing path already excludes as an
//!   in-process artifact. Modeled numbers therefore stay byte-identical
//!   at any `--exec-batch`; only the *measured* wall moves.
//!
//! ## What "fused" means here — and the performance tradeoff
//!
//! A fused dispatch is one *engine* dispatch: one slot acquisition, one
//! worker wakeup chain, the members' literals executed back-to-back on
//! the leader's thread. It is **not** a single XLA execution over
//! stacked inputs — the AOT artifacts are shape-specialized to one
//! batch size, and a stacked execution would reduce loss/gradient over
//! the combined batch, which cannot be split back per caller
//! byte-identically. (Lowering batch-size-`B·k` artifacts with
//! per-branch outputs is the ROADMAP follow-up that would turn a group
//! into literally one execution.)
//!
//! Consequently fusion amortizes the *per-dispatch* costs — slot
//! round-trips, cross-thread wakeups, cache-cold parameter reloads —
//! and that is a win exactly when those dominate: small/serialized
//! `--exec-slots` (the paper tables' honest-timing mode) or many tiny
//! branches. With `--exec-slots` at machine size and heavy branches,
//! the group runs sequentially under its single slot while other slots
//! idle, trading away intra-group parallelism: measured wall can then
//! *grow*. This is why the knob defaults to off and the bench pins
//! `--exec-slots 1` for the batched-vs-unbatched comparison.
//!
//! ## Liveness
//!
//! The leader never waits while holding an execution slot, followers
//! never hold one at all, and the collect wait is bounded by the window
//! — so the worst case under starved concurrency (fewer concurrent
//! same-key callers than `--exec-batch`) is a window's delay per group,
//! never a deadlock. A leader that dies mid-group drops its members'
//! reply channels, which surfaces as an error on their side rather than
//! a hang. Effective fill is bounded by how many same-key branches are
//! actually concurrent: `min(--exec-batch, --exec-threads, per-peer
//! admission cap)`.
//!
//! [`Engine::run`]: super::Engine::run

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::engine::{ExecTiming, Executable};
use crate::error::{Error, Result};
use crate::util::sync::Semaphore;

/// Default collect window: long enough for a worker-pool wave of
/// same-epoch branches to meet in the batcher, short enough to be
/// invisible next to a PJRT gradient execution.
pub const DEFAULT_EXEC_BATCH_WAIT: Duration = Duration::from_micros(500);

/// Fusion group key: only callers agreeing on every field may share a
/// fused dispatch.
///
/// `exe` (the compiled executable's address) already implies the full
/// input signature — artifacts are shape-specialized — but the logical
/// batch size and param count are kept as an explicit shape-compat
/// guard, and `version` carries the params generation so branches of
/// different param versions (overlapping epochs in cross-epoch mode)
/// never fuse.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FuseKey {
    /// Executable identity (stable: the engine caches executables for
    /// the life of the process).
    pub exe: usize,
    /// Logical batch size the artifact is specialized to.
    pub batch: usize,
    /// Parameter vector length.
    pub params: usize,
    /// Params version (the offload generation tag).
    pub version: u64,
}

impl FuseKey {
    pub fn for_exe(exe: &Arc<Executable>, batch: usize, params: usize, version: u64) -> Self {
        Self { exe: Arc::as_ptr(exe) as usize, batch, params, version }
    }
}

/// Owned input/output literals crossing threads between a follower and
/// its group leader.
///
/// SAFETY: mirrors [`Executable`]'s rationale — PJRT literals are
/// host-side buffers whose wrappers omit `Send` only because they hold
/// raw pointers. Each literal vector has exactly one owner at any time:
/// a follower moves its inputs into the group under the group mutex,
/// the leader takes them, executes, and moves them (plus the outputs)
/// back through the reply channel.
struct LitVec(Vec<xla::Literal>);
unsafe impl Send for LitVec {}

/// What a leader sends each member back: outputs, the member's own
/// input literals (returned so callers can re-use cached packings), and
/// the member's own sub-execution duration.
type MemberReply = Result<(LitVec, LitVec, Duration)>;

struct Member {
    inputs: LitVec,
    reply: SyncSender<MemberReply>,
}

struct GroupState {
    members: Vec<Member>,
    /// Set once the leader has taken the members: late arrivals must
    /// start a fresh group instead of enqueueing into a dead one.
    closed: bool,
}

struct Group {
    state: Mutex<GroupState>,
    /// Signalled when the group fills; the leader parks here.
    filled: Condvar,
}

impl Group {
    fn new() -> Self {
        Self {
            state: Mutex::new(GroupState { members: Vec::new(), closed: false }),
            filled: Condvar::new(),
        }
    }
}

enum Role {
    /// This caller opened the group; its own inputs ride along.
    Leader(Arc<Group>, Vec<xla::Literal>),
    /// This caller enqueued into an open group; the reply arrives here.
    Follower(Receiver<MemberReply>),
}

/// The coalescing core. Owned by the [`Engine`]; exposed publicly so
/// benches and tests can exercise the grouping machinery with synthetic
/// execution closures (no artifacts needed).
///
/// [`Engine`]: super::Engine
pub struct ExecBatcher {
    max: usize,
    wait: Duration,
    groups: Mutex<HashMap<FuseKey, Arc<Group>>>,
    batched_execs: AtomicU64,
    fused_branches: AtomicU64,
}

impl ExecBatcher {
    /// `max` members per fused run (`<= 1` disables fusion at the
    /// engine level — [`Engine::run_fused`] then takes the plain path);
    /// `wait` bounds how long a leader collects before dispatching a
    /// partial group.
    ///
    /// [`Engine::run_fused`]: super::Engine::run_fused
    pub fn new(max: usize, wait: Duration) -> Self {
        Self {
            max: max.max(1),
            wait,
            groups: Mutex::new(HashMap::new()),
            batched_execs: AtomicU64::new(0),
            fused_branches: AtomicU64::new(0),
        }
    }

    /// Maximum members per fused run.
    pub fn max(&self) -> usize {
        self.max
    }

    /// The collect window.
    pub fn wait(&self) -> Duration {
        self.wait
    }

    /// Fused dispatches performed (each group run counts once, whatever
    /// its fill).
    pub fn batched_execs(&self) -> u64 {
        self.batched_execs.load(Ordering::Relaxed)
    }

    /// Total branches that went through fused dispatches.
    pub fn fused_branches(&self) -> u64 {
        self.fused_branches.load(Ordering::Relaxed)
    }

    /// Join (or lead) the fused run for `key`. Blocks until this
    /// caller's inputs have executed; returns `(outputs, inputs back,
    /// timing)` — `timing.exec` is this caller's own sub-execution,
    /// `timing.queue_wait` everything else (collect window, slot wait,
    /// other members' turns).
    ///
    /// `exec` runs one input list against the shared executable; only
    /// the *leader's* closure is ever invoked (for every member), which
    /// is sound because the key pins the executable identity.
    pub fn run<E>(
        &self,
        key: FuseKey,
        inputs: Vec<xla::Literal>,
        sem: &Semaphore,
        exec: E,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        E: Fn(&[xla::Literal]) -> Result<Vec<xla::Literal>>,
    {
        let t_start = Instant::now();
        match self.enlist(key, inputs) {
            Role::Follower(rx) => match rx.recv() {
                Ok(Ok((outs, ins, exec))) => {
                    let queue_wait = t_start.elapsed().saturating_sub(exec);
                    Ok((outs.0, ins.0, ExecTiming { exec, queue_wait }))
                }
                Ok(Err(e)) => Err(e),
                // the leader died between taking the group and replying
                // (a panic inside the handler stack): fail this branch
                // loudly instead of hanging — the FaaS retry policy owns
                // what happens next
                Err(_) => Err(Error::Runtime(
                    "fused execution leader vanished before replying".into(),
                )),
            },
            Role::Leader(group, own) => self.lead(key, group, own, t_start, sem, exec),
        }
    }

    /// Become a follower of an open group, or the leader of a fresh one.
    fn enlist(&self, key: FuseKey, inputs: Vec<xla::Literal>) -> Role {
        let mut groups = self.groups.lock().unwrap();
        if let Some(group) = groups.get(&key) {
            let group = group.clone();
            // lock order is always map -> group
            let mut st = group.state.lock().unwrap();
            // joinable iff still open and there is room left beside the
            // leader: total occupancy is members + 1
            if !st.closed && st.members.len() + 2 <= self.max {
                let (tx, rx) = sync_channel(1);
                st.members.push(Member { inputs: LitVec(inputs), reply: tx });
                let full = st.members.len() + 1 >= self.max;
                drop(st);
                drop(groups);
                if full {
                    group.filled.notify_all();
                }
                return Role::Follower(rx);
            }
            // closed (leader already collecting) or full (leader not
            // yet woken): fall through and replace it — the old
            // leader's cleanup is pointer-checked, so it will not
            // remove the replacement
        }
        let fresh = Arc::new(Group::new());
        groups.insert(key, fresh.clone());
        Role::Leader(fresh, inputs)
    }

    /// Leader phase: collect members until full or the window expires,
    /// close the group, then run everyone under one execution slot.
    fn lead<E>(
        &self,
        key: FuseKey,
        group: Arc<Group>,
        own_inputs: Vec<xla::Literal>,
        t_start: Instant,
        sem: &Semaphore,
        exec: E,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, ExecTiming)>
    where
        E: Fn(&[xla::Literal]) -> Result<Vec<xla::Literal>>,
    {
        // collect: park on the condvar until the group fills or the
        // window runs out (no lock held besides the group's own, and
        // no execution slot — a starved group can never block the
        // engine)
        let deadline = Instant::now() + self.wait;
        {
            let mut st = group.state.lock().unwrap();
            while st.members.len() + 1 < self.max {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) =
                    group.filled.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        // close: retire the group from the map (unless a racing joiner
        // already replaced a full group with a fresh one), then take
        // the members. Joiners that slipped in between the wake-up and
        // this close are included — the close is what makes the member
        // set final.
        let members = {
            let mut groups = self.groups.lock().unwrap();
            if let Some(current) = groups.get(&key) {
                if Arc::ptr_eq(current, &group) {
                    groups.remove(&key);
                }
            }
            let mut st = group.state.lock().unwrap();
            st.closed = true;
            std::mem::take(&mut st.members)
        };

        // fused dispatch: one execution slot for the whole group
        let _slot = sem.acquire();
        self.batched_execs.fetch_add(1, Ordering::Relaxed);
        self.fused_branches
            .fetch_add(1 + members.len() as u64, Ordering::Relaxed);

        // the leader's own turn first, then every member in arrival
        // order; each turn is timed individually so billing stays
        // per-branch
        let t0 = Instant::now();
        let own_result = exec(&own_inputs);
        let own_exec = t0.elapsed();
        for Member { inputs, reply } in members {
            let t0 = Instant::now();
            let result = exec(&inputs.0);
            let exec_dur = t0.elapsed();
            // a receiver can only be gone if the follower's thread died
            let _ = reply
                .send(result.map(|outs| (LitVec(outs), inputs, exec_dur)));
        }
        let outs = own_result?;
        // the leader's queue_wait is computed exactly like a follower's:
        // everything that is not its own turn — collect window, slot
        // wait, AND the member turns it served — is a fusion artifact.
        // Snapshotting before the member loop would leak the other
        // members' executions into the leader's billed handler time.
        let queue_wait = t_start.elapsed().saturating_sub(own_exec);
        Ok((outs, own_inputs, ExecTiming { exec: own_exec, queue_wait }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::literal_f32;
    use std::sync::Barrier;

    /// A deterministic synthetic "execution": reads the single rank-1
    /// f32 input and returns `[2x + 1]` — pure data movement through
    /// the batcher, bitwise checkable.
    fn double_plus_one(ins: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let v = ins[0].to_vec::<f32>()?;
        let out: Vec<f32> = v.iter().map(|x| 2.0 * x + 1.0).collect();
        Ok(vec![literal_f32(&out, &[out.len() as i64])?])
    }

    fn key(version: u64) -> FuseKey {
        FuseKey { exe: 0xDEAD, batch: 4, params: 8, version }
    }

    fn input(seed: f32) -> Vec<xla::Literal> {
        vec![literal_f32(&[seed, seed + 0.25, seed * 3.0, -seed], &[4]).unwrap()]
    }

    /// Run `n` concurrent callers of `version_of(i)` through one
    /// batcher; returns per-caller output bits.
    fn fan_in(
        batcher: &Arc<ExecBatcher>,
        n: usize,
        version_of: impl Fn(usize) -> u64 + Copy + Send + 'static,
    ) -> Vec<Vec<u32>> {
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let batcher = batcher.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let inputs = input(i as f32);
                    let want_back: Vec<u32> = inputs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    barrier.wait();
                    let (outs, ins, _timing) = batcher
                        .run(key(version_of(i)), inputs, &sem, double_plus_one)
                        .unwrap();
                    // the caller's own literals come back for re-use
                    let got_back: Vec<u32> = ins[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    assert_eq!(got_back, want_back, "inputs must round-trip");
                    outs[0]
                        .to_vec::<f32>()
                        .unwrap()
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn expected(i: usize) -> Vec<u32> {
        let seed = i as f32;
        [seed, seed + 0.25, seed * 3.0, -seed]
            .iter()
            .map(|x| (2.0 * x + 1.0f32).to_bits())
            .collect()
    }

    #[test]
    fn full_group_fuses_into_one_dispatch() {
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(500)));
        let got = fan_in(&b, 8, |_| 7);
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i), "member {i} got someone else's output");
        }
        assert_eq!(b.batched_execs(), 1, "8 callers at batch 8 = one fused run");
        assert_eq!(b.fused_branches(), 8);
    }

    #[test]
    fn cross_version_callers_never_fuse() {
        // two params versions, four callers each: exactly two groups,
        // never a mixed one — the cross-generation contract
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let got = fan_in(&b, 8, |i| (i % 2) as u64);
        for (i, bits) in got.iter().enumerate() {
            assert_eq!(bits, &expected(i));
        }
        assert_eq!(
            b.batched_execs(),
            2,
            "4+4 callers of two versions must form exactly two fused runs"
        );
        assert_eq!(b.fused_branches(), 8);
    }

    #[test]
    fn window_expiry_dispatches_partial_group() {
        // a lone caller cannot fill the group: the window bounds its
        // wait and the singleton still executes
        let b = Arc::new(ExecBatcher::new(8, Duration::from_millis(5)));
        let got = fan_in(&b, 1, |_| 1);
        assert_eq!(got[0], expected(0));
        assert_eq!(b.batched_execs(), 1);
        assert_eq!(b.fused_branches(), 1);
    }

    #[test]
    fn sequential_callers_form_sequential_groups() {
        // no concurrency: each call leads its own group (fill 1) —
        // correctness never depends on arrival luck
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(1)));
        let sem = Semaphore::new(1);
        for i in 0..3usize {
            let (outs, _, _) = b
                .run(key(9), input(i as f32), &sem, double_plus_one)
                .unwrap();
            let bits: Vec<u32> = outs[0]
                .to_vec::<f32>()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(bits, expected(i));
        }
        assert_eq!(b.batched_execs(), 3);
        assert_eq!(b.fused_branches(), 3);
    }

    #[test]
    fn billed_exec_is_one_turn_for_every_member_including_the_leader() {
        // 4 callers, each turn ~20 ms: every caller's `exec` must cover
        // its own turn only — the rest of the group's work lands in
        // queue_wait, which billing excludes. A leader that billed its
        // members' turns would report ~80 ms here (regression: its
        // queue_wait used to be snapshotted before the member loop).
        const TURN_MS: u64 = 20;
        let b = Arc::new(ExecBatcher::new(4, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let b = b.clone();
                let sem = sem.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let t0 = Instant::now();
                    let (_, _, timing) = b
                        .run(key(11), input(i as f32), &sem, |ins| {
                            std::thread::sleep(Duration::from_millis(TURN_MS));
                            double_plus_one(ins)
                        })
                        .unwrap();
                    (timing, t0.elapsed())
                })
            })
            .collect();
        for h in handles {
            let (timing, wall) = h.join().unwrap();
            // what the FaaS layer would bill is the caller's handler
            // wall minus the reported queue_wait — it must stay ~one
            // turn (generous slack, but far below the 3-extra-turns a
            // leaked group would add)
            let billed = wall.saturating_sub(timing.queue_wait);
            assert!(
                billed < Duration::from_millis(3 * TURN_MS),
                "a member would bill more than its own turn: {billed:?} \
                 (wall {wall:?}, queue_wait {:?})",
                timing.queue_wait
            );
            assert!(
                timing.exec < Duration::from_millis(3 * TURN_MS),
                "a member's own-execution report exceeds its turn: {:?}",
                timing.exec
            );
        }
        assert_eq!(b.batched_execs(), 1);
    }

    #[test]
    fn member_error_is_delivered_to_that_member_only() {
        // an exec failure for one member's inputs must not poison the
        // others: encode "fail" as a NaN marker the closure rejects
        let b = Arc::new(ExecBatcher::new(2, Duration::from_millis(500)));
        let sem = Arc::new(Semaphore::new(1));
        let barrier = Arc::new(Barrier::new(2));
        let spawn = |poison: bool| {
            let b = b.clone();
            let sem = sem.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let inputs = if poison {
                    vec![literal_f32(&[f32::NAN], &[1]).unwrap()]
                } else {
                    input(1.0)
                };
                barrier.wait();
                b.run(key(3), inputs, &sem, |ins| {
                    let v = ins[0].to_vec::<f32>()?;
                    if v.iter().any(|x| x.is_nan()) {
                        return Err(Error::Runtime("poisoned member".into()));
                    }
                    double_plus_one(ins)
                })
                .map(|(outs, _, _)| outs[0].to_vec::<f32>().unwrap())
            })
        };
        let ok = spawn(false);
        let bad = spawn(true);
        let results = [ok.join().unwrap(), bad.join().unwrap()];
        let (oks, errs): (Vec<_>, Vec<_>) = results.into_iter().partition(|r| r.is_ok());
        assert_eq!(oks.len(), 1, "the healthy member must succeed");
        assert_eq!(errs.len(), 1, "the poisoned member must fail alone");
        assert!(errs[0].as_ref().unwrap_err().to_string().contains("poisoned"));
    }
}
