//! Runtime layer: the rust side of the AOT bridge.
//!
//! Loads `artifacts/manifest.json` + the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the PJRT CPU client, and
//! exposes typed entry points to the coordinator:
//!
//! - [`ModelRuntime::grad`] — (params, x, y) -> (loss, flat gradient):
//!   the per-batch hot spot (contains the L2 model and the L1 Pallas
//!   matmul kernels, lowered into one HLO module). Fused groups of
//!   concurrent same-version callers additionally take the stacked
//!   fast path — ONE `grad_stacked_{B}x{k}` execution with per-branch
//!   outputs — when the manifest (schema v2) carries such artifacts;
//! - [`ModelRuntime::update`] — SGD apply;
//! - [`ModelRuntime::eval`] — (loss, correct count) on a validation set;
//! - [`QsgdKernel`] — the Pallas quantizer pair, used to cross-validate
//!   the rust QSGD codec against the kernel bit-for-bit.
//!
//! Python never runs here: the binary is self-contained given the
//! artifacts directory.

mod batcher;
mod engine;
mod manifest;

pub use batcher::{ExecBatcher, FuseKey, StackedRun, DEFAULT_EXEC_BATCH_WAIT};
pub use engine::{literal_f32, literal_i32, scalar_f32, Engine, ExecTiming, Executable};
pub use manifest::{Manifest, ModelEntry, QsgdEntry, MANIFEST_VERSION};

use std::sync::Arc;
use std::time::Duration;

use crate::data::Batch;
use crate::error::{Error, Result};

/// PJRT input literals for one batch object, packed once and reused
/// across epochs instead of being re-copied (`vec1` + reshape) on every
/// branch invocation.
///
/// Single-occupancy checkout protocol: the one branch per epoch that
/// reads a batch object takes the packed literals out of the
/// [`DecodedCache`] sidecar, executes with them, and checks them back
/// in — [`ModelRuntime::grad_packed`] returns them for exactly that.
///
/// SAFETY: mirrors [`Executable`]'s rationale — literals are host-side
/// buffers whose wrapper omits `Send` only because it holds a raw
/// pointer; the checkout protocol hands the value to one thread at a
/// time.
///
/// [`DecodedCache`]: crate::store::DecodedCache
pub struct PackedBatch {
    batch: usize,
    x: xla::Literal,
    y: xla::Literal,
}
unsafe impl Send for PackedBatch {}

impl PackedBatch {
    /// Logical batch size these literals were packed for.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

/// A model's compiled entry points, bound to one (model, dataset) pair.
pub struct ModelRuntime {
    pub entry: ModelEntry,
    engine: Arc<Engine>,
    manifest: Manifest,
}

impl ModelRuntime {
    /// Load a model runtime from an artifacts dir.
    pub fn load(engine: Arc<Engine>, artifacts_dir: &str, model_key: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let entry = manifest.model(model_key)?.clone();
        Ok(Self { entry, engine, manifest })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn param_count(&self) -> usize {
        self.entry.param_count
    }

    /// (h, w, c) input shape.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.entry.input
    }

    /// Initial parameters (as lowered by the python side, seed 0).
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.manifest.resolve(&self.entry.init_params);
        let raw = std::fs::read(&path)?;
        if raw.len() != 4 * self.entry.param_count {
            return Err(Error::Runtime(format!(
                "{}: expected {} bytes, got {}",
                path.display(),
                4 * self.entry.param_count,
                raw.len()
            )));
        }
        Ok(crate::util::bytes::bytes_to_f32s(&raw))
    }

    fn check_params(&self, params: &[f32]) -> Result<()> {
        if params.len() != self.entry.param_count {
            return Err(Error::Runtime(format!(
                "params len {} != {}",
                params.len(),
                self.entry.param_count
            )));
        }
        Ok(())
    }

    fn batch_literals(
        &self,
        batch: usize,
        x: &[f32],
        y: &[i32],
    ) -> Result<(xla::Literal, xla::Literal)> {
        let (h, w, c) = self.entry.input;
        let lx = literal_f32(x, &[batch as i64, h as i64, w as i64, c as i64])?;
        let ly = literal_i32(y, &[batch as i64])?;
        Ok((lx, ly))
    }

    /// Compute (loss, flat gradient) for one batch — Algorithm 1's
    /// `ComputeBatchGradients`. `pallas=false` selects the no-kernel
    /// ablation artifact.
    pub fn grad(
        &self,
        batch: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        pallas: bool,
    ) -> Result<GradOutput> {
        let packed = self.pack_batch_literals_raw(batch, x, y)?;
        Ok(self.grad_packed(params, packed, pallas, None)?.0)
    }

    /// Pack a batch's input literals once, for reuse across epochs (see
    /// [`PackedBatch`]).
    pub fn pack_batch_literals(&self, batch: &Batch) -> Result<PackedBatch> {
        self.pack_batch_literals_raw(batch.size, &batch.x, &batch.y)
    }

    fn pack_batch_literals_raw(&self, batch: usize, x: &[f32], y: &[i32]) -> Result<PackedBatch> {
        let (lx, ly) = self.batch_literals(batch, x, y)?;
        Ok(PackedBatch { batch, x: lx, y: ly })
    }

    /// [`Self::grad`] over pre-packed batch literals, returning them to
    /// the caller afterwards (cache check-in). `fuse_version` is the
    /// params version tag: `Some(v)` routes the execution through the
    /// engine's [`ExecBatcher`], fusing it with concurrent same-artifact
    /// same-version branches into one engine dispatch; `None` always
    /// dispatches alone. Either way the math is bit-identical — fusion
    /// never mixes members' literals.
    pub fn grad_packed(
        &self,
        params: &[f32],
        packed: PackedBatch,
        pallas: bool,
        fuse_version: Option<u64>,
    ) -> Result<(GradOutput, PackedBatch)> {
        self.check_params(params)?;
        let batch = packed.batch;
        let file = if pallas {
            self.entry.grad_for(batch)?.to_string()
        } else {
            self.entry
                .grad_nopallas
                .get(&batch)
                .cloned()
                .ok_or_else(|| {
                    Error::Runtime(format!("no nopallas grad artifact for batch {batch}"))
                })?
        };
        let exe = self.engine.load(self.manifest.resolve(&file))?;
        let lp = literal_f32(params, &[params.len() as i64])?;
        let PackedBatch { x: lx, y: ly, .. } = packed;
        let inputs = vec![lp, lx, ly];
        let (parts, mut inputs, timing) = match fuse_version {
            Some(version) => {
                let key = FuseKey::for_exe(&exe, batch, params.len(), version);
                // stacked artifacts cover only the pallas grad path; the
                // closure falls back to back-to-back turns for group
                // sizes no stacked factor covers
                if pallas && !self.entry.stacked_ks(batch).is_empty() {
                    self.engine.run_fused_stacked(&exe, inputs, key, |views| {
                        self.grad_stacked(batch, views)
                    })?
                } else {
                    self.engine.run_fused(&exe, inputs, key)?
                }
            }
            None => {
                let (parts, timing) = self.engine.run(&exe, &inputs)?;
                (parts, inputs, timing)
            }
        };
        if parts.len() != 2 {
            return Err(Error::Runtime(format!(
                "grad artifact returned {} outputs, expected 2",
                parts.len()
            )));
        }
        let out = GradOutput {
            loss: scalar_f32(&parts[0])?,
            grads: parts[1].to_vec::<f32>()?,
            wall: timing.exec,
            queue_wait: timing.queue_wait,
        };
        // recover the batch literals for the caller's cache check-in
        // (inputs were [params, x, y]; the params literal is per-epoch
        // scratch and simply drops)
        let ly = inputs
            .pop()
            .ok_or_else(|| Error::Runtime("fused run returned no input literals".into()))?;
        let lx = inputs
            .pop()
            .ok_or_else(|| Error::Runtime("fused run returned no input literals".into()))?;
        Ok((out, PackedBatch { batch, x: lx, y: ly }))
    }

    /// Execute a whole fused group as ONE stacked XLA execution.
    ///
    /// Invoked by the group leader (via [`Engine::run_fused_stacked`])
    /// with every member's input slice — `[params, x, y]` each, leader
    /// first. Packs the members' micro-batches into one `(k, B, H, W,
    /// C)` literal against the smallest available stacking factor `k >=
    /// group size` (pad lanes replicate the last real member and are
    /// discarded), runs the `grad_stacked_{B}x{k}` artifact once, and
    /// splits its per-branch `(losses[k], grads[k, P])` outputs back
    /// into per-member `(loss, grads)` literal pairs.
    ///
    /// Returns `Ok(None)` — back-to-back fallback — for singleton
    /// groups (stacking would only add pad waste) and for groups larger
    /// than every available factor.
    fn grad_stacked(&self, batch: usize, views: &[&[xla::Literal]]) -> Result<StackedRun> {
        let g = views.len();
        if g < 2 {
            return Ok(None);
        }
        let Some(k) = self.entry.stacked_ks(batch).into_iter().find(|&k| k >= g) else {
            return Ok(None);
        };
        let file = self.entry.grad_stacked_for(batch, k)?.to_string();
        let exe = self.engine.load(self.manifest.resolve(&file))?;
        let (h, w, c) = self.entry.input;
        let p = self.entry.param_count;
        // the FuseKey pins the params version, so every member's params
        // literal is identical: reuse the leader's
        let params = views[0][0].to_vec::<f32>()?;
        let elems = batch * h * w * c;
        let mut xs = Vec::with_capacity(k * elems);
        let mut ys = Vec::with_capacity(k * batch);
        for lane in 0..k {
            let v = views[lane.min(g - 1)];
            xs.extend_from_slice(&v[1].to_vec::<f32>()?);
            ys.extend_from_slice(&v[2].to_vec::<i32>()?);
        }
        let lp = literal_f32(&params, &[p as i64])?;
        let lx = literal_f32(
            &xs,
            &[k as i64, batch as i64, h as i64, w as i64, c as i64],
        )?;
        let ly = literal_i32(&ys, &[k as i64, batch as i64])?;
        // the leader already holds the group's execution slot: dispatch
        // raw, timing only the stacked execution itself
        let t0 = std::time::Instant::now();
        let parts = engine::execute_literals(&exe, &[lp, lx, ly])?;
        let wall = t0.elapsed();
        if parts.len() != 2 {
            return Err(Error::Runtime(format!(
                "stacked grad artifact returned {} outputs, expected 2",
                parts.len()
            )));
        }
        let losses = parts[0].to_vec::<f32>()?;
        let grads = parts[1].to_vec::<f32>()?;
        if losses.len() != k || grads.len() != k * p {
            return Err(Error::Runtime(format!(
                "stacked grad artifact shape mismatch: {} losses / {} grad \
                 elems for k={k}, params={p}",
                losses.len(),
                grads.len()
            )));
        }
        let mut per_member = Vec::with_capacity(g);
        for i in 0..g {
            per_member.push(vec![
                literal_f32(&losses[i..i + 1], &[1])?,
                literal_f32(&grads[i * p..(i + 1) * p], &[p as i64])?,
            ]);
        }
        Ok(Some((per_member, wall, k)))
    }

    /// SGD apply: params' = params - lr * grads.
    pub fn update(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        self.check_params(params)?;
        self.check_params(grads)?;
        let exe = self
            .engine
            .load(self.manifest.resolve(&self.entry.update))?;
        let lp = literal_f32(params, &[params.len() as i64])?;
        let lg = literal_f32(grads, &[grads.len() as i64])?;
        let llr = literal_f32(&[lr], &[1])?;
        let (parts, _) = self.engine.run(&exe, &[lp, lg, llr])?;
        Ok(parts[0].to_vec::<f32>()?)
    }

    /// Evaluate a batch: (mean loss, correct count).
    pub fn eval(&self, batch: usize, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.check_params(params)?;
        let file = self.entry.eval.get(&batch).cloned().ok_or_else(|| {
            Error::Runtime(format!(
                "no eval artifact for batch {batch} (have {:?})",
                self.entry.eval.keys().collect::<Vec<_>>()
            ))
        })?;
        let exe = self.engine.load(self.manifest.resolve(&file))?;
        let lp = literal_f32(params, &[params.len() as i64])?;
        let (lx, ly) = self.batch_literals(batch, x, y)?;
        let (parts, _) = self.engine.run(&exe, &[lp, lx, ly])?;
        Ok((scalar_f32(&parts[0])?, scalar_f32(&parts[1])?))
    }

    /// Evaluate a whole dataset by tiling over the largest eval batch
    /// that fits (remainder dropped). Returns (mean loss, accuracy).
    pub fn eval_dataset(&self, params: &[f32], data: &crate::data::Dataset) -> Result<(f32, f32)> {
        let batch = *self
            .entry
            .eval
            .keys()
            .filter(|&&b| b <= data.len())
            .max()
            .ok_or_else(|| Error::Runtime("validation set smaller than any eval batch".into()))?;
        let elems = data.sample_elems();
        let mut total_loss = 0f64;
        let mut correct = 0f64;
        let mut batches = 0usize;
        for chunk in 0..(data.len() / batch) {
            let lo = chunk * batch;
            let x = &data.x[lo * elems..(lo + batch) * elems];
            let y = &data.y[lo..lo + batch];
            let (loss, ncorrect) = self.eval(batch, params, x, y)?;
            total_loss += loss as f64;
            correct += ncorrect as f64;
            batches += 1;
        }
        Ok((
            (total_loss / batches.max(1) as f64) as f32,
            (correct / (batches * batch).max(1) as f64) as f32,
        ))
    }
}

/// Result of one gradient step.
#[derive(Debug, Clone)]
pub struct GradOutput {
    pub loss: f32,
    pub grads: Vec<f32>,
    /// PJRT execution wall time (the measured Table-I compute stage).
    pub wall: Duration,
    /// Time spent waiting for an engine execution slot — an artifact of
    /// in-process concurrency that billing paths must exclude.
    pub queue_wait: Duration,
}

/// The Pallas QSGD kernel pair, runnable from rust for codec
/// cross-validation.
pub struct QsgdKernel {
    engine: Arc<Engine>,
    entry: QsgdEntry,
    dir: std::path::PathBuf,
}

impl QsgdKernel {
    pub fn load(engine: Arc<Engine>, artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self {
            engine,
            entry: manifest.qsgd.clone(),
            dir: manifest.dir,
        })
    }

    pub fn n(&self) -> usize {
        self.entry.n
    }

    pub fn s(&self) -> u8 {
        self.entry.s
    }

    /// Run the Pallas quantizer: (v, u) -> (levels, norm).
    pub fn encode(&self, v: &[f32], u: &[f32]) -> Result<(Vec<i32>, f32)> {
        if v.len() != self.entry.n || u.len() != self.entry.n {
            return Err(Error::Runtime(format!(
                "qsgd kernel is specialized to n={}",
                self.entry.n
            )));
        }
        let exe = self.engine.load(self.dir.join(&self.entry.encode))?;
        let lv = literal_f32(v, &[v.len() as i64])?;
        let lu = literal_f32(u, &[u.len() as i64])?;
        let (parts, _) = self.engine.run(&exe, &[lv, lu])?;
        Ok((parts[0].to_vec::<i32>()?, scalar_f32(&parts[1])?))
    }

    /// Run the Pallas dequantizer: (levels, norm) -> v_hat.
    pub fn decode(&self, q: &[i32], norm: f32) -> Result<Vec<f32>> {
        let exe = self.engine.load(self.dir.join(&self.entry.decode))?;
        let lq = literal_i32(q, &[q.len() as i64])?;
        let ln = literal_f32(&[norm], &[1])?;
        let (parts, _) = self.engine.run(&exe, &[lq, ln])?;
        Ok(parts[0].to_vec::<f32>()?)
    }
}
