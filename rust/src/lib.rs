//! # p2pless — serverless peer-to-peer distributed training
//!
//! Production-shaped reproduction of *"Exploring the Impact of Serverless
//! Computing on Peer To Peer Training Machine Learning"* (Barrak et al.,
//! CS.DC 2023).
//!
//! The paper's system: a peer-to-peer training cluster where each peer
//! (an EC2 instance) offloads its most expensive stage — per-batch
//! gradient computation — to a fleet of serverless functions (AWS Lambda)
//! orchestrated by a dynamically-generated Step Functions state machine,
//! while peers exchange averaged gradients through dedicated persistent
//! queues (RabbitMQ), optionally QSGD-compressed, in synchronous or
//! asynchronous mode.
//!
//! This crate is the L3 coordinator plus every substrate the paper runs
//! on (see `DESIGN.md` for the substitution table):
//!
//! - [`broker`] — RabbitMQ-like message broker (latest-gradient queues,
//!   consume-without-delete, sync-barrier queue, 100 MB message cap).
//! - [`store`] — S3-like object store (UUID-referenced large payloads).
//! - [`faas`] — Lambda + Step Functions substrate (cold starts, memory
//!   sizing, GB-second billing, parallel Map state, 15-min timeout),
//!   dispatched over a real worker pool ([`faas::executor`]) with dual
//!   time accounting: a deterministic *modeled* wall for the paper
//!   tables and a *measured* wall that shrinks with `--exec-threads`.
//! - [`cloud`] — EC2 instance catalog (t2.*) with real AWS pricing.
//! - [`compress`] — QSGD / top-k / delta gradient codecs.
//! - [`runtime`] — PJRT engine executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at runtime.
//! - [`data`] — synthetic MNIST/CIFAR datasets, partitioner, batcher.
//! - [`coordinator`] — the paper's contribution: peer actors running
//!   Algorithm 1, gradient exchange, barriers, convergence detection,
//!   and the serverless offload path.
//! - [`perfmodel`] — analytic time model calibrated to the paper's
//!   measurements (Tables I–III), used to extrapolate cloud-scale runs.
//! - [`costs`] — the paper's Eq. (1)/(2) pricing engine.
//! - [`metrics`] — per-stage CPU/memory/time collection (Table I stages).
//! - [`harness`] — one driver per paper table/figure.

pub mod broker;
pub mod cloud;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod costs;
pub mod data;
pub mod error;
pub mod faas;
pub mod harness;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod store;
pub mod util;

pub use error::{Error, Result};
